"""Jitted control-plane hot path: greedy_jit/local_jit parity with the
numpy baselines, registry integration, and the zero-numpy end-to-end
jitted step (partition → offload → cost under jax.jit / lax.scan)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costs
from repro.core.api import (GraphEdgeController, JitPolicy, JitStepResult,
                            available_offload_policies, get_offload_policy)
from repro.core.dynamic_graph import perturb_scenario, random_scenario
from repro.core.offload.baselines import (greedy_rollout_jit,
                                          local_rollout_jit, run_greedy,
                                          run_local)
from repro.core.offload.batched_env import make_scene, stack_states
from repro.core.offload.env import OffloadEnv


def scenario(seed=0, capacity=20, users=16, m=3, e=32):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, capacity, users, e)
    net = costs.default_network(rng, capacity, m)
    return state, net


# -- registry ----------------------------------------------------------------

def test_jit_policies_registered():
    assert {"greedy_jit", "local_jit"} <= set(available_offload_policies())
    for name in ("greedy_jit", "local_jit"):
        pol = get_offload_policy(name)
        assert pol.name == name
        assert isinstance(pol, JitPolicy)
    assert not isinstance(get_offload_policy("greedy"), JitPolicy)
    assert not isinstance(get_offload_policy("local"), JitPolicy)


# -- parity with the numpy baselines ----------------------------------------

CASES = [
    dict(seed=0, capacity=20, users=16, m=3, e=32),     # inactive tail
    dict(seed=1, capacity=16, users=16, m=4, e=40),     # fully active
    dict(seed=2, capacity=24, users=9, m=2, e=12),      # mostly inactive
    dict(seed=3, capacity=32, users=30, m=3, e=90),     # servers fill up
    dict(seed=4, capacity=12, users=12, m=6, e=20),     # more servers
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("jit_name,np_run", [
    ("greedy_jit", run_greedy), ("local_jit", run_local)])
def test_rollout_parity_with_numpy_env(case, jit_name, np_run):
    """Same scene → identical assignments, rewards to f32 tolerance."""
    state, net = scenario(**case)
    ctrl = GraphEdgeController(net=net, policy=jit_name)
    part = ctrl.partition(state)
    env = OffloadEnv(net, state, part, zeta_sp=ctrl.zeta_sp,
                     cost_scale=ctrl.cost_scale)
    stats = np_run(env)
    scene = make_scene(net, state, part.subgraph, zeta_sp=ctrl.zeta_sp,
                       cost_scale=ctrl.cost_scale)
    rollout = (greedy_rollout_jit if jit_name == "greedy_jit"
               else local_rollout_jit)
    assign, reward = jax.jit(rollout)(scene)
    np.testing.assert_array_equal(np.asarray(assign, np.int64), env.assign)
    assert np.isclose(float(reward), stats["reward"], rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("jit_name,np_name", [("greedy_jit", "greedy"),
                                              ("local_jit", "local")])
def test_controller_step_parity(jit_name, np_name):
    """controller.step() through the jit dispatch == the env-walking path."""
    for seed in range(3):
        state, net = scenario(seed=seed, users=14 + seed)
        d_np = GraphEdgeController(net=net, policy=np_name).step(state)
        d_j = GraphEdgeController(net=net, policy=jit_name).step(state)
        np.testing.assert_array_equal(d_j.servers, d_np.servers)
        np.testing.assert_array_equal(d_j.partition.subgraph,
                                      d_np.partition.subgraph)
        assert np.isclose(float(d_j.cost.c), float(d_np.cost.c), rtol=1e-5)
        assert np.isclose(d_j.assignment.reward, d_np.assignment.reward,
                          rtol=1e-4, atol=1e-5)
        # stats dict carries the standard episode keys
        for key in ("system_cost", "t_all", "i_all", "cross_bits"):
            assert key in d_j.assignment.stats


def test_policy_call_surface_matches_registry_baseline():
    """The OffloadPolicy __call__(env) surface works for jit policies —
    the registry contract every env-driven caller relies on."""
    state, net = scenario()
    ctrl = GraphEdgeController(net=net, policy="greedy")
    env = ctrl.make_env(state)
    a_jit = get_offload_policy("greedy_jit")(env)
    env2 = ctrl.make_env(state)
    a_np = get_offload_policy("greedy")(env2)
    np.testing.assert_array_equal(a_jit.servers, a_np.servers)
    assert np.isclose(a_jit.reward, a_np.reward, rtol=1e-4, atol=1e-5)


def test_empty_scene_all_inactive():
    """Zero active users: every slot stays unassigned, reward 0."""
    state, net = scenario(users=2)
    drop = jnp.ones(state.capacity, jnp.float32)
    from repro.core.dynamic_graph import remove_users
    empty = remove_users(state, drop)
    d = GraphEdgeController(net=net, policy="greedy_jit").step(empty)
    assert (d.servers == -1).all()
    assert d.assignment.reward == 0.0


# -- the end-to-end jitted step ----------------------------------------------

def test_jit_step_fn_runs_under_jit_and_scan():
    """partition → offload → cost traces as one XLA computation: a whole
    rollout runs inside jax.jit + lax.scan (any numpy round-trip would
    raise a TracerError), and matches the eager step()."""
    state, net = scenario(users=14)
    ctrl = GraphEdgeController(net=net, policy="greedy_jit",
                               partitioner="hicut_jax")
    fn = ctrl.jit_step_fn()

    rng = np.random.default_rng(7)
    states = [state]
    for _ in range(3):
        states.append(perturb_scenario(rng, states[-1], 0.3))
    stacked = stack_states(states)

    @jax.jit
    def roll(sts):
        def body(carry, st):
            res = fn(st)
            return carry + res.cost.c, (res.servers, res.subgraph)
        return jax.lax.scan(body, jnp.zeros(()), sts)

    total, (servers, subgraphs) = roll(stacked)
    eager = [ctrl.step(s) for s in states]
    assert np.isclose(float(total),
                      sum(float(d.cost.c) for d in eager), rtol=1e-5)
    for i, d in enumerate(eager):
        np.testing.assert_array_equal(np.asarray(servers[i]), d.servers)
        np.testing.assert_array_equal(np.asarray(subgraphs[i]),
                                      d.partition.subgraph)


def test_step_batch_matches_sequential_step():
    """step_batch — the streaming cycle's single vmapped decide+cost call
    — is assignment-exact against per-state step() and shares its
    partition cache; non-jit policies and B=1 fall back cleanly."""
    state, net = scenario(users=14)
    ctrl = GraphEdgeController(net=net, policy="greedy_jit")
    rng = np.random.default_rng(9)
    states = [state] + [perturb_scenario(rng, state, 0.3)
                        for _ in range(3)]
    eager = [ctrl.step(s) for s in states]
    batched = ctrl.step_batch(states)
    assert len(batched) == len(eager)
    for d_e, d_b in zip(eager, batched):
        np.testing.assert_array_equal(d_b.servers, d_e.servers)
        np.testing.assert_array_equal(d_b.partition.subgraph,
                                      d_e.partition.subgraph)
        assert np.isclose(float(d_b.cost.c), float(d_e.cost.c), rtol=1e-5)
        assert d_b.topo_key == d_e.topo_key
    assert ctrl.step_batch([]) == []
    assert len(ctrl.step_batch([state])) == 1
    # greedy (non-jit) silently takes the sequential road
    seq_ctrl = GraphEdgeController(net=net, policy="greedy")
    assert len(seq_ctrl.step_batch(states)) == len(states)


def test_jit_step_batch_fn_is_vmapped_step_fn():
    """jit_step_batch_fn over stacked states == per-state jit_step_fn."""
    state, net = scenario(users=12)
    ctrl = GraphEdgeController(net=net, policy="greedy_jit",
                               partitioner="hicut_jax")
    rng = np.random.default_rng(11)
    states = [state] + [perturb_scenario(rng, state, 0.4)
                        for _ in range(2)]
    res = jax.jit(ctrl.jit_step_batch_fn())(stack_states(states))
    assert isinstance(res, JitStepResult)
    fn = ctrl.jit_step_fn()
    for i, s in enumerate(states):
        one = fn(s)
        np.testing.assert_array_equal(np.asarray(res.servers[i]),
                                      np.asarray(one.servers))
        assert np.isclose(float(res.cost.c[i]), float(one.cost.c),
                          rtol=1e-6)


def test_jit_step_fn_result_type():
    state, net = scenario()
    ctrl = GraphEdgeController(net=net, policy="local_jit")
    res = jax.jit(ctrl.jit_step_fn())(state)
    assert isinstance(res, JitStepResult)
    active = np.asarray(state.mask) > 0
    servers = np.asarray(res.servers)
    assert ((servers[active] >= 0) & (servers[active] < 3)).all()
    assert (servers[~active] == -1).all()
    # cost is the exact batch model for that assignment
    w = costs.assignment_onehot(jnp.asarray(servers), 3)
    sc = costs.system_cost(net, state, w)
    assert np.isclose(float(res.cost.c), float(sc.c), rtol=1e-6)


def test_jit_step_fn_rejects_non_jit_pieces():
    state, net = scenario()
    with pytest.raises(TypeError, match="greedy_jit"):
        GraphEdgeController(net=net, policy="greedy").jit_step_fn()
    with pytest.raises(ValueError, match="hicut_ref"):
        GraphEdgeController(net=net, policy="greedy_jit",
                            partitioner="hicut_ref").jit_step_fn()
    # "none" partitioner is jnp-pure → supported
    fn = GraphEdgeController(net=net, policy="greedy_jit",
                             partitioner="none").jit_step_fn()
    res = jax.jit(fn)(state)
    active = np.asarray(state.mask) > 0
    sub = np.asarray(res.subgraph)
    assert len(np.unique(sub[active])) == active.sum()
