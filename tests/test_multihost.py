"""Multi-host SPMD serving (repro.gnn.multihost): sharded plan
construction parity, pair-exchange layout invariants, plan-shard cache
key agreement, and — in the slow lane — real multi-process gloo runs
sweeping process counts {1, 2, 4} that must be **bitwise** equal to the
single-process ``distributed_gcn_forward`` for every aggregate kernel,
inactive-vertex and zero-halo edge cases included (DESIGN.md §8)."""
import os
import socket
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import random_edges
from repro.gnn.multihost import (PlanShard, ShardedPlanCache, agree_metadata,
                                 make_partition_plan_shard, plan_shard_key,
                                 process_device_range)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def graph(rng, n=60, e=150, devices=4, inactive_frac=0.1):
    edges = random_edges(rng, n, e)
    assign = rng.integers(0, devices, n)
    assign[rng.random(n) < inactive_frac] = -1        # inactive vertices
    return edges, assign.astype(np.int64)


# -- sharded construction (fast, in-process) ----------------------------------

def test_process_device_range_contiguous_split():
    assert process_device_range(8, 0, 2) == (0, 4)
    assert process_device_range(8, 1, 2) == (4, 8)
    assert process_device_range(4, 3, 4) == (3, 4)
    with pytest.raises(AssertionError):
        process_device_range(6, 0, 4)                 # not divisible


def test_agree_metadata_single_process_is_identity():
    local = np.array([7, 3], np.int64)
    assert np.array_equal(agree_metadata(local), local)


@pytest.mark.parametrize("exchange", ["pair", "gather"])
def test_plan_shard_single_process_matches_full_plan(rng, exchange):
    """At one process the shard IS the plan: every array — including the
    O(E·K) neighbor blocks — must be bitwise equal to
    ``make_partition_plan_sparse``'s, and the degree pass must reproduce
    the per-slot neighbor sums exactly (``np.add.at`` accumulates f32
    in slot order)."""
    from repro.gnn.distributed import make_partition_plan_sparse
    edges, assign = graph(rng)
    plan = make_partition_plan_sparse(edges, assign, 4, exchange=exchange)
    shard = make_partition_plan_shard(edges, assign, 4, exchange=exchange,
                                      process_id=0, num_processes=1)
    assert (shard.dev0, shard.dev1) == (0, 4)
    assert shard.exchange == exchange
    back = shard.to_plan()
    for name in ("perm", "send_idx", "send_mask", "nbr_idx", "nbr_val",
                 "mask"):
        assert np.array_equal(getattr(back, name), getattr(plan, name)), \
            name
    assert (back.block, back.halo, back.n) == (plan.block, plan.halo,
                                               plan.n)
    assert np.array_equal(shard.wdeg, plan.nbr_val.sum(2))


def test_plan_shards_partition_the_neighbor_arrays(rng):
    """Across processes, each shard holds exactly its own device slab of
    the full plan's neighbor arrays — same K, same layout metadata."""
    from repro.gnn.distributed import make_partition_plan_sparse
    edges, assign = graph(rng)
    plan = make_partition_plan_sparse(edges, assign, 4, exchange="pair")
    for nproc in (2, 4):
        for pid in range(nproc):
            s = make_partition_plan_shard(edges, assign, 4,
                                          exchange="pair", process_id=pid,
                                          num_processes=nproc)
            assert (s.dev0, s.dev1) == process_device_range(4, pid, nproc)
            # simulated shards can't allgather K (agree_metadata is an
            # identity off-grid), so compare the valid slot prefix: the
            # slab's real neighbors match and the plan's extra padded
            # slots are inert
            assert s.k <= plan.max_degree
            slab_val = plan.nbr_val[s.dev0:s.dev1]
            assert np.array_equal(s.nbr_val, slab_val[:, :, :s.k])
            assert not slab_val[:, :, s.k:].any()
            real = s.nbr_val > 0
            assert np.array_equal(s.nbr_idx[real],
                                  plan.nbr_idx[s.dev0:s.dev1, :, :s.k][real])
            assert np.array_equal(s.perm, plan.perm)
            assert np.array_equal(s.send_idx, plan.send_idx)


def test_pair_exchange_halo_is_cut_edges_only(rng):
    """The pair layout's wire bytes cover exactly the cut: every occupied
    [q, p] send slot is a row of device q read by a cross edge into p,
    rows are unique per (q, p), and the bytes model is strictly below the
    replicate-everything baseline."""
    edges, assign = graph(rng, inactive_frac=0.0)
    shard = make_partition_plan_shard(edges, assign, 4, exchange="pair",
                                      process_id=0, num_processes=1)
    i, j = edges.T
    cross = assign[i] != assign[j]
    cut_pairs = set()
    for a, b in edges[cross]:
        cut_pairs.add((assign[a], assign[b], b))      # q=owner of dst slot
        cut_pairs.add((assign[b], assign[a], a))
    occupied = int(shard.send_mask.sum())
    assert occupied == len({(q, p, v) for q, p, v in cut_pairs})
    for q in range(4):
        for p in range(4):
            slots = shard.send_idx[q, p][shard.send_mask[q, p] > 0]
            assert len(np.unique(slots)) == len(slots)
    assert shard.bytes_per_aggregate(16) \
        < shard.replicate_bytes_per_aggregate(16)


def test_zero_halo_graph_builds_and_serves(rng):
    """No cross edges at all: halo collapses to the 1-slot minimum, every
    send mask is zero, and the sharded forward still matches the
    reference bitwise (the all_to_all moves only zero rows)."""
    import jax
    from jax.sharding import Mesh
    from repro.gnn.distributed import distributed_gcn_forward, \
        make_partition_plan_sparse
    from repro.gnn.layers import gcn_init
    from repro.gnn.multihost import fetch_global, put_feature_blocks, \
        sharded_forward_fn
    n = 24
    edges = random_edges(rng, n, 60)
    assign = np.zeros(n, np.int64)                    # all on one device
    shard = make_partition_plan_shard(edges, assign, 1, exchange="pair",
                                      process_id=0, num_processes=1)
    assert shard.halo == 1 and shard.send_mask.sum() == 0
    mesh = Mesh(np.array(jax.devices()[:1]), ("servers",))
    params = gcn_init(jax.random.PRNGKey(0), [8, 6, 4])
    x = rng.standard_normal((n, 8)).astype(np.float32)
    plan = make_partition_plan_sparse(edges, assign, 1, exchange="pair")
    ref = distributed_gcn_forward(mesh, "servers", plan, params, x)
    fwd, _ = sharded_forward_fn(mesh, "servers", shard)
    out = fwd(put_feature_blocks(mesh, "servers", shard, x), params)
    assert np.array_equal(shard.gather(fetch_global(out)), ref)


@pytest.mark.parametrize("agg", ["dense", "sparse", "fused"])
def test_sharded_forward_matches_distributed_inprocess(rng, agg):
    """Single-process resident path vs ``distributed_gcn_forward`` on the
    full plan: bitwise, for every aggregate, with inactive vertices."""
    import jax
    from jax.sharding import Mesh
    from repro.gnn.distributed import distributed_gcn_forward, \
        make_partition_plan_sparse
    from repro.gnn.layers import gcn_init
    edges, assign = graph(rng, devices=1)
    from repro.gnn.multihost import fetch_global, put_feature_blocks, \
        sharded_forward_fn
    mesh = Mesh(np.array(jax.devices()[:1]), ("servers",))
    params = gcn_init(jax.random.PRNGKey(1), [8, 6, 4])
    x = rng.standard_normal((len(assign), 8)).astype(np.float32)
    plan = make_partition_plan_sparse(edges, assign, 1, exchange="pair")
    ref = distributed_gcn_forward(mesh, "servers", plan, params, x,
                                  aggregate=agg)
    shard = make_partition_plan_shard(edges, assign, 1, exchange="pair",
                                      process_id=0, num_processes=1)
    fwd, resolved = sharded_forward_fn(mesh, "servers", shard, aggregate=agg)
    assert resolved == agg
    out = fwd(put_feature_blocks(mesh, "servers", shard, x), params)
    assert np.array_equal(shard.gather(fetch_global(out)), ref)


def test_plan_shard_key_lockstep_and_sensitivity(rng):
    """The cache key is a pure function of (edges, assign, P, exchange) —
    identical across processes by construction — and changes when any of
    them does."""
    edges, assign = graph(rng)
    k = plan_shard_key(edges, assign, 4, "pair")
    assert k == plan_shard_key(edges.copy(), assign.copy(), 4, "pair")
    assert k != plan_shard_key(edges, assign, 2, "pair")
    assert k != plan_shard_key(edges, assign, 4, "gather")
    other = assign.copy()
    other[0] = (other[0] + 1) % 4
    assert k != plan_shard_key(edges, other, 4, "pair")


def test_sharded_plan_cache_hits_on_same_topology(rng):
    import jax
    from jax.sharding import Mesh
    edges, assign = graph(rng, devices=1)
    mesh = Mesh(np.array(jax.devices()[:1]), ("servers",))
    cache = ShardedPlanCache(mesh, "servers")
    k1, shard, fwd, hit1 = cache.entry(edges, assign, 1)
    assert not hit1 and isinstance(shard, PlanShard)
    k2, shard2, fwd2, hit2 = cache.entry(edges, assign, 1)
    assert hit2 and k2 == k1 and shard2 is shard and fwd2 is fwd


def test_sharded_plan_cache_evicts_lru(rng):
    """A size-2 cache over 3 topologies evicts the least recently used
    shard: re-requesting the evictee is a miss that rebuilds (fresh shard
    object), while the survivors still hit, and currsize never exceeds
    the bound."""
    import jax
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("servers",))
    cache = ShardedPlanCache(mesh, "servers", size=2)
    topos = [graph(np.random.default_rng(i), devices=1) for i in range(3)]
    shards = []
    for edges, assign in topos:
        _, shard, _, hit = cache.entry(edges, assign, 1)
        assert not hit
        shards.append(shard)
    # topo0 was LRU when topo2 arrived → evicted; topo1/topo2 resident
    info = cache.info()
    assert info.currsize == 2 and info.maxsize == 2
    _, s1, _, hit1 = cache.entry(*topos[1], 1)
    _, s2, _, hit2 = cache.entry(*topos[2], 1)
    assert hit1 and s1 is shards[1]
    assert hit2 and s2 is shards[2]
    _, s0, _, hit0 = cache.entry(*topos[0], 1)
    assert not hit0 and s0 is not shards[0]       # rebuilt after eviction
    assert cache.info().currsize == 2


def test_plan_shard_key_sensitive_to_active_mask(rng):
    """The digest must change when vertices go inactive (``assign = -1``)
    or when their incident edges are dropped — otherwise a fault-churned
    layout could alias a stale cached shard."""
    edges, assign = graph(rng, inactive_frac=0.0)
    base = plan_shard_key(edges, assign, 4, "pair")
    # deactivating one vertex changes the key
    off = assign.copy()
    off[3] = -1
    assert plan_shard_key(edges, off, 4, "pair") != base
    # dropping that vertex's edges (same assignment) also changes the key
    keep = ~np.any(edges == 3, axis=1)
    assert keep.sum() < len(edges)                # the vertex had edges
    assert plan_shard_key(edges[keep], assign, 4, "pair") != base
    # and the two churned layouts do not alias each other
    assert plan_shard_key(edges[keep], off, 4, "pair") != \
        plan_shard_key(edges, off, 4, "pair")


# -- multi-process parity sweep (slow lane) -----------------------------------

_WORKER = textwrap.dedent("""
    import os, sys
    nproc, pid, port, outdir = (int(sys.argv[1]), int(sys.argv[2]),
                                sys.argv[3], sys.argv[4])
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%d" % (4 // nproc))
    import jax
    if nproc > 1:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize("127.0.0.1:" + port, nproc, pid)
    import numpy as np
    from jax.sharding import Mesh
    from repro.gnn.layers import gcn_init
    from repro.gnn.multihost import (fetch_global, make_partition_plan_shard,
                                     put_feature_blocks, sharded_forward_fn)
    rng = np.random.default_rng(5)
    n = 80
    edges = np.load(outdir + "/edges.npy")
    assign = np.load(outdir + "/assign.npy")
    x = np.load(outdir + "/x.npy")
    params = gcn_init(jax.random.PRNGKey(3), [16, 8, 5])
    mesh = Mesh(np.array(jax.devices()), ("servers",))
    shard = make_partition_plan_shard(edges, assign, 4, exchange="pair")
    xb = put_feature_blocks(mesh, "servers", shard, x)
    flags = {}
    for agg in ("dense", "sparse", "fused"):
        fwd, _ = sharded_forward_fn(mesh, "servers", shard, aggregate=agg)
        out = shard.gather(fetch_global(fwd(xb, params)))
        ref = np.load(outdir + "/ref_" + agg + ".npy")
        flags[agg] = int(np.array_equal(out, ref))
    if pid == 0:
        print("BITWISE", flags)
""")

_REF = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.gnn.distributed import (distributed_gcn_forward,
                                       make_partition_plan_sparse)
    from repro.gnn.layers import gcn_init
    outdir = sys.argv[1]
    edges = np.load(outdir + "/edges.npy")
    assign = np.load(outdir + "/assign.npy")
    x = np.load(outdir + "/x.npy")
    params = gcn_init(jax.random.PRNGKey(3), [16, 8, 5])
    mesh = Mesh(np.array(jax.devices()), ("servers",))
    plan = make_partition_plan_sparse(edges, assign, 4, exchange="pair")
    for agg in ("dense", "sparse", "fused"):
        ref = distributed_gcn_forward(mesh, "servers", plan, params, x,
                                      aggregate=agg)
        np.save(outdir + "/ref_" + agg + ".npy", np.asarray(ref))
    print("REF OK")
""")


def _free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return str(s.getsockname()[1])


@pytest.mark.slow
@pytest.mark.parametrize("nproc", [1, 2, 4])
def test_multihost_bitwise_parity_subprocess(nproc, tmp_path):
    """The full sweep the issue demands: {1, 2, 4} simulated processes
    over a 4-device mesh (1×4, 2×2, 4×1), sharded plan + resident
    features + halo-only exchange, bitwise equal to the single-process
    ``distributed_gcn_forward`` for dense/sparse/fused — on a graph with
    inactive vertices and an uneven cut."""
    rng = np.random.default_rng(5)
    edges, assign = graph(rng, n=80, e=240)
    x = rng.standard_normal((80, 16)).astype(np.float32)
    np.save(tmp_path / "edges.npy", edges)
    np.save(tmp_path / "assign.npy", assign)
    np.save(tmp_path / "x.npy", x)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    ref = subprocess.run([sys.executable, "-c", _REF, str(tmp_path)],
                         capture_output=True, text=True, timeout=420,
                         env=env)
    assert ref.returncode == 0, ref.stderr[-4000:]
    port = _free_port()
    procs = [subprocess.Popen(
        [sys.executable, "-c", _WORKER, str(nproc), str(pid), port,
         str(tmp_path)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env) for pid in range(nproc)]
    outs = [p.communicate(timeout=420)[0] for p in procs]
    for p, out in zip(procs, outs):
        assert p.returncode == 0, out[-4000:]
    assert "BITWISE {'dense': 1, 'sparse': 1, 'fused': 1}" in outs[0], \
        outs[0][-2000:]


@pytest.mark.slow
def test_serve_multihost_launcher_parity_and_halo_gate(tmp_path):
    """The CLI end to end at 2 simulated hosts: bitwise parity against
    its own 1-host reference and halo bytes strictly below the
    replicate-everything baseline."""
    import json
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    ref = str(tmp_path / "ref.npy")
    base = [sys.executable, "-m", "repro.launch.serve_multihost",
            "--quick", "--devices", "4", "--steps", "2",
            "--vertices", "4000", "--edges", "12000"]
    one = subprocess.run(
        base + ["--processes", "1", "--ref-out", ref,
                "--json-out", str(tmp_path / "one.json")],
        capture_output=True, text=True, timeout=420, env=env)
    assert one.returncode == 0, one.stdout + one.stderr
    two = subprocess.run(
        base + ["--processes", "2", "--ref-in", ref,
                "--json-out", str(tmp_path / "two.json")],
        capture_output=True, text=True, timeout=420, env=env)
    assert two.returncode == 0, two.stdout + two.stderr
    rec = json.loads((tmp_path / "two.json").read_text())
    assert rec["parity_max_err"] == 0.0
    assert rec["halo_bytes_per_step"] < rec["replicate_bytes_per_step"]
