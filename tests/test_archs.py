"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED variant (≤2 layers, d_model ≤ 512, ≤4 experts)
and runs one forward + one train step on CPU — shapes + no NaNs — plus
decode == teacher-forced forward equivalence for one arch per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, list_archs
from repro.models import transformer as T
from repro.models.config import reduced
from repro.optim.adamw import AdamWConfig

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    if cfg.num_prefix_tokens and cfg.prefix_dim:
        batch["prefix_emb"] = 0.02 * jax.random.normal(
            KEY, (b, cfg.num_prefix_tokens, cfg.prefix_dim))
    if cfg.encoder_stages:
        batch["frames"] = 0.02 * jax.random.normal(
            KEY, (b, cfg.encoder_seq_len, cfg.prefix_dim))
    return batch


def test_registry_complete():
    assert len(list_archs()) == 10
    types = {get_config(a).arch_type for a in ARCHS}
    assert types == {"dense", "moe", "hybrid", "ssm", "vlm", "audio"}


def test_full_configs_match_assignment():
    cfg = get_config("gemma2-9b")
    assert cfg.num_layers == 42 and cfg.d_model == 3584
    assert cfg.attn_logit_softcap == 50.0
    cfg = get_config("deepseek-v2-lite-16b")
    assert cfg.kv_lora_rank == 512 and cfg.num_experts == 64
    assert cfg.num_experts_per_tok == 6
    cfg = get_config("zamba2-2.7b")
    assert cfg.num_layers == 54 and cfg.ssm_state == 64
    cfg = get_config("mixtral-8x7b")
    assert cfg.num_experts == 8 and cfg.num_experts_per_tok == 2
    cfg = get_config("rwkv6-7b")
    assert cfg.d_model == 4096 and cfg.vocab_size == 65536
    cfg = get_config("internvl2-26b")
    assert cfg.num_heads == 48 and cfg.d_ff == 16384


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_forward_and_train(arch):
    cfg = reduced(get_config(arch))
    assert cfg.d_model <= 512 and cfg.num_layers <= 2
    assert cfg.num_experts <= 4
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    logits, aux = T.forward(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    step = jax.jit(T.make_train_step(cfg, AdamWConfig(lr=1e-3)))
    params2, opt2, m = step(params, T.init_opt(params), batch)
    assert np.isfinite(float(m["loss"]))
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree_util.tree_map(lambda a, b: jnp.mean(a - b),
                               params, params2), 0.0)
    assert delta != 0.0                      # the step actually trained


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-v2-lite-16b",
                                  "zamba2-2.7b", "rwkv6-7b",
                                  "mixtral-8x7b", "gemma2-9b"])
def test_decode_matches_teacher_forcing(arch):
    cfg = reduced(get_config(arch))
    params = T.init_params(cfg, KEY)
    b, s = 2, 10
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    logits_tf, _ = T.forward(cfg, params, {"tokens": toks, "targets": toks})
    cache = T.init_cache(cfg, b, max_len=s, dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                  jnp.int32(t))
        outs.append(lg)
    err = float(jnp.max(jnp.abs(logits_tf - jnp.concatenate(outs, 1))))
    assert err < 1e-3, err


def test_sliding_window_decode_ring_buffer():
    """A windowed arch decodes correctly past the window boundary."""
    import dataclasses
    cfg = reduced(get_config("h2o-danube-1.8b"))
    spec = cfg.stages[0].unit[0]
    window = 4
    cfg = dataclasses.replace(
        cfg, stages=(dataclasses.replace(
            cfg.stages[0],
            unit=(dataclasses.replace(spec, window=window),)),))
    params = T.init_params(cfg, KEY)
    b, s = 1, 12
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    logits_tf, _ = T.forward(cfg, params, {"tokens": toks, "targets": toks})
    cache = T.init_cache(cfg, b, max_len=s, dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                  jnp.int32(t))
        outs.append(lg)
    err = float(jnp.max(jnp.abs(logits_tf - jnp.concatenate(outs, 1))))
    assert err < 1e-3, err


def test_param_counts_close_to_reference():
    """Sanity: full-config param counts are in the right ballpark."""
    expected = {"mixtral-8x7b": 46.7e9, "deepseek-v2-lite-16b": 15.7e9,
                "gemma2-9b": 10.2e9, "h2o-danube-1.8b": 1.8e9}
    for arch, n in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.15, (arch, got)
