"""Sparse edge-list fast path: plan-builder parity vs the dense oracle,
gather-aggregate parity vs the dense kernel, layer auto-dispatch, the
edge-list partition-cache key, and a 5k-vertex serve round-trip."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.api import topology_key
from repro.core.dynamic_graph import make_graph_state
from repro.gnn.distributed import (make_partition_plan,
                                   make_partition_plan_dense_reference,
                                   make_partition_plan_sparse)
from repro.kernels.gnn_aggregate.ops import (dense_to_padded_neighbors,
                                             gather_aggregate,
                                             normalized_aggregate,
                                             padded_neighbors_from_coo)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _random_layout(seed: int, n: int, p: int):
    """Random symmetric 0/1 adjacency + assignment with inactive slots."""
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < rng.uniform(0.02, 0.3)).astype(np.float32)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 0)
    assign = rng.integers(0, p, n).astype(np.int64)
    assign[rng.random(n) < 0.2] = -1
    adj *= (assign >= 0)[:, None] * (assign >= 0)[None, :]
    return adj, assign


# --- plan parity ------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(4, 80), st.integers(2, 6), st.integers(0, 99999))
def test_sparse_plan_matches_dense_oracle(n, p, seed):
    """make_partition_plan_sparse == the original triple-loop builder on
    every field: perm, halo layout, send schedule, adjacency semantics."""
    adj, assign = _random_layout(seed, n, p)
    ref = make_partition_plan_dense_reference(adj, assign, p)
    i, j = np.nonzero(np.triu(adj, 1))
    sp = make_partition_plan_sparse(np.stack([i, j], 1), assign, p, n=n)
    wrapped = make_partition_plan(adj, assign, p)
    for plan in (sp, wrapped):
        assert (plan.block, plan.halo, plan.n) == (ref.block, ref.halo, n)
        np.testing.assert_array_equal(plan.perm, ref.perm)
        np.testing.assert_array_equal(plan.send_idx, ref.send_idx)
        np.testing.assert_array_equal(plan.send_mask, ref.send_mask)
        np.testing.assert_array_equal(plan.mask, ref.mask)
        np.testing.assert_allclose(plan.dense_adj_ext(), ref.adj_ext)


def test_sparse_plan_weighted_edges(rng):
    """Edge weights flow into adj_ext exactly as dense matrix entries do."""
    n, p = 30, 3
    adj = np.triu((rng.random((n, n)) < 0.2) * rng.integers(1, 9, (n, n)),
                  1).astype(np.float32)
    adj = adj + adj.T
    assign = rng.integers(0, p, n).astype(np.int64)
    ref = make_partition_plan_dense_reference(adj, assign, p)
    i, j = np.nonzero(np.triu(adj, 1))
    sp = make_partition_plan_sparse(np.stack([i, j], 1), assign, p, n=n,
                                    weights=adj[i, j])
    np.testing.assert_allclose(sp.dense_adj_ext(), ref.adj_ext)


def test_gather_handles_inactive_max_vertex(rng):
    """Satellite fix: scatter→gather round-trips to the stored n even when
    the highest-id vertices are inactive (perm.max()+1 would be wrong)."""
    n, p = 12, 2
    assign = np.array([0, 1, 0, 1, 0, 1, 0, 1, -1, -1, -1, -1], np.int64)
    edges = np.array([[0, 2], [1, 3], [4, 6], [0, 1]], np.int64)
    plan = make_partition_plan_sparse(edges, assign, p, n=n)
    assert plan.n == n
    x = rng.normal(size=(n, 5)).astype(np.float32)
    out = plan.gather(plan.scatter(x))
    assert out.shape == (n, 5)
    active = assign >= 0
    np.testing.assert_array_equal(out[active], x[active])
    assert np.all(out[~active] == 0)


# --- sparse aggregate parity ------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(2, 120), st.integers(1, 70), st.integers(0, 9999))
def test_gather_aggregate_matches_dense_oracle(n, f, seed):
    rng = np.random.default_rng(seed)
    adj = ((rng.random((n, n)) < 0.15) * rng.random((n, n))).astype(
        np.float32)
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    rs = rng.random(n).astype(np.float32)
    cs = rng.random(n).astype(np.float32)
    ref = normalized_aggregate(jnp.asarray(adj), x, rs, cs, impl="xla")
    idx, val = dense_to_padded_neighbors(adj)
    for impl in ("xla", "interpret"):
        got = gather_aggregate(idx, val, x, rs, cs, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_padded_neighbors_roundtrip(rng):
    """COO → padded lists → dense reconstruction is exact (duplicates sum)."""
    n = 17
    src = rng.integers(0, n, 40)
    dst = rng.integers(0, n, 40)
    val = rng.random(40).astype(np.float32)
    idx, nv = padded_neighbors_from_coo(src, dst, val, n)
    dense = np.zeros((n, n), np.float32)
    np.add.at(dense, (src, dst), val)
    recon = np.zeros((n, n), np.float32)
    rows = np.repeat(np.arange(n), idx.shape[1])
    np.add.at(recon, (rows, idx.ravel()), nv.ravel())
    np.testing.assert_allclose(recon, dense, rtol=1e-6, atol=1e-6)


def test_layers_auto_sparse_matches_closed_form():
    """gcn_apply takes the gather path at ≥256 vertices / low density and
    still equals the closed-form dense propagation."""
    from repro.gnn.layers import (gcn_apply, gcn_init, gcn_norm,
                                  maybe_padded_neighbors)
    rng = np.random.default_rng(3)
    n = 300
    adj = (rng.random((n, n)) < 0.01).astype(np.float32)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 0)
    x = jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32))
    mask = jnp.ones(n)
    a_hat, dinv = gcn_norm(jnp.asarray(adj), mask)
    assert maybe_padded_neighbors(a_hat) is not None
    params = gcn_init(jax.random.PRNGKey(0), [16, 8, 4])
    out = gcn_apply(params, x, jnp.asarray(adj), mask)
    a_norm = dinv[:, None] * a_hat * dinv[None, :]
    expect = a_norm @ jax.nn.relu(a_norm @ x @ params[0]["w"]) @ \
        params[1]["w"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


# --- control plane ----------------------------------------------------------

def test_topology_key_ignores_positions(rng):
    """The partition cache key hashes (capacity, mask, edge list): mobility
    leaves it unchanged, topology edits do not."""
    edges = [[0, 1], [1, 2], [2, 3]]
    pos = rng.random((5, 2)) * 100
    a = make_graph_state(8, pos, edges, np.ones(5))
    b = make_graph_state(8, rng.random((5, 2)) * 100, edges, np.ones(5))
    c = make_graph_state(8, pos, [[0, 1], [1, 2], [3, 4]], np.ones(5))
    assert topology_key(a) == topology_key(b)
    assert topology_key(a) != topology_key(c)


def test_decision_plan_is_sparse_built(rng):
    """Decision.to_partition_plan goes through the O(E) path (no dense
    blocks attached) and still serves the correct vertex set."""
    from repro.core import costs
    from repro.core.api import GraphEdgeController
    from repro.core.dynamic_graph import random_scenario
    state = random_scenario(rng, 24, 16, 40)
    net = costs.default_network(rng, 24, 4)
    dec = GraphEdgeController(net=net).step(state)
    plan = dec.to_partition_plan(4)
    assert plan.adj_ext is None          # sparse-first, densified on demand
    assert plan.n == state.capacity
    np.testing.assert_allclose(plan.dense_adj_ext().sum(),
                               np.asarray(state.adj).sum())


# --- end-to-end serve round-trip -------------------------------------------

@pytest.mark.slow
def test_sparse_serve_roundtrip_5k():
    """5000-vertex serve through the sparse plan + gather aggregation vs
    the closed-form dense GCN (independent of the kernels under test)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.hicut import hicut_ref
        from repro.data.graphs import random_graph
        from repro.gnn.distributed import (distributed_gcn_forward,
                                           make_partition_plan_sparse)
        from repro.gnn.layers import gcn_init
        n = 5000
        g = random_graph(n, 50_000, seed=0, feature_dim=24)
        assign = hicut_ref(n, g.edges) % 4
        plan = make_partition_plan_sparse(g.edges, assign, 4, n=n)
        assert plan.adj_ext is None
        params = gcn_init(jax.random.PRNGKey(0), [24, 16, 5])
        x = g.features
        mesh = Mesh(np.array(jax.devices()), ("servers",))
        out = distributed_gcn_forward(mesh, "servers", plan, params, x)
        # closed-form dense oracle (no kernel reuse)
        a_hat = jnp.asarray(g.adjacency() + np.eye(n, dtype=np.float32))
        dinv = 1.0 / jnp.sqrt(a_hat.sum(1))
        a_norm = dinv[:, None] * a_hat * dinv[None, :]
        expect = a_norm @ jax.nn.relu(
            a_norm @ jnp.asarray(x) @ params[0]["w"]) @ params[1]["w"]
        print("ERR", float(np.abs(out - np.asarray(expect)).max()))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    assert float(out.stdout.split("ERR")[1]) < 1e-3
