"""Cost model (Eqs. 3–14): hand-checked values + invariants + the
incremental env cost vs the batch model."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import costs
from repro.core.dynamic_graph import make_graph_state, random_scenario


def tiny_setup(n_users=4, m=2, seed=0):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, n_users, n_users, 3, plane=1000.0)
    net = costs.default_network(rng, n_users, m, plane=1000.0)
    return rng, state, net


def test_uplink_rate_formula():
    rng, state, net = tiny_setup()
    r = np.asarray(costs.uplink_rate(net, state))
    h = np.asarray(costs.channel_gain(net, state))
    # Eq. (3) recomputed by hand for one (i, m)
    i, m = 1, 0
    expect = float(net.B_im[i, m]) * np.log2(
        1 + float(net.P_i[i]) * h[i, m] / net.sigma2)
    assert np.isclose(r[i, m], expect, rtol=1e-5)
    assert (r > 0).all()


def test_upload_cost_scales_with_data():
    rng, state, net = tiny_setup()
    w = costs.assignment_onehot(jnp.zeros(4, jnp.int32), 2)
    t1, e1 = costs.upload_costs(net, state, w)
    state2 = state._replace(task_kb=state.task_kb * 2)
    t2, e2 = costs.upload_costs(net, state2, w)
    np.testing.assert_allclose(np.asarray(t2), 2 * np.asarray(t1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(e2), 2 * np.asarray(e1), rtol=1e-5)


def test_cross_server_bits_zero_when_colocated():
    """Co-locating every user removes all cross-server traffic (Eq. 8→0)."""
    rng, state, net = tiny_setup()
    w = costs.assignment_onehot(jnp.zeros(4, jnp.int32), 2)
    x = costs.cross_server_bits(state, w)
    assert float(jnp.sum(x)) == 0.0


def test_cross_server_bits_hand_value():
    # two users, one edge, on different servers
    state = make_graph_state(2, [[0, 0], [10, 10]], [(0, 1)], [100.0, 200.0])
    rng = np.random.default_rng(0)
    net = costs.default_network(rng, 2, 2)
    w = costs.assignment_onehot(jnp.asarray([0, 1]), 2)
    x = np.asarray(costs.cross_server_bits(state, w))
    # x_{0→1} = X_0 (user0 on sv0 has neighbor on sv1), x_{1→0} = X_1
    assert np.isclose(x[0, 1], 100e3)
    assert np.isclose(x[1, 0], 200e3)


def test_system_cost_prefers_colocated_neighbors():
    state = make_graph_state(4, [[0, 0], [1, 1], [999, 999], [998, 998]],
                             [(0, 1), (2, 3)], [1000.0] * 4)
    rng = np.random.default_rng(1)
    net = costs.default_network(rng, 4, 2)
    together = costs.assignment_onehot(jnp.asarray([0, 0, 1, 1]), 2)
    split = costs.assignment_onehot(jnp.asarray([0, 1, 0, 1]), 2)
    c_tog = costs.system_cost(net, state, together)
    c_spl = costs.system_cost(net, state, split)
    assert float(c_tog.c) < float(c_spl.c)
    assert float(c_tog.cross_bits.sum()) == 0.0


def test_masked_users_cost_nothing():
    rng, state, net = tiny_setup()
    dead = state._replace(mask=jnp.zeros_like(state.mask))
    w = costs.assignment_onehot(jnp.zeros(4, jnp.int32), 2)
    sc = costs.system_cost(net, dead, w)
    assert float(sc.t_all) == 0.0
    assert float(sc.i_all) == 0.0


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(0, 40), st.integers(0, 9999))
def test_costs_nonnegative_and_finite(n, e, seed):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, n, n, e)
    net = costs.default_network(rng, n, 4)
    assign = rng.integers(0, 4, n)
    sc = costs.system_cost(net, state,
                           costs.assignment_onehot(jnp.asarray(assign), 4))
    for v in (sc.c, sc.t_all, sc.i_all, sc.i_gnn):
        assert np.isfinite(float(v)) and float(v) >= 0.0


def test_env_marginal_cost_matches_batch_model():
    """Σ marginal costs over an episode == the Eqs. (12)–(13) batch totals
    for the assignment-dependent terms."""
    from repro.core.offload.env import OffloadEnv
    rng = np.random.default_rng(2)
    state = random_scenario(rng, 12, 10, 20)
    net = costs.default_network(rng, 12, 3)
    env = OffloadEnv(net, state, np.arange(12), use_subgraph_reward=False,
                     cost_scale=1.0)
    env.reset()
    total_marginal = 0.0
    while env.t < env.num_steps:
        i = env.current_user()
        k = int(rng.integers(3))
        total_marginal += env.marginal_cost(i, k)
        acts = np.zeros((3, 2), np.float32)
        acts[:, 1] = 1.0
        acts[k, 0] = 2.0
        env.step(acts)
    sc = env.final_cost()
    batch_total = float(jnp.sum(sc.t_up) + jnp.sum(sc.i_up)
                        + jnp.sum(sc.t_com) + sc.i_gnn
                        + jnp.sum(sc.i_com)
                        # marginal counts (X_i+X_j)/R per new cross pair once;
                        # batch T_tran counts x̃/R once per (k,l) — same total
                        + jnp.sum(sc.t_tran) / 2.0)
    assert np.isclose(total_marginal, batch_total, rtol=0.05), \
        (total_marginal, batch_total)
