"""Batched vmapped OffloadEnv (repro.core.offload.batched_env).

Parity pins: with B = 1 the batched env must reproduce the legacy numpy
``OffloadEnv`` trajectory (same seeds/actions → same server choices and
assignment exactly, same rewards/observations to f32 tolerance). With
B > 1, vmap must not couple episodes — each evolves exactly as it does
alone — and steps past ``num_steps`` must be masked no-ops.
"""
import jax
import numpy as np
import pytest

from repro.core import costs
from repro.core.dynamic_graph import random_scenario
from repro.core.offload.batched_env import BatchedOffloadEnv
from repro.core.offload.drlgo import hicut_partition
from repro.core.offload.env import ACT_DIM, OBS_DIM, OffloadEnv


def make_pair(seed=0, n=12, users=None, m=3, e=18, **kw):
    """(numpy env, B=1 batched env) over the same scenario/net/partition."""
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, n, users or n, e)
    net = costs.default_network(rng, n, m)
    env = OffloadEnv(net, state, hicut_partition(state), **kw)
    return env, env.as_batched()


def rollout_actions(env, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.random((env.m, ACT_DIM)).astype(np.float32)
            for _ in range(env.num_steps)]


def test_b1_parity_with_legacy_numpy_env():
    env, benv = make_pair(zeta_sp=0.3, cost_scale=2.0)
    obs_n, s_n = env.reset()
    es, obs_b, s_b = benv.reset()
    np.testing.assert_allclose(np.asarray(obs_b)[0], obs_n,
                               rtol=1e-4, atol=1e-6)
    assert s_b.shape == (1, env.m * OBS_DIM)
    for acts in rollout_actions(env):
        obs_n, _, rew_n, done_n, k_n = env.step(acts)
        es, obs_b, _, rew_b, done_b, k_b = benv.step(es, acts[None])
        assert int(k_b[0]) == k_n                      # same server choice
        assert bool(done_b[0]) == done_n
        np.testing.assert_allclose(np.asarray(rew_b)[0], rew_n,
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(np.asarray(obs_b)[0], obs_n,
                                   rtol=1e-4, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(es.assign)[0], env.assign)
    fin_n, fin_b = env.final_cost(), benv.final_costs(es)
    np.testing.assert_allclose(float(fin_b.c[0]), float(fin_n.c), rtol=1e-5)
    np.testing.assert_allclose(float(fin_b.t_all[0]), float(fin_n.t_all),
                               rtol=1e-5)
    np.testing.assert_allclose(float(fin_b.i_all[0]), float(fin_n.i_all),
                               rtol=1e-5)


def test_b1_parity_drl_only_ablation():
    env, benv = make_pair(seed=3, use_subgraph_reward=False)
    env.reset()
    es, _, _ = benv.reset()
    for acts in rollout_actions(env, seed=4):
        _, _, rew_n, _, k_n = env.step(acts)
        es, _, _, rew_b, _, k_b = benv.step(es, acts[None])
        assert int(k_b[0]) == k_n
        np.testing.assert_allclose(np.asarray(rew_b)[0], rew_n,
                                   rtol=1e-4, atol=1e-6)


def test_vmapped_episodes_evolve_independently():
    rng = np.random.default_rng(7)
    n, m = 14, 3
    scenarios = [random_scenario(rng, n, u, 20) for u in (9, 12, 14)]
    net = costs.default_network(rng, n, m)
    parts = [hicut_partition(s) for s in scenarios]
    benv = BatchedOffloadEnv.from_scenarios(net, scenarios, parts,
                                            zeta_sp=0.2)
    singles = [BatchedOffloadEnv.from_scenarios(net, [s], [p], zeta_sp=0.2)
               for s, p in zip(scenarios, parts)]
    es, obs, _ = benv.reset()
    states1 = [e.reset() for e in singles]
    arng = np.random.default_rng(8)
    for _ in range(n):                       # full padded range
        acts = arng.random((3, m, ACT_DIM)).astype(np.float32)
        es, obs, _, rew, done, k = benv.step(es, acts)
        for b, single in enumerate(singles):
            es1, obs1, _, rew1, done1, k1 = single.step(states1[b][0],
                                                        acts[b:b + 1])
            states1[b] = (es1, obs1, None)
            assert int(k[b]) == int(k1[0])
            np.testing.assert_allclose(np.asarray(rew[b]),
                                       np.asarray(rew1[0]),
                                       rtol=1e-6, atol=1e-7)
            np.testing.assert_allclose(np.asarray(obs[b]),
                                       np.asarray(obs1[0]),
                                       rtol=1e-6, atol=1e-7)
    for b, single in enumerate(singles):
        np.testing.assert_array_equal(np.asarray(es.assign)[b],
                                      np.asarray(states1[b][0].assign)[0])


def test_padded_steps_are_masked_noops():
    env, benv = make_pair(n=16, users=9, e=12)
    assert benv.num_steps[0] == 9
    es, _, _ = benv.reset()
    arng = np.random.default_rng(2)
    rewards = []
    snap = None
    for t in range(16):                      # capacity > active users
        acts = arng.random((1, env.m, ACT_DIM)).astype(np.float32)
        es, _, _, rew, done, _ = benv.step(es, acts)
        rewards.append(float(np.asarray(rew).sum()))
        if t == 8:                           # last valid step just ran
            snap = (np.asarray(es.assign)[0].copy(),
                    np.asarray(es.load)[0].copy())
        if t >= 8:
            assert bool(done[0])
    assert all(r == 0.0 for r in rewards[9:])          # padding: zero reward
    np.testing.assert_array_equal(np.asarray(es.assign)[0], snap[0])
    np.testing.assert_array_equal(np.asarray(es.load)[0], snap[1])
    active = np.asarray(env.state.mask) > 0
    assert (snap[0][active] >= 0).all()                # C1 still holds
    assert (snap[0][~active] == -1).all()
    assert snap[1].sum() == active.sum()


def test_trainer_batched_matches_history_contract():
    from repro.core.offload.drlgo import DRLGOTrainer, DRLGOTrainerConfig
    cfg = DRLGOTrainerConfig(capacity=16, n_users=10, n_assoc=20,
                             episodes=6, batch_envs=3,
                             warmup_steps=10_000)    # rollout-only, fast
    tr = DRLGOTrainer(cfg)
    hist = tr.train()
    assert len(hist) == 6
    assert [h["episode"] for h in hist] == list(range(6))
    assert all(np.isfinite(h["system_cost"]) and np.isfinite(h["reward"])
               for h in hist)
    # only valid transitions reach the replay buffer
    assert len(tr.buffer) <= 2 * 3 * 16
    assert len(tr.buffer) > 0


def test_trainer_batched_updates_move_params():
    import jax.numpy as jnp
    from repro.core.offload.drlgo import DRLGOTrainer, DRLGOTrainerConfig
    from repro.core.offload.maddpg import (MADDPGConfig, ReplayBuffer,
                                           init_maddpg)
    cfg = DRLGOTrainerConfig(capacity=12, n_users=8, n_assoc=14, episodes=4,
                             batch_envs=2, warmup_steps=8)
    tr = DRLGOTrainer(cfg)
    # shrink the MADDPG batch so updates engage within a tiny test budget
    tr.mcfg = MADDPGConfig(n_agents=cfg.n_servers, obs_dim=OBS_DIM,
                           act_dim=ACT_DIM, batch_size=8)
    tr.state = init_maddpg(tr.mcfg, jax.random.PRNGKey(1))
    tr.buffer = ReplayBuffer(tr.mcfg, seed=1)
    before = jax.tree_util.tree_map(jnp.copy, tr.state.actor)
    hist = tr.train()
    assert any("critic_0" in h for h in hist)
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree_util.tree_map(lambda a, b: a - b, before, tr.state.actor),
        0.0)
    assert delta > 0


def test_ptom_batched_smoke():
    from repro.core.offload.drlgo import DRLGOTrainer, DRLGOTrainerConfig
    from repro.core.offload.ppo import PPOConfig, PTOMAgent
    cfg = DRLGOTrainerConfig(capacity=12, n_users=8, n_assoc=14,
                             batch_envs=2)
    tr = DRLGOTrainer(cfg)
    benv = tr.make_batched_env([tr.scenario] * 2)
    agent = PTOMAgent(PPOConfig(state_dim=cfg.n_servers * OBS_DIM,
                                n_actions=cfg.n_servers))
    out = agent.run_batch(benv)
    assert len(out) == 2
    assert all(np.isfinite(o["system_cost"]) for o in out)
    # identical scenarios + deterministic rollout → identical episodes
    det = agent.run_batch(benv, learn=False, explore=False)
    assert det[0]["reward"] == pytest.approx(det[1]["reward"])


def test_replay_buffer_add_batch_wraps():
    from repro.core.offload.maddpg import MADDPGConfig, ReplayBuffer
    cfg = MADDPGConfig(n_agents=2, obs_dim=3, buffer_size=8)
    buf = ReplayBuffer(cfg)
    k = 5
    mk = lambda i: (np.full((k, 2, 3), i, np.float32), np.zeros((k, 6)),
                    np.zeros((k, 2, 2)), np.zeros((k, 2)),
                    np.zeros((k, 2, 3)), np.zeros((k, 6)), np.zeros(k))
    buf.add_batch(*mk(1))
    assert len(buf) == 5 and not buf.full
    buf.add_batch(*mk(2))                     # wraps: 10 adds into size 8
    assert len(buf) == 8 and buf.full
    assert buf.obs[0, 0, 0] == 2 and buf.obs[1, 0, 0] == 2   # wrapped
    assert buf.obs[4, 0, 0] == 1 and buf.obs[5, 0, 0] == 2
    assert buf.ptr == 2
