"""Dynamic graph model (§3.2): mask module + position attribute semantics,
plus the property suite over churn (``perturb_scenario`` /
``add_users`` / ``remove_users`` / the fault-event waves): adjacency stays
symmetric with a zero diagonal, inactive rows/columns carry no edges, and
``num_active`` always equals the mask population."""
import jax.numpy as jnp
import numpy as np

from _hyp import given, settings, st
from repro.core.dynamic_graph import (EVENT_ARRIVE, EVENT_DEPART, GraphEvent,
                                      GraphState, add_users, apply_user_event,
                                      arrival_wave, departure_wave,
                                      make_graph_state, move_users,
                                      perturb_scenario, random_scenario,
                                      remove_users, rewire)


def test_make_graph_state_masks_and_pads():
    st = make_graph_state(8, [[0, 0], [1, 1], [2, 2]], [(0, 1), (1, 2)],
                          [10, 20, 30])
    assert float(st.num_active()) == 3
    assert st.adj.shape == (8, 8)
    assert float(st.adj[0, 1]) == 1.0 and float(st.adj[1, 0]) == 1.0
    assert float(st.task_kb[3]) == 0.0           # padded slot empty


def test_remove_users_drops_edges():
    st = make_graph_state(4, np.zeros((4, 2)), [(0, 1), (1, 2), (2, 3)],
                          [1, 1, 1, 1])
    st2 = remove_users(st, jnp.asarray([0.0, 1.0, 0.0, 0.0]))
    assert float(st2.num_active()) == 3
    assert float(st2.adj[0, 1]) == 0.0 and float(st2.adj[1, 2]) == 0.0
    assert float(st2.adj[2, 3]) == 1.0           # untouched edge survives


def test_add_users_reuses_masked_slots():
    st = make_graph_state(4, np.zeros((3, 2)), [(0, 1)], [1, 1, 1], active=3)
    st = remove_users(st, jnp.asarray([0.0, 1.0, 0.0, 0.0]))
    adj_new = np.zeros((4, 4), np.float32)
    adj_new[1, 2] = adj_new[2, 1] = 1.0
    st2 = add_users(st, jnp.asarray([0.0, 1.0, 0.0, 0.0]),
                    jnp.asarray(np.full((4, 2), 7.0, np.float32)),
                    jnp.asarray(np.full(4, 42.0, np.float32)),
                    jnp.asarray(adj_new))
    assert float(st2.num_active()) == 3
    assert float(st2.task_kb[1]) == 42.0
    assert float(st2.pos[1, 0]) == 7.0
    assert float(st2.adj[1, 2]) == 1.0


def test_add_cannot_clobber_active_slot():
    st = make_graph_state(3, np.zeros((3, 2)), [], [1, 2, 3])
    st2 = add_users(st, jnp.ones(3), jnp.asarray(np.full((3, 2), 9.0,
                                                         np.float32)),
                    jnp.asarray(np.full(3, 99.0, np.float32)),
                    st.adj)
    np.testing.assert_allclose(np.asarray(st2.task_kb),
                               np.asarray(st.task_kb))


def test_move_users_only_moves_active():
    st = make_graph_state(3, np.zeros((2, 2)), [], [1, 1], active=2)
    newp = jnp.asarray(np.full((3, 2), 5.0, np.float32))
    st2 = move_users(st, newp)
    assert float(st2.pos[0, 0]) == 5.0
    assert float(st2.pos[2, 0]) == 0.0           # masked slot unchanged


def test_rewire_symmetrizes_and_masks():
    st = make_graph_state(4, np.zeros((3, 2)), [], [1, 1, 1], active=3)
    adj = np.zeros((4, 4), np.float32)
    adj[0, 1] = 1.0           # one-directional input
    adj[2, 3] = 1.0           # touches masked vertex 3
    st2 = rewire(st, jnp.asarray(adj))
    assert float(st2.adj[1, 0]) == 1.0
    assert float(st2.adj[2, 3]) == 0.0
    assert float(jnp.diagonal(st2.adj).sum()) == 0.0


def test_perturb_keeps_invariants(rng):
    st = random_scenario(rng, 24, 18, 40)
    for _ in range(5):
        st = perturb_scenario(rng, st, 0.3)
        adj = np.asarray(st.adj)
        mask = np.asarray(st.mask)
        np.testing.assert_allclose(adj, adj.T)
        assert np.all(np.diagonal(adj) == 0)
        # no edges incident to masked vertices
        assert np.all(adj[mask == 0] == 0)
        assert np.all(adj[:, mask == 0] == 0)


# -- property suite: every churn path preserves the layout invariants --------

def _assert_layout_invariants(state: GraphState) -> None:
    """The §3.2 contract every mutation must preserve: symmetric adjacency,
    zero diagonal, no edges or task bits on inactive slots, binary mask,
    and ``num_active`` equal to the mask population."""
    adj = np.asarray(state.adj)
    mask = np.asarray(state.mask)
    np.testing.assert_array_equal(adj, adj.T)
    assert np.all(np.diagonal(adj) == 0)
    assert np.all(adj[mask == 0] == 0)
    assert np.all(adj[:, mask == 0] == 0)
    assert np.all((mask == 0) | (mask == 1))
    assert np.all(np.asarray(state.task_kb)[mask == 0] == 0)
    assert float(state.num_active()) == mask.sum()


@settings(max_examples=10)
@given(st.integers(0, 2**31 - 1), st.sampled_from([0.1, 0.3, 0.6]))
def test_property_perturb_preserves_invariants(seed, rate):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, 20, 14, 30)
    for _ in range(3):
        state = perturb_scenario(rng, state, rate)
        _assert_layout_invariants(state)


@settings(max_examples=10)
@given(st.integers(0, 2**31 - 1), st.integers(1, 12))
def test_property_arrival_wave_counts_and_invariants(seed, count):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, 16, 9, 20)
    before = int(np.asarray(state.mask).sum())
    grown = arrival_wave(rng, state, count)
    _assert_layout_invariants(grown)
    want = before + min(count, state.capacity - before)
    assert int(np.asarray(grown.mask).sum()) == want
    # arrivals only ever activate — nobody already active is touched
    assert np.all(np.asarray(grown.mask) >= np.asarray(state.mask))


@settings(max_examples=10)
@given(st.integers(0, 2**31 - 1), st.integers(1, 12))
def test_property_departure_wave_counts_and_invariants(seed, count):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, 16, 9, 20)
    before = int(np.asarray(state.mask).sum())
    shrunk = departure_wave(rng, state, count)
    _assert_layout_invariants(shrunk)
    assert int(np.asarray(shrunk.mask).sum()) == before - min(count, before)
    assert np.all(np.asarray(shrunk.mask) <= np.asarray(state.mask))


@settings(max_examples=10)
@given(st.integers(0, 2**31 - 1), st.integers(0, 2**16))
def test_property_add_users_arbitrary_adjacency(seed, adj_seed):
    """``add_users`` must sanitize an *arbitrary* (asymmetric, self-looped,
    mask-violating) proposed adjacency into a legal layout."""
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, 12, 6, 12)
    mask = np.asarray(state.mask)
    add = ((np.random.default_rng(adj_seed).random(12) < 0.5)
           & (mask == 0)).astype(np.float32)
    raw = (np.random.default_rng(adj_seed + 1)
           .random((12, 12)) < 0.4).astype(np.float32)   # deliberately dirty
    grown = add_users(state, jnp.asarray(add),
                      jnp.asarray(rng.uniform(0, 100, (12, 2))
                                  .astype(np.float32)),
                      jnp.asarray(rng.uniform(1, 9, 12).astype(np.float32)),
                      jnp.asarray(raw))
    _assert_layout_invariants(grown)
    assert int(np.asarray(grown.mask).sum()) == int(mask.sum() + add.sum())


@settings(max_examples=10)
@given(st.integers(0, 2**31 - 1), st.integers(0, 2**16))
def test_property_remove_users_subset(seed, drop_seed):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, 12, 8, 16)
    drop = (np.random.default_rng(drop_seed).random(12) < 0.4) \
        .astype(np.float32)
    shrunk = remove_users(state, jnp.asarray(drop))
    _assert_layout_invariants(shrunk)
    gone = (np.asarray(state.mask) > 0) & (drop > 0)
    assert int(np.asarray(shrunk.mask).sum()) == \
        int(np.asarray(state.mask).sum()) - int(gone.sum())


@settings(max_examples=10)
@given(st.integers(0, 2**31 - 1), st.sampled_from([EVENT_ARRIVE,
                                                   EVENT_DEPART]),
       st.integers(1, 6))
def test_property_apply_user_event_matches_wave(seed, kind, count):
    """The event dispatcher is exactly the wave helpers (same rng stream ⇒
    bitwise-identical states) — the fault injector's determinism rests on
    this."""
    state = random_scenario(np.random.default_rng(seed), 14, 8, 18)
    via_event = apply_user_event(np.random.default_rng(seed + 1), state,
                                 GraphEvent(0, kind, count=count))
    wave = arrival_wave if kind == EVENT_ARRIVE else departure_wave
    direct = wave(np.random.default_rng(seed + 1), state, count)
    for a, b in zip(via_event, direct):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _assert_layout_invariants(via_event)
