"""Dynamic graph model (§3.2): mask module + position attribute semantics."""
import jax.numpy as jnp
import numpy as np

from repro.core.dynamic_graph import (GraphState, add_users,
                                      make_graph_state, move_users,
                                      perturb_scenario, random_scenario,
                                      remove_users, rewire)


def test_make_graph_state_masks_and_pads():
    st = make_graph_state(8, [[0, 0], [1, 1], [2, 2]], [(0, 1), (1, 2)],
                          [10, 20, 30])
    assert float(st.num_active()) == 3
    assert st.adj.shape == (8, 8)
    assert float(st.adj[0, 1]) == 1.0 and float(st.adj[1, 0]) == 1.0
    assert float(st.task_kb[3]) == 0.0           # padded slot empty


def test_remove_users_drops_edges():
    st = make_graph_state(4, np.zeros((4, 2)), [(0, 1), (1, 2), (2, 3)],
                          [1, 1, 1, 1])
    st2 = remove_users(st, jnp.asarray([0.0, 1.0, 0.0, 0.0]))
    assert float(st2.num_active()) == 3
    assert float(st2.adj[0, 1]) == 0.0 and float(st2.adj[1, 2]) == 0.0
    assert float(st2.adj[2, 3]) == 1.0           # untouched edge survives


def test_add_users_reuses_masked_slots():
    st = make_graph_state(4, np.zeros((3, 2)), [(0, 1)], [1, 1, 1], active=3)
    st = remove_users(st, jnp.asarray([0.0, 1.0, 0.0, 0.0]))
    adj_new = np.zeros((4, 4), np.float32)
    adj_new[1, 2] = adj_new[2, 1] = 1.0
    st2 = add_users(st, jnp.asarray([0.0, 1.0, 0.0, 0.0]),
                    jnp.asarray(np.full((4, 2), 7.0, np.float32)),
                    jnp.asarray(np.full(4, 42.0, np.float32)),
                    jnp.asarray(adj_new))
    assert float(st2.num_active()) == 3
    assert float(st2.task_kb[1]) == 42.0
    assert float(st2.pos[1, 0]) == 7.0
    assert float(st2.adj[1, 2]) == 1.0


def test_add_cannot_clobber_active_slot():
    st = make_graph_state(3, np.zeros((3, 2)), [], [1, 2, 3])
    st2 = add_users(st, jnp.ones(3), jnp.asarray(np.full((3, 2), 9.0,
                                                         np.float32)),
                    jnp.asarray(np.full(3, 99.0, np.float32)),
                    st.adj)
    np.testing.assert_allclose(np.asarray(st2.task_kb),
                               np.asarray(st.task_kb))


def test_move_users_only_moves_active():
    st = make_graph_state(3, np.zeros((2, 2)), [], [1, 1], active=2)
    newp = jnp.asarray(np.full((3, 2), 5.0, np.float32))
    st2 = move_users(st, newp)
    assert float(st2.pos[0, 0]) == 5.0
    assert float(st2.pos[2, 0]) == 0.0           # masked slot unchanged


def test_rewire_symmetrizes_and_masks():
    st = make_graph_state(4, np.zeros((3, 2)), [], [1, 1, 1], active=3)
    adj = np.zeros((4, 4), np.float32)
    adj[0, 1] = 1.0           # one-directional input
    adj[2, 3] = 1.0           # touches masked vertex 3
    st2 = rewire(st, jnp.asarray(adj))
    assert float(st2.adj[1, 0]) == 1.0
    assert float(st2.adj[2, 3]) == 0.0
    assert float(jnp.diagonal(st2.adj).sum()) == 0.0


def test_perturb_keeps_invariants(rng):
    st = random_scenario(rng, 24, 18, 40)
    for _ in range(5):
        st = perturb_scenario(rng, st, 0.3)
        adj = np.asarray(st.adj)
        mask = np.asarray(st.mask)
        np.testing.assert_allclose(adj, adj.T)
        assert np.all(np.diagonal(adj) == 0)
        # no edges incident to masked vertices
        assert np.all(adj[mask == 0] == 0)
        assert np.all(adj[:, mask == 0] == 0)
