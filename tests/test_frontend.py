"""Streaming front-end: bounded-queue backpressure, continuous batching
parity against the oracle, Lyapunov/static admission under simulated
overload, deadline shedding, the conservation invariant, and the SLO
telemetry plumbing (repro.serve.frontend / repro.serve.metrics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import costs
from repro.core.api import GraphEdgeController
from repro.core.dynamic_graph import perturb_scenario, random_scenario
from repro.core.offload.lyapunov import virtual_queue_update
from repro.gnn.layers import gcn_apply, gcn_init
from repro.serve import (AdmitAll, LyapunovAdmission, ManualClock,
                         RequestTiming, ServingEngine,
                         StaticPriorityAdmission, StreamRequest,
                         StreamingFrontend, poisson_workload)
from repro.serve.frontend import (REJECT_ADMISSION, REJECT_DEADLINE,
                                  REJECT_QUEUE_FULL, _bucket)
from repro.serve.metrics import CycleTelemetry, percentiles, summarize


def make_engine(seed=0, capacity=24, users=18, m=3, e=40, **engine_kw):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, capacity, users, e)
    net = costs.default_network(rng, capacity, m)
    ctrl = GraphEdgeController(net=net, policy="greedy_jit")
    params = gcn_init(jax.random.PRNGKey(seed), [8, 6, 4])
    mesh = Mesh(np.array(jax.devices()[:1]), ("servers",))
    engine = ServingEngine(controller=ctrl, params=params, mesh=mesh,
                           **engine_kw)
    return engine, state, rng


def req(state, rng, tenant=0, deadline=None):
    x = rng.normal(size=(state.capacity, 8)).astype(np.float32)
    return StreamRequest(state, x, tenant=tenant, deadline=deadline)


def oracle_err(engine, res):
    st = res.request.state
    oracle = np.asarray(gcn_apply(engine.params, jnp.asarray(res.request.x),
                                  st.adj, st.mask))
    served = np.nonzero(np.asarray(st.mask) > 0)[0]
    return float(np.abs(res.output[served] - oracle[served]).max())


# -- bounded queue / backpressure ---------------------------------------------

def test_queue_full_backpressure_is_explicit():
    """Overflowing the bounded queue rejects with reason queue_full —
    counted, recorded, never silently dropped — and conservation holds at
    every instant."""
    engine, state, rng = make_engine()
    fe = StreamingFrontend(engine=engine, queue_depth=2,
                           clock=ManualClock(tick_per_now=0.01))
    assert fe.submit(req(state, rng))
    assert fe.stats.conservation_ok
    assert fe.submit(req(state, rng))
    assert not fe.submit(req(state, rng, tenant=7))    # full → backpressure
    assert fe.stats.submitted == 3
    assert fe.stats.rejected == {REJECT_QUEUE_FULL: 1}
    assert fe.stats.deferred == 2                      # still queued
    assert fe.stats.conservation_ok
    rej = fe.rejections[0]
    assert (rej.tenant, rej.reason) == (7, REJECT_QUEUE_FULL)
    fe.pump()
    assert fe.stats.served == 2 and fe.stats.deferred == 0
    assert fe.stats.conservation_ok


# -- continuous batching ------------------------------------------------------

def test_burst_batches_and_matches_oracle():
    """A same-topology burst forms real batches (one plan-cache entry, one
    decide per batch) and every member matches the single-device oracle."""
    engine, state, rng = make_engine()
    fe = StreamingFrontend(engine=engine, queue_depth=16, max_batch=4)
    results = fe.run([(0.0, req(state, rng)) for _ in range(6)])
    assert len(results) == 6
    assert fe.stats.batches == 2                       # 4 + 2
    assert sorted(r.batch_size for r in results) == [2, 2, 4, 4, 4, 4]
    assert fe.stats.batched_requests == 6
    assert engine.plan_cache_info().misses == 1        # one shared plan
    for r in results:
        assert oracle_err(engine, r) < 1e-4
    assert fe.stats.conservation_ok and fe.stats.deferred == 0
    slo = fe.slo_summary()
    assert slo["served"] == 6 and slo["sustained_rps"] > 0


def test_batch_groups_only_matching_topology():
    """The batch former only pulls queued requests sharing the head's
    topology fingerprint; others stay queued (not deferred, not rejected)
    for a later cycle."""
    engine, state, rng = make_engine()
    other = perturb_scenario(rng, state, 0.6)
    fe = StreamingFrontend(engine=engine, queue_depth=16, max_batch=8,
                           clock=ManualClock(tick_per_now=0.01))
    for s in (state, state, other, state):
        assert fe.submit(req(s, rng))
    first = fe.pump()
    assert len(first) == 3                             # the three on `state`
    assert all(r.batch_size == 3 for r in first)
    assert len(fe.queue) == 1 and fe.stats.defer_events == 0
    second = fe.pump()
    assert len(second) == 1 and second[0].request.state is other
    assert fe.stats.conservation_ok and fe.stats.deferred == 0
    for r in first + second:
        assert oracle_err(engine, r) < 1e-4


def test_batched_forward_matches_per_request_forward():
    """The batched dispatch path (scatter_batch → vmapped forward →
    gather_batch, with power-of-two padding) is numerically identical to
    serving each member through the plan's single-request forward."""
    engine, state, rng = make_engine()
    decision, entry, _ = engine.decide_entry(state)
    xs = [rng.normal(size=(state.capacity, 8)).astype(np.float32)
          for _ in range(3)]
    batched = engine.batched_forward(entry)
    blocks = entry.plan.scatter_batch(xs, pad_to=4)    # bucket pads 3 → 4
    outs = entry.plan.gather_batch(
        np.asarray(batched(blocks, engine.params)), count=3)
    for x, out in zip(xs, outs):
        single = entry.plan.gather(np.asarray(
            entry.forward(entry.plan.scatter(x), engine.params)))
        np.testing.assert_allclose(out, single, atol=1e-6)


# -- deadlines ---------------------------------------------------------------

def test_expired_deadline_rejected_not_served():
    """A queued request whose absolute deadline tick has passed is shed
    with reason deadline before any service is spent on it."""
    engine, state, rng = make_engine()
    clock = ManualClock(tick_per_now=0.0)
    fe = StreamingFrontend(engine=engine, queue_depth=8, clock=clock)
    assert fe.submit(req(state, rng, deadline=0.5))
    clock.advance(1.0)                                 # blow the budget
    assert fe.pump() == []
    assert fe.stats.rejected == {REJECT_DEADLINE: 1}
    assert fe.stats.served == 0 and fe.stats.conservation_ok


# -- admission control --------------------------------------------------------

def test_static_priority_sheds_low_ranks_over_high_water():
    """Above the high-water backlog only tenants ranked <= keep_rank keep
    admitting; everyone else is rejected outright (the ablation arm)."""
    engine, state, rng = make_engine()
    fe = StreamingFrontend(
        engine=engine, queue_depth=16,
        admission=StaticPriorityAdmission(high_water=2, keep_rank=0),
        clock=ManualClock(tick_per_now=0.01))
    for tenant in (0, 1, 1, 1):
        assert fe.submit(req(state, rng, tenant=tenant))
    results = fe.pump()
    assert [r.request.tenant for r in results] == [0]
    assert fe.stats.rejected == {REJECT_ADMISSION: 3}
    assert fe.stats.conservation_ok and fe.stats.deferred == 0


def test_lyapunov_defers_over_theta_then_drains():
    """Best-effort requests over the backlog bound are deferred (never
    rejected), the idle drain keeps the virtual queues decaying, and the
    whole queue eventually serves — no deadlock, nothing lost."""
    engine, state, rng = make_engine()
    adm = LyapunovAdmission(num_tenants=1, theta=0.5, idle_drain=1.0)
    fe = StreamingFrontend(engine=engine, queue_depth=16, admission=adm,
                           clock=ManualClock(tick_per_now=0.01))
    for _ in range(4):
        assert fe.submit(req(state, rng))              # one tenant floods
    served = []
    for _ in range(32):
        served.extend(fe.pump())
        if not len(fe.queue):
            break
    assert len(served) == 4                            # all eventually run
    assert fe.stats.defer_events > 0
    assert fe.stats.rejected == {}
    assert fe.stats.conservation_ok and fe.stats.deferred == 0
    assert adm.queue_max <= adm.theta + 1.0            # boundedness


def test_lyapunov_bounds_admitted_tail_under_overload():
    """Simulated overload (ManualClock: arrivals far above service): the
    Lyapunov arm sheds load with fully-accounted rejects while the
    *admitted* p99 stays within the SLO budget regime."""
    engine, state, rng = make_engine()
    deadline, tenants = 0.5, 3
    adm = LyapunovAdmission(num_tenants=tenants)
    fe = StreamingFrontend(engine=engine, queue_depth=8, max_batch=4,
                           admission=adm,
                           clock=ManualClock(tick_per_now=0.02))
    wl = poisson_workload(
        np.random.default_rng(3), rate=100.0, count=40,
        make_request=lambda i: req(state, rng, tenant=i % tenants,
                                   deadline=deadline))
    results = fe.run(wl)
    stats = fe.stats
    assert stats.submitted == 40
    assert stats.rejected_total > 0                    # overload sheds
    assert stats.conservation_ok and stats.deferred == 0
    assert stats.admitted == len(results)
    slo = fe.slo_summary()
    assert slo["total"]["p99"] <= 2 * deadline         # bounded tail
    for r in results:
        assert oracle_err(engine, r) < 1e-4


def test_virtual_queue_update_shared_recursion():
    """The front-end's admission controller runs on the same recursion as
    the per-server offload scheduler: Q ← max(Q + a − μ, 0)."""
    assert virtual_queue_update(0.0, 1.0, 0.0, xp=np) == 1.0
    assert virtual_queue_update(1.0, 0.0, 0.4, xp=np) == pytest.approx(0.6)
    assert virtual_queue_update(0.2, 0.0, 1.0, xp=np) == 0.0   # floor at 0
    adm = LyapunovAdmission(num_tenants=2, idle_drain=1.0)
    adm.q = {0: 1.0, 1: 0.25}
    adm.on_cycle(served=0, now=0.0)                    # idle drain μ = 0.5
    assert adm.q[0] == pytest.approx(0.5)
    assert adm.q[1] == 0.0


# -- telemetry ----------------------------------------------------------------

def test_request_timing_phases_and_percentiles():
    t = RequestTiming(arrival=1.0, admit=1.5, dispatch=1.75, done=2.0)
    assert t.phases() == {"queue_wait": 0.5, "decide": 0.25,
                          "forward": 0.25, "total": 1.0}
    p = percentiles([1.0, 2.0, 3.0, 4.0])
    assert p["p50"] == 2.5 and p["max"] == 4.0 and p["mean"] == 2.5
    s = summarize([t, RequestTiming(arrival=2.0, admit=2.0, dispatch=2.5,
                                    done=3.0)])
    assert s["served"] == 2
    assert s["sustained_rps"] == pytest.approx(1.0)    # span 1.0→3.0
    assert s["total"]["max"] == 1.0
    assert summarize([]) == {"served": 0, "sustained_rps": 0.0}


def test_bucket_cap_semantics():
    """Property-pinned _bucket contract: the result is always ≥ b (the cap
    bounds padding — it must never shrink a batch below the members
    already in it), never exceeds max(b, max_batch), and for b within the
    front-end's own limit it is the smallest power of two ≥ b capped at
    max_batch."""
    for max_batch in (1, 2, 3, 4, 8, 12, 16):
        prev = 0
        for b in range(1, 3 * max_batch + 2):
            got = _bucket(b, max_batch)
            assert got >= b                          # never truncates
            assert got <= max(b, max_batch)          # cap honored
            assert got >= prev                       # monotone in b
            prev = got
            if b <= max_batch:
                assert got <= max_batch
                pow2 = 1 << (b - 1).bit_length()
                assert got == min(pow2, max_batch)
            else:
                assert got == b                      # oversize passes thru


# -- cross-topology batching --------------------------------------------------

def test_cross_topology_single_dispatch_serves_mixed_batch():
    """With cross_topology=True one pump cycle serves requests on
    different (same-bucket) topologies as ONE cross dispatch — and each
    member still matches its own topology's oracle."""
    engine, state, rng = make_engine()
    others = [perturb_scenario(rng, state, 0.2) for _ in range(2)]
    fe = StreamingFrontend(engine=engine, queue_depth=16, max_batch=8,
                           cross_topology=True,
                           clock=ManualClock(tick_per_now=0.01))
    for s in (state, others[0], state, others[1]):
        assert fe.submit(req(s, rng))
    results = fe.pump()
    assert len(results) == 4
    assert fe.stats.cross_batches == 1
    assert fe.stats.cross_batched_requests == 4
    assert len(fe.queue) == 0 and fe.stats.conservation_ok
    for r in results:
        assert oracle_err(engine, r) < 1e-4


def test_cross_topology_run_matches_sequential_engine_exactly():
    """End to end: a stream alternating over perturbed topologies served
    cross-topology is bit-exact against the sequential ServingEngine
    oracle (aggregate pinned so both sides run the identical kernel)."""
    engine, state, rng = make_engine(aggregate="fused")
    topos = [state] + [perturb_scenario(rng, state, 0.25)
                       for _ in range(3)]
    reqs = [req(topos[i % len(topos)], rng) for i in range(12)]
    fe = StreamingFrontend(engine=engine, queue_depth=32, max_batch=8,
                           cross_topology=True)
    results = fe.run([(0.0, r) for r in reqs])
    assert len(results) == 12 and fe.stats.cross_batches >= 1
    from repro.serve.engine import ServeRequest
    oracle_engine, _, _ = make_engine(aggregate="fused")
    by_rid = {r.rid: r for r in results}
    seq = oracle_engine.serve_all(
        [ServeRequest(r.state, r.x) for r in reqs])
    for rid, res in enumerate(seq):
        assert float(np.abs(by_rid[rid].output - res.output).max()) == 0.0
    assert fe.stats.conservation_ok and fe.stats.deferred == 0


def test_cross_topology_off_keeps_topology_gate():
    """cross_topology=False (the default) preserves the PR 6 behavior:
    only the head's topology joins a cycle."""
    engine, state, rng = make_engine()
    other = perturb_scenario(rng, state, 0.6)
    fe = StreamingFrontend(engine=engine, queue_depth=16, max_batch=8,
                           clock=ManualClock(tick_per_now=0.01))
    for s in (state, other, state):
        assert fe.submit(req(s, rng))
    assert len(fe.pump()) == 2 and fe.stats.cross_batches == 0
    assert len(fe.queue) == 1


# -- weighted tenant shares ---------------------------------------------------

def test_lyapunov_weighted_shares_drain_proportionally():
    adm = LyapunovAdmission(num_tenants=2, idle_drain=1.0,
                            weights={0: 3.0, 1: 1.0})
    adm.q = {0: 1.0, 1: 1.0}
    adm.on_cycle(served=0, now=0.0)       # capacity 1.0 split 3:1
    assert adm.q[0] == pytest.approx(0.25)
    assert adm.q[1] == pytest.approx(0.75)
    with pytest.raises(ValueError):
        LyapunovAdmission(weights={0: 0.0})


def test_lyapunov_starvation_bound_holds():
    """A deferred tenant re-enters the admit region within the analytic
    starvation bound even when every cycle is idle (worst case: drain is
    only the guaranteed minimum share)."""
    adm = LyapunovAdmission(num_tenants=3, theta=1.0, idle_drain=1.0,
                            weights={2: 0.5})
    start = 6.0
    adm.q = {2: start}
    adm.queue_max = start
    bound = adm.starvation_bound(2)
    assert bound == int(np.ceil((start - adm.theta)
                                / (1.0 * 0.5 / 2.5)))
    cycles = 0
    while adm.q[2] > adm.theta:
        adm.on_cycle(served=0, now=float(cycles))
        cycles += 1
        assert cycles <= bound
    assert cycles <= bound
    # a heavier tenant's bound is proportionally tighter
    assert adm.starvation_bound(0, backlog=start) < bound


def test_lyapunov_weighted_tenant_admits_more_under_contention():
    """Under a symmetric two-tenant flood, the weight-4 tenant's admitted
    share exceeds the weight-1 tenant's."""
    engine, state, rng = make_engine()
    adm = LyapunovAdmission(num_tenants=2, theta=1.5, idle_drain=1.0,
                            weights={0: 4.0, 1: 1.0})
    fe = StreamingFrontend(engine=engine, queue_depth=64, max_batch=2,
                           admission=adm,
                           clock=ManualClock(tick_per_now=0.01))
    served = {0: 0, 1: 0}
    for cycle in range(30):
        for tenant in (0, 1):
            fe.submit(req(state, rng, tenant=tenant))
        for r in fe.pump():
            served[r.request.tenant] += 1
    assert served[0] > served[1] > 0
    assert fe.stats.conservation_ok


# -- decide-stage telemetry ---------------------------------------------------

def test_cycle_telemetry_histogram_and_decide_percentiles():
    t = CycleTelemetry()
    for b, d in ((4, 0.2), (4, 0.4), (2, 0.1), (1, 0.3)):
        t.record(b, d)
    d = t.as_dict()
    assert d["cycles"] == 4
    assert d["batch_hist"] == {"1": 1, "2": 1, "4": 2}
    assert d["batch_mean"] == pytest.approx(2.75)
    assert d["decide"]["p50"] == pytest.approx(0.25)
    assert d["decide"]["p95"] == pytest.approx(
        float(np.percentile([0.2, 0.4, 0.1, 0.3], 95)))
    assert d["decide_per_request"]["max"] == pytest.approx(0.3)


def test_frontend_records_cycle_telemetry_under_manual_clock():
    """The front-end logs one telemetry sample per non-empty cycle with
    deterministic ManualClock decide latencies (admit→dispatch = the
    fixed per-now tick) and the per-cycle batch sizes."""
    engine, state, rng = make_engine()
    fe = StreamingFrontend(engine=engine, queue_depth=16, max_batch=4,
                           clock=ManualClock(tick_per_now=0.01))
    for _ in range(6):
        assert fe.submit(req(state, rng))
    fe.pump()
    fe.pump()
    d = fe.cycles.as_dict()
    assert d["cycles"] == 2
    assert d["batch_hist"] == {"2": 1, "4": 1}
    # ManualClock: every now() call advances 0.01; the decide phase spans
    # a fixed number of calls, so p50 == p95 deterministically
    assert d["decide"]["p50"] == pytest.approx(d["decide"]["p95"])
    assert d["decide"]["p50"] > 0
    assert fe.stats_dict()["cycles"]["cycles"] == 2


# -- concurrent intake --------------------------------------------------------

def test_run_threaded_overlaps_intake_and_serves_everything():
    """The threaded driver (producer thread + pump loop) drains a Poisson
    workload with full conservation and oracle-correct outputs."""
    engine, state, rng = make_engine()
    other = perturb_scenario(rng, state, 0.3)
    fe = StreamingFrontend(engine=engine, queue_depth=64, max_batch=8,
                           cross_topology=True)
    wl = poisson_workload(
        rng, rate=500.0, count=30,
        make_request=lambda i: req((state, other)[i % 2], rng,
                                   tenant=i % 3))
    results = fe.run_threaded(wl)
    assert len(results) == 30
    assert fe.stats.submitted == 30
    assert sorted(r.rid for r in results) == list(range(30))
    assert fe.stats.conservation_ok and fe.stats.deferred == 0
    assert max(oracle_err(engine, r) for r in results) < 1e-4


def test_run_drains_open_loop_poisson_workload():
    """End to end on the wall clock: a Poisson stream over two topologies
    and three tenants drains, serves in batches, and conserves."""
    engine, state, rng = make_engine()
    other = perturb_scenario(rng, state, 0.4)
    fe = StreamingFrontend(engine=engine, queue_depth=64, max_batch=8,
                           admission=AdmitAll())
    wl = poisson_workload(
        rng, rate=400.0, count=24,
        make_request=lambda i: req((state, other)[i % 2], rng,
                                   tenant=i % 3))
    results = fe.run(wl)
    assert len(results) == 24
    assert fe.stats.conservation_ok and fe.stats.deferred == 0
    assert fe.stats.batches < 24                       # batching happened
    assert engine.plan_cache_info().misses == 2        # one per topology
    assert max(oracle_err(engine, r) for r in results) < 1e-4


# -- amortized admission-time service estimate --------------------------------

def test_est_service_amortizes_decide_over_backlog():
    """The batched decide is a per-cycle cost: the admission-time estimate
    spreads it over the batch the backlog supports (capped at max_batch)
    and charges the per-request forward cost whole — a deep backlog must
    never look *slower* per request than a shallow one."""
    engine, state, rng = make_engine()
    fe = StreamingFrontend(engine=engine, queue_depth=32, max_batch=8,
                           clock=ManualClock(tick_per_now=0.01))
    for _ in range(4):
        fe.submit(req(state, rng))
    fe.pump()
    assert fe._est_decide > 0.0 and fe._est_forward > 0.0
    # amortization: decide cost split max_batch ways at deep backlog
    deep = fe.est_service(backlog=fe.max_batch)
    shallow = fe.est_service(backlog=1)
    assert deep == fe._est_decide / fe.max_batch + fe._est_forward
    assert shallow == fe._est_decide + fe._est_forward
    assert deep < shallow
    # backlog beyond max_batch can't amortize further (one cycle's batch)
    assert fe.est_service(backlog=100) == deep
    assert fe.est_service(backlog=0) == shallow         # empty queue: 1
    stats = fe.stats_dict()
    assert stats["est_decide"] == fe._est_decide
    assert stats["est_forward"] == fe._est_forward
    assert stats["est_service"] == fe.est_service(len(fe.queue))


def test_admission_sees_amortized_not_full_cycle_cost():
    """The controller's ``decide`` receives the amortized estimate — the
    decide cost split over the backlog's batch, not the full cycle cost
    per request (the old, systematically pessimistic behaviour that shed
    deadlines the batched cycle would comfortably meet)."""
    class Recorder(AdmitAll):
        def __init__(self):
            self.seen = []

        def decide(self, entry, now, backlog, est_service):
            self.seen.append((backlog, est_service))
            return super().decide(entry, now, backlog, est_service)

    engine, state, rng = make_engine()
    rec = Recorder()
    fe = StreamingFrontend(engine=engine, queue_depth=32, max_batch=8,
                           admission=rec,
                           clock=ManualClock(tick_per_now=0.01))
    for _ in range(8):
        fe.submit(req(state, rng))
    fe.pump()                                   # estimates now warm
    rec.seen.clear()
    d0, f0 = fe._est_decide, fe._est_forward    # pre-cycle EWMA state
    assert d0 > 0.0 and f0 > 0.0
    for _ in range(8):
        fe.submit(req(state, rng))
    fe.pump()
    backlog, est = rec.seen[0]
    assert backlog == 8
    assert est == d0 / 8 + f0 < d0 + f0
    # every candidate of the cycle saw the same (cycle-scoped) estimate
    assert all(e == est for _, e in rec.seen)
