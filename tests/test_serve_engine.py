"""Serving engine: pipelined outputs vs the single-device oracle across a
dynamic rollout, plan-cache behaviour on unchanged topologies, and the LRU
bounds on both the plan cache and the controller's partition cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import costs
from repro.core.api import GraphEdgeController
from repro.core.dynamic_graph import (move_users, perturb_scenario,
                                      random_scenario)
from repro.gnn.layers import gcn_apply, gcn_init
from repro.serve import ServeRequest, ServingEngine


def make_engine(seed=0, capacity=24, users=18, m=3, e=40, policy="greedy_jit",
                **engine_kw):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, capacity, users, e)
    net = costs.default_network(rng, capacity, m)
    ctrl = GraphEdgeController(net=net, policy=policy)
    params = gcn_init(jax.random.PRNGKey(seed), [8, 6, 4])
    mesh = Mesh(np.array(jax.devices()[:1]), ("servers",))
    engine = ServingEngine(controller=ctrl, params=params, mesh=mesh,
                           **engine_kw)
    return engine, state, rng


def oracle_err(engine, res):
    st = res.request.state
    oracle = np.asarray(gcn_apply(engine.params, jnp.asarray(res.request.x),
                                  st.adj, st.mask))
    served = np.nonzero(np.asarray(st.mask) > 0)[0]
    return float(np.abs(res.output[served] - oracle[served]).max())


def requests_for(rng, state, steps, repeats, change_rate, features=8):
    reqs = []
    for t in range(steps):
        if t:
            state = perturb_scenario(rng, state, change_rate)
        for _ in range(repeats):
            x = rng.normal(size=(state.capacity, features))
            reqs.append(ServeRequest(state, x.astype(np.float32)))
    return reqs


# -- correctness across a dynamic rollout ------------------------------------

@pytest.mark.parametrize("policy", ["greedy_jit", "greedy"])
def test_rollout_outputs_match_oracle(policy):
    """Pipelined serving across a change_rate>0 rollout stays on the
    oracle for every request — jit and numpy policies alike."""
    engine, state, rng = make_engine(policy=policy)
    reqs = requests_for(rng, state, steps=4, repeats=1, change_rate=0.3)
    results = engine.serve_all(reqs)
    assert [r.step for r in results] == list(range(len(reqs)))
    for res in results:
        assert oracle_err(engine, res) < 1e-4


def test_pipelining_preserves_request_pairing():
    """Each result carries its own request/decision (depth-1 pipelining
    must not shift outputs by one)."""
    engine, state, rng = make_engine()
    reqs = requests_for(rng, state, steps=5, repeats=1, change_rate=0.4)
    for res, req in zip(engine.serve(iter(reqs)), reqs):
        assert res.request is req
        assert res.decision.state is req.state


# -- plan cache --------------------------------------------------------------

def test_plan_cache_hits_on_unchanged_topology():
    """A request stream on one fixed layout builds the plan exactly once."""
    engine, state, rng = make_engine()
    reqs = [ServeRequest(state, rng.normal(size=(state.capacity, 8))
                         .astype(np.float32)) for _ in range(4)]
    results = engine.serve_all(reqs)
    assert [r.plan_cache_hit for r in results] == [False, True, True, True]
    assert results[0].plan is results[1].plan
    info = engine.plan_cache_info()
    assert (info.hits, info.misses, info.currsize) == (3, 1, 1)
    # the partition cache saw the same stream
    assert engine.controller.cache_info().hits == 3


def test_plan_cache_keyed_on_assignment_too():
    """Pure mobility keeps the topology (partition cache hits) but can move
    the greedy assignment — the plan cache must key on both."""
    engine, state, rng = make_engine()
    moved = move_users(state, state.pos + jnp.asarray(
        rng.uniform(300, 900, (state.capacity, 2)).astype(np.float32)))
    x = rng.normal(size=(state.capacity, 8)).astype(np.float32)
    r1, r2 = engine.serve_all([ServeRequest(state, x),
                               ServeRequest(moved, x)])
    assert engine.controller.cache_info().hits == 1      # same topology
    same_assign = np.array_equal(r1.decision.servers, r2.decision.servers)
    # cache hit iff the policy reproduced the assignment
    assert r2.plan_cache_hit == same_assign
    for res in (r1, r2):
        assert oracle_err(engine, res) < 1e-4


def test_plan_cache_lru_bound():
    engine, state, rng = make_engine(plan_cache_size=2)
    reqs = requests_for(rng, state, steps=4, repeats=1, change_rate=0.6)
    engine.serve_all(reqs)
    info = engine.plan_cache_info()
    assert info.currsize <= 2
    assert info.maxsize == 2


def test_controller_partition_cache_lru_bound():
    rng = np.random.default_rng(0)
    state = random_scenario(rng, 20, 14, 30)
    net = costs.default_network(rng, 20, 3)
    ctrl = GraphEdgeController(net=net, policy="greedy_jit", cache_size=2)
    states = [state]
    for _ in range(3):
        states.append(perturb_scenario(rng, states[-1], 0.6))
    for s in states:
        ctrl.step(s)
    info = ctrl.cache_info()
    assert info.currsize <= 2 and info.maxsize == 2
    # the oldest topology was evicted → stepping it again is a miss
    misses = info.misses
    ctrl.step(states[0])
    assert ctrl.cache_info().misses == misses + 1


def test_plan_cache_interleaved_tenant_topologies():
    """Two tenants' topology streams interleaved: each distinct topology
    costs exactly one miss, every revisit hits — batching's substrate."""
    engine, state, rng = make_engine(plan_cache_size=8)
    other = perturb_scenario(rng, state, 0.6)

    def req(s):
        return ServeRequest(s, rng.normal(size=(s.capacity, 8))
                            .astype(np.float32))

    results = engine.serve_all([req(state), req(other), req(state),
                                req(other), req(state), req(other)])
    assert [r.plan_cache_hit for r in results] == \
        [False, False, True, True, True, True]
    info = engine.plan_cache_info()
    assert (info.hits, info.misses, info.currsize) == (4, 2, 2)
    for res in results:
        assert oracle_err(engine, res) < 1e-4


def test_plan_cache_lru_eviction_order():
    """A hit refreshes recency: with a 2-deep cache, A B A C evicts B (the
    least recently *used*, not least recently inserted), so B misses again
    while A keeps hitting until C+B push it out."""
    engine, state, rng = make_engine(plan_cache_size=2)
    s2 = perturb_scenario(rng, state, 0.6)
    s3 = perturb_scenario(rng, s2, 0.6)

    def req(s):
        return ServeRequest(s, rng.normal(size=(s.capacity, 8))
                            .astype(np.float32))

    stream = [req(state), req(s2), req(state), req(s3), req(s2), req(state)]
    results = engine.serve_all(stream)
    #         A:miss  B:miss  A:hit  C:miss(evict B)  B:miss(evict A)  A:miss
    assert [r.plan_cache_hit for r in results] == \
        [False, False, True, False, False, False]
    info = engine.plan_cache_info()
    assert (info.hits, info.misses, info.currsize) == (1, 5, 2)


# -- mid-stream failure ------------------------------------------------------

class Boom(RuntimeError):
    pass


def test_serve_flushes_pending_on_poisoned_iterator():
    """If the request *stream* raises after request t was dispatched,
    t's in-flight result still reaches the consumer before the exception
    propagates — the pipeline never silently loses a served request."""
    engine, state, rng = make_engine()
    good = ServeRequest(state, rng.normal(size=(state.capacity, 8))
                        .astype(np.float32))

    def poisoned():
        yield good
        raise Boom("stream died")

    gen = engine.serve(poisoned())
    res = next(gen)
    assert res.request is good
    assert oracle_err(engine, res) < 1e-4
    with pytest.raises(Boom):
        next(gen)


def test_serve_flushes_pending_on_failing_decide():
    """Same for a *request* whose control stage raises (bad state): the
    previous request's pending result is flushed first."""
    engine, state, rng = make_engine()
    good = ServeRequest(state, rng.normal(size=(state.capacity, 8))
                        .astype(np.float32))
    bad = ServeRequest(None, good.x)          # controller.step(None) raises
    gen = engine.serve([good, bad])
    res = next(gen)
    assert res.request is good
    assert oracle_err(engine, res) < 1e-4
    with pytest.raises(Exception):
        next(gen)


# -- multi-device end to end --------------------------------------------------

@pytest.mark.slow
def test_engine_multidevice_subprocess():
    """Engine round-trip on a real 4-device mesh (virtual CPUs)."""
    import os
    import subprocess
    import sys
    import textwrap
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import costs
        from repro.core.api import GraphEdgeController
        from repro.core.dynamic_graph import perturb_scenario, random_scenario
        from repro.gnn.layers import gcn_apply, gcn_init
        from repro.serve import ServeRequest, ServingEngine
        rng = np.random.default_rng(2)
        state = random_scenario(rng, 48, 40, 120)
        net = costs.default_network(rng, 48, 4)
        ctrl = GraphEdgeController(net=net, policy="greedy_jit")
        params = gcn_init(jax.random.PRNGKey(0), [16, 8, 5])
        mesh = Mesh(np.array(jax.devices()), ("servers",))
        engine = ServingEngine(controller=ctrl, params=params, mesh=mesh)
        reqs = []
        for t in range(3):
            if t:
                state = perturb_scenario(rng, state, 0.3)
            reqs.append(ServeRequest(
                state, rng.normal(size=(48, 16)).astype(np.float32)))
        err = 0.0
        for res in engine.serve(reqs):
            st = res.request.state
            oracle = np.asarray(gcn_apply(params, jnp.asarray(res.request.x),
                                          st.adj, st.mask))
            act = np.nonzero(np.asarray(st.mask) > 0)[0]
            err = max(err, float(np.abs(res.output[act] - oracle[act]).max()))
        print("ERR", err)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    assert float(out.stdout.split("ERR")[1]) < 1e-4


# -- pair exchange through the engine -----------------------------------------

def test_engine_pair_exchange_matches_gather_bitwise():
    """The engine's ``exchange="pair"`` knob (the multi-host halo-only
    wire) serves bitwise-identically to the default gather layout — the
    two layouts move the same rows, just over different collectives."""
    reqs = None
    outs = {}
    for exchange in ("gather", "pair"):
        engine, state, rng = make_engine(exchange=exchange)
        if reqs is None:
            reqs = requests_for(rng, state, steps=2, repeats=2,
                                change_rate=0.3)
        results = engine.serve_all(reqs)
        assert all(r.plan.exchange == exchange for r in results)
        assert max(oracle_err(engine, r) for r in results) < 1e-4
        outs[exchange] = [r.output for r in results]
    for a, b in zip(outs["gather"], outs["pair"]):
        assert np.array_equal(a, b)
