"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps
+ hypothesis property sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels.chunk_scan.ops import ssd_chunk_scan
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.gnn_aggregate.ops import normalized_aggregate

RNG = np.random.default_rng(0)


# --- gnn_aggregate ----------------------------------------------------------

@pytest.mark.parametrize("n,f,dtype", [
    (64, 32, np.float32), (128, 128, np.float32), (200, 70, np.float32),
    (5, 3, np.float32), (130, 257, np.float32), (64, 32, jnp.bfloat16),
])
def test_gnn_aggregate_matches_ref(n, f, dtype):
    adj = (RNG.random((n, n)) < 0.15).astype(np.float32)
    x = jnp.asarray(RNG.normal(size=(n, f)).astype(np.float32)).astype(dtype)
    rs = RNG.random(n).astype(np.float32)
    cs = RNG.random(n).astype(np.float32)
    ref = normalized_aggregate(jnp.asarray(adj), x, rs, cs, impl="xla")
    ker = normalized_aggregate(jnp.asarray(adj), x, rs, cs,
                               impl="interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert float(jnp.max(jnp.abs(ref.astype(jnp.float32)
                                 - ker.astype(jnp.float32)))) < tol * max(
        1.0, float(jnp.max(jnp.abs(ref.astype(jnp.float32)))))


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 96), st.integers(1, 48), st.integers(0, 9999))
def test_gnn_aggregate_property(n, f, seed):
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < 0.2).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    rs = rng.random(n).astype(np.float32)
    cs = rng.random(n).astype(np.float32)
    ref = normalized_aggregate(jnp.asarray(adj), x, rs, cs, impl="xla")
    ker = normalized_aggregate(jnp.asarray(adj), x, rs, cs,
                               impl="interpret")
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# --- flash attention --------------------------------------------------------

@pytest.mark.parametrize("b,h,kv,s,dh,causal,win,cap", [
    (2, 4, 2, 256, 64, True, None, None),
    (1, 4, 4, 128, 32, True, None, 50.0),
    (2, 8, 2, 256, 64, True, 128, None),
    (1, 2, 1, 512, 128, False, None, None),
    (1, 4, 2, 256, 64, True, 64, 30.0),
    (1, 2, 2, 384, 64, True, None, None),     # non-pow2 seq (block 128)
])
def test_flash_attention_matches_ref(b, h, kv, s, dh, causal, win, cap):
    q = jnp.asarray(RNG.normal(size=(b, h, s, dh)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, kv, s, dh)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, kv, s, dh)).astype(np.float32))
    ref = flash_attention(q, k, v, causal=causal, window=win, softcap=cap,
                          impl="xla")
    ker = flash_attention(q, k, v, causal=causal, window=win, softcap=cap,
                          impl="interpret")
    assert float(jnp.max(jnp.abs(ref - ker))) < 2e-5


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    ref = flash_attention(q, k, v, impl="xla").astype(jnp.float32)
    ker = flash_attention(q, k, v, impl="interpret").astype(jnp.float32)
    assert float(jnp.max(jnp.abs(ref - ker))) < 3e-2


# --- ssd chunk scan ---------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 64, 4, 32, 16, 16), (1, 128, 2, 64, 32, 32),
    (2, 96, 3, 16, 8, 32), (1, 256, 8, 64, 64, 128),
])
def test_ssd_chunk_scan_matches_sequential(b, s, h, p, n, chunk):
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)).astype(np.float32)) * 0.5
    bm = jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32)) * 0.5
    cm = jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32)) * 0.5
    la = -jnp.asarray(RNG.random((b, s, h)).astype(np.float32))
    ref = ssd_chunk_scan(x, bm, cm, la, impl="xla")
    ker = ssd_chunk_scan(x, bm, cm, la, impl="interpret", chunk=chunk)
    rel = float(jnp.max(jnp.abs(ref - ker)) /
                (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 1e-4, rel


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.sampled_from([32, 64, 96]),
       st.integers(1, 4), st.integers(0, 9999))
def test_ssd_chunk_scan_property(b, s, h, seed):
    rng = np.random.default_rng(seed)
    p = n = 16
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32)) * 0.5
    bm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32)) * 0.5
    cm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32)) * 0.5
    la = -jnp.asarray(rng.random((b, s, h)).astype(np.float32)) * 2.0
    ref = ssd_chunk_scan(x, bm, cm, la, impl="xla")
    ker = ssd_chunk_scan(x, bm, cm, la, impl="interpret", chunk=32)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_ssd_decay_extremes():
    """Zero decay (a→0) forgets history; unit decay accumulates it."""
    b, s, h, p, n = 1, 8, 1, 4, 4
    x = jnp.ones((b, s, h, p))
    bm = jnp.ones((b, s, n))
    cm = jnp.ones((b, s, n))
    la_zero = jnp.full((b, s, h), -50.0)       # decay ≈ 0
    y = ssd_chunk_scan(x, bm, cm, la_zero, impl="interpret", chunk=4)
    np.testing.assert_allclose(np.asarray(y), n, rtol=1e-4)
    la_one = jnp.zeros((b, s, h))              # decay = 1: running sum
    y = ssd_chunk_scan(x, bm, cm, la_one, impl="interpret", chunk=4)
    expect = n * np.arange(1, s + 1, dtype=np.float32)
    np.testing.assert_allclose(np.asarray(y)[0, :, 0, 0], expect, rtol=1e-4)
