"""Cross-topology batching: shape buckets, plan padding (bitwise-exact),
the multi-plan forward, the engine's bucket-keyed cross dispatch, and the
batched-decide control path (ISSUE 8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import costs
from repro.core.api import GraphEdgeController
from repro.core.dynamic_graph import perturb_scenario, random_scenario
from repro.gnn.distributed import (PLAN_BUCKET_QUANTUM, gather_multi,
                                   make_forward_fn, make_multi_forward_fn,
                                   make_partition_plan, pad_plan,
                                   pad_plan_to_bucket, plan_bucket,
                                   prepare_plan_consts, scatter_multi)
from repro.gnn.layers import gcn_init
from repro.serve.engine import ServeRequest, ServingEngine


def rand_adj(rng, n, p=0.2):
    a = rng.random((n, n)) < p
    a = np.triu(a, 1)
    return (a | a.T).astype(np.float64)


def mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("servers",))


def build_plans(rng, sizes, p=1):
    plans = []
    for n in sizes:
        assign = rng.integers(0, p, n)
        plans.append(make_partition_plan(rand_adj(rng, n), assign, p))
    return plans


# -- shape buckets -----------------------------------------------------------

def test_plan_bucket_quantizes_slot_dims():
    rng = np.random.default_rng(0)
    plan = build_plans(rng, [19])[0]
    p, n, block, halo, k = plan_bucket(plan)
    assert (p, n) == (plan.num_devices, plan.n)
    for padded, raw in ((block, plan.block), (halo, plan.halo),
                        (k, plan.max_degree)):
        assert padded >= max(raw, PLAN_BUCKET_QUANTUM)
        assert padded % PLAN_BUCKET_QUANTUM == 0
        assert padded - raw < PLAN_BUCKET_QUANTUM \
            or raw < PLAN_BUCKET_QUANTUM


def test_nearby_topologies_share_a_bucket():
    """Perturbed same-capacity layouts — the streaming workload — land in
    one bucket (that is the whole point of the quantum)."""
    rng = np.random.default_rng(1)
    state = random_scenario(rng, 24, 18, 40)
    other = perturb_scenario(rng, state, 0.1)
    plans = [make_partition_plan(np.asarray(s.adj, np.float64),
                                 np.zeros(24, np.int64), 1)
             for s in (state, other)]
    assert plan_bucket(plans[0]) == plan_bucket(plans[1])


# -- plan padding ------------------------------------------------------------

def test_pad_plan_is_bitwise_exact():
    """Padding appends inert slots only: the padded plan's forward output
    is bit-for-bit the original's, for every aggregate kernel."""
    rng = np.random.default_rng(2)
    plan = build_plans(rng, [22])[0]
    padded = pad_plan(plan, plan.block + 11, plan.halo + 5,
                      plan.max_degree + 3)
    x = rng.standard_normal((plan.n, 8)).astype(np.float32)
    params = gcn_init(jax.random.PRNGKey(0), [8, 6, 4])
    for agg in ("dense", "sparse", "fused"):
        ref = make_forward_fn(mesh1(), "servers", plan, aggregate=agg)
        fwd = make_forward_fn(mesh1(), "servers", padded, aggregate=agg)
        y_ref = plan.gather(np.asarray(ref(plan.scatter(x), params)))
        y_pad = padded.gather(np.asarray(fwd(padded.scatter(x), params)))
        assert np.array_equal(y_ref, y_pad), agg


def test_pad_plan_refuses_to_shrink():
    rng = np.random.default_rng(3)
    plan = build_plans(rng, [16])[0]
    with pytest.raises(AssertionError):
        pad_plan(plan, plan.block - 1, plan.halo, plan.max_degree)


# -- multi-plan forward ------------------------------------------------------

@pytest.mark.parametrize("agg", ["dense", "sparse", "fused"])
def test_multi_forward_matches_per_plan_forward(agg):
    """One cross-topology dispatch over B different plans is bitwise equal
    to B per-plan single dispatches."""
    rng = np.random.default_rng(4)
    plans = build_plans(rng, [18, 25, 21])
    bucket = tuple(np.max([plan_bucket(p) for p in plans], axis=0)[2:])
    padded = [pad_plan(p, *bucket) for p in plans]
    xs = [rng.standard_normal((p.n, 8)).astype(np.float32) for p in plans]
    params = gcn_init(jax.random.PRNGKey(1), [8, 6, 4])
    fwd = make_multi_forward_fn(
        mesh1(), "servers", agg,
        [prepare_plan_consts(p, agg) for p in padded])
    outs = gather_multi(padded, np.asarray(
        fwd(scatter_multi(padded, xs), params)))
    for plan, x, out in zip(plans, xs, outs):
        single = make_forward_fn(mesh1(), "servers", plan, aggregate=agg)
        y = plan.gather(np.asarray(single(plan.scatter(x), params)))
        assert np.array_equal(out, y)


# -- engine surface ----------------------------------------------------------

def make_engine(seed=0, capacity=24, users=18, m=3, e=40, **kw):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, capacity, users, e)
    net = costs.default_network(rng, capacity, m)
    ctrl = GraphEdgeController(net=net, policy="greedy_jit")
    params = gcn_init(jax.random.PRNGKey(seed), [8, 6, 4])
    engine = ServingEngine(controller=ctrl, params=params, mesh=mesh1(),
                           **kw)
    return engine, state, rng


def test_decide_entries_matches_sequential_decide():
    """The batched control stage (one vmapped XLA call for the cycle) is
    assignment-exact against per-request decide_entry, and both roads meet
    in the same plan-cache entries."""
    engine, state, rng = make_engine()
    states = [state] + [perturb_scenario(rng, state, 0.3)
                        for _ in range(3)]
    seq = [engine.decide_entry(s) for s in states]
    engine2, _, _ = make_engine()
    got = engine2.decide_entries(states)
    assert len(got) == len(seq)
    for (d_s, e_s, _), (d_b, e_b, _) in zip(seq, got):
        np.testing.assert_array_equal(d_s.assignment.servers,
                                      d_b.assignment.servers)
        assert d_b.cost.c == pytest.approx(d_s.cost.c, rel=1e-5)
        assert e_s.key == e_b.key
    # the batch hits the same cache entries a second time around
    hits0 = engine2.plan_cache_info().hits
    engine2.decide_entries(states)
    assert engine2.plan_cache_info().hits == hits0 + len(states)


def test_cross_batched_forward_exact_vs_sequential_engine():
    """The engine's bucket-keyed cross dispatch serves requests resolved
    against different cached plans with EXACT parity (max err == 0) vs the
    sequential per-request engine — the CI-gated invariant."""
    engine, state, rng = make_engine(aggregate="fused")
    states = [state] + [perturb_scenario(rng, state, 0.2)
                        for _ in range(2)]
    xs = [rng.normal(size=(s.capacity, 8)).astype(np.float32)
          for s in states]
    # sequential oracle on an identical twin engine
    oracle, _, _ = make_engine(aggregate="fused")
    seq = oracle.serve_all([ServeRequest(s, x)
                            for s, x in zip(states, xs)])
    decided = engine.decide_entries(states)
    entries = [pe for _, pe, _ in decided]
    assert len({engine.entry_bucket(e) for e in entries}) == 1
    plans, fwd = engine.cross_batched_forward(entries)
    outs = gather_multi(plans, np.asarray(
        fwd(scatter_multi(plans, xs), engine.params)))
    for res, out in zip(seq, outs):
        assert float(np.abs(out - res.output).max()) == 0.0


def test_cross_batched_forward_is_cached_on_member_keys():
    engine, state, rng = make_engine()
    states = [state, perturb_scenario(rng, state, 0.2)]
    entries = [pe for _, pe, _ in engine.decide_entries(states)]
    plans1, fwd1 = engine.cross_batched_forward(entries)
    plans2, fwd2 = engine.cross_batched_forward(entries)
    assert fwd1 is fwd2 and plans1 is plans2


# -- adaptive bucket quantums -------------------------------------------------

def test_bucket_family_quantum_widens_over_straddling_halos():
    """A family whose halo widths straddle a PLAN_BUCKET_QUANTUM boundary
    (e.g. 7 vs 9) doubles its quantum until both land in one bucket, so
    the hot layout family batches together instead of splitting."""
    from repro.serve.engine import BucketFamily, PlanEntry
    rng = np.random.default_rng(7)
    base = build_plans(rng, [22])[0]
    lo = pad_plan(base, base.block, 7, base.max_degree)
    hi = pad_plan(base, base.block, 9, base.max_degree)
    assert plan_bucket(lo) != plan_bucket(hi)          # fixed quantum splits
    engine, _, _ = make_engine()
    e_lo = PlanEntry(("t", "lo"), lo, lambda *a: None)
    e_hi = PlanEntry(("t", "hi"), hi, lambda *a: None)
    b_lo = engine.entry_bucket(e_lo)
    assert b_lo == plan_bucket(lo)                     # first sighting: q=8
    b_hi = engine.entry_bucket(e_hi)                   # spread seen → widen
    assert engine.entry_bucket(e_lo) == b_hi           # e_lo re-buckets
    assert e_lo.bucket_quantum == e_hi.bucket_quantum == 16
    # widening only merges: one more width inside the same 16-bucket
    mid = pad_plan(base, base.block, 12, base.max_degree)
    e_mid = PlanEntry(("t", "mid"), mid, lambda *a: None)
    assert engine.entry_bucket(e_mid) == b_hi
    # the family histogram is bounded and capped at the quantum ceiling
    fam = BucketFamily()
    for h in range(1, 200):
        q = fam.observe(h)
    from repro.serve.engine import PLAN_BUCKET_QUANTUM_CAP, _FAMILY_HIST_MAX
    assert q == PLAN_BUCKET_QUANTUM_CAP
    assert len(fam.hist) <= _FAMILY_HIST_MAX


def test_adaptive_bucket_serves_cross_batch_after_widening():
    """End to end: two plans split at quantum 8 still serve as ONE
    cross-topology dispatch once their family widened — outputs stay
    bitwise equal to the per-plan forwards."""
    from repro.serve.engine import PlanEntry
    rng = np.random.default_rng(8)
    base = build_plans(rng, [20])[0]
    variants = [pad_plan(base, base.block, 7, base.max_degree),
                pad_plan(base, base.block, 9, base.max_degree)]
    engine, _, _ = make_engine()
    entries = [PlanEntry(("t", str(i)), p, lambda *a: None)
               for i, p in enumerate(variants)]
    for e in entries:
        engine.entry_bucket(e)
    assert len({engine.entry_bucket(e) for e in entries}) == 1
    plans, fwd = engine.cross_batched_forward(entries)
    xs = [rng.standard_normal((p.n, 8)).astype(np.float32)
          for p in variants]
    outs = gather_multi(plans, np.asarray(
        fwd(scatter_multi(plans, xs), engine.params)))
    for plan, x, out in zip(variants, xs, outs):
        single = make_forward_fn(mesh1(), "servers", plan)
        y = plan.gather(np.asarray(single(plan.scatter(x), engine.params)))
        assert np.array_equal(out, y)
