"""Multilevel METIS-style partitioner (repro.core.multilevel): matching /
contraction invariants, validity + capacity properties, cut quality vs the
mincut baseline on seeded planted-community sweeps, the jnp refinement
twin (JitPartitioner), and the round-trip through the controller and the
serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costs
from repro.core.api import (GraphEdgeController, JitPartitioner,
                            get_partitioner, state_edges)
from repro.core.dynamic_graph import random_scenario
from repro.core.hicut import cut_metrics
from repro.core.multilevel import (contract, heavy_edge_matching,
                                   multilevel_jax, multilevel_partition)


def planted_graph(rng, n, k=4, deg_in=6, cross_frac=0.08):
    """Random graph with k balanced planted communities: ~deg_in/2 · n
    intra-community edges plus a cross_frac fraction of cross edges."""
    com = np.repeat(np.arange(k), n // k)
    com = np.concatenate([com, rng.integers(0, k, n - len(com))])
    rng.shuffle(com)
    have = set()
    target_in = n * deg_in // 2
    tries = 0
    while len(have) < target_in and tries < 50 * target_in:
        tries += 1
        i = int(rng.integers(n))
        peers = np.nonzero(com == com[i])[0]
        j = int(rng.choice(peers))
        if i != j:
            have.add((min(i, j), max(i, j)))
    n_cross, added, tries = int(len(have) * cross_frac), 0, 0
    while added < n_cross and tries < 50 * max(n_cross, 1):
        tries += 1
        i, j = int(rng.integers(n)), int(rng.integers(n))
        if i != j and com[i] != com[j] and (min(i, j), max(i, j)) not in have:
            have.add((min(i, j), max(i, j)))
            added += 1
    return np.array(sorted(have), np.int64), com


# -- coarsening building blocks ----------------------------------------------

def test_heavy_edge_matching_is_a_matching():
    rng = np.random.default_rng(0)
    edges, _ = planted_graph(rng, 80)
    w = rng.uniform(1, 10, len(edges))
    match = heavy_edge_matching(80, edges, w)
    # involution: partners point at each other, singletons at themselves
    np.testing.assert_array_equal(match[match], np.arange(80))
    # matched pairs are actual edges
    adj = set(map(tuple, edges))
    for v in range(80):
        if match[v] != v:
            i, j = min(v, match[v]), max(v, match[v])
            assert (i, j) in adj
    # uniform-weight graphs must not stall (the jittered-tie regression)
    m1 = heavy_edge_matching(80, edges, np.ones(len(edges)))
    assert (m1 != np.arange(80)).sum() // 2 > 80 // 8


def test_heavy_edges_matched_first():
    # path 0-1-2-3 with one heavy middle edge: (1,2) must be matched
    edges = np.array([[0, 1], [1, 2], [2, 3]])
    match = heavy_edge_matching(4, edges, np.array([1.0, 100.0, 1.0]))
    assert match[1] == 2 and match[2] == 1


def test_contract_conserves_weight():
    rng = np.random.default_rng(1)
    edges, _ = planted_graph(rng, 60)
    w = rng.uniform(1, 5, len(edges))
    vwgt = np.ones(60)
    match = heavy_edge_matching(60, edges, w)
    n_c, cmap, ce, cw, cv = contract(60, edges, w, vwgt, match)
    assert cv.sum() == 60                       # vertex weight conserved
    assert n_c == len(np.unique(cmap))
    # edge weight between distinct clusters is conserved
    cross = cmap[edges[:, 0]] != cmap[edges[:, 1]]
    np.testing.assert_allclose(cw.sum(), w[cross].sum())
    assert (ce[:, 0] != ce[:, 1]).all()         # no self loops


# -- validity + capacity ------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_partition_valid_and_capacity_respecting(seed):
    rng = np.random.default_rng(seed)
    users = 24 + seed * 12
    state = random_scenario(rng, users + 8, users, 3 * users)
    part = get_partitioner("multilevel")(state)
    active = np.asarray(state.mask) > 0
    sub = part.subgraph
    assert ((sub[active] >= 0) & (sub[active] < 4)).all()
    assert (sub[~active] == -1).all()
    cap = int(np.ceil(active.sum() / 4 * 1.1))
    assert np.bincount(sub[active], minlength=4).max() <= cap


def test_registry_kwargs_and_num_parts():
    rng = np.random.default_rng(2)
    state = random_scenario(rng, 40, 36, 100)
    part = get_partitioner("multilevel", num_parts=3)(state)
    active = np.asarray(state.mask) > 0
    assert set(np.unique(part.subgraph[active])) <= {0, 1, 2}
    cap = int(np.ceil(active.sum() / 3 * 1.1))
    assert np.bincount(part.subgraph[active], minlength=3).max() <= cap


# -- cut quality vs the mincut baseline ---------------------------------------

def test_cut_cost_beats_mincut_on_planted_sweep():
    """On the seeded planted-community sweep the multilevel cut must be
    no worse than the pairwise max-flow baseline, seed for seed."""
    from repro.core.mincut_baseline import pairwise_mincut_partition
    totals = np.zeros(2)
    for seed in range(6):
        rng = np.random.default_rng(seed)
        n = 60 + seed * 12
        edges, _ = planted_graph(rng, n)
        w = rng.integers(1, 101, len(edges))
        ml = multilevel_partition(n, edges, 4, seed=seed)
        mc = pairwise_mincut_partition(n, edges, w, 4, seed=seed)
        c_ml = cut_metrics(n, edges, ml)["cross_edges"]
        c_mc = cut_metrics(n, edges, mc)["cross_edges"]
        assert c_ml <= c_mc, (seed, c_ml, c_mc)
        totals += (c_ml, c_mc)
    assert totals[0] < totals[1]       # and strictly better in aggregate


def test_recovers_planted_communities():
    """With balanced planted communities the pipeline should land at (or
    very near) the planted cut."""
    rng = np.random.default_rng(3)
    edges, com = planted_graph(rng, 96)
    ml = multilevel_partition(96, edges, 4, seed=3)
    c_ml = cut_metrics(96, edges, ml)["cross_edges"]
    c_planted = cut_metrics(96, edges, com)["cross_edges"]
    assert c_ml <= 1.5 * c_planted + 2


# -- jnp refinement twin (JitPartitioner) -------------------------------------

def test_multilevel_jax_registry_and_jit_parity():
    rng = np.random.default_rng(4)
    state = random_scenario(rng, 36, 30, 90)
    p = get_partitioner("multilevel_jax")
    assert isinstance(p, JitPartitioner)
    part = p(state)
    active = np.asarray(state.mask) > 0
    sub = part.subgraph
    assert ((sub[active] >= 0) & (sub[active] < 4)).all()
    assert (sub[~active] == -1).all()
    cap = int(np.ceil(active.sum() / 4 * 1.1))
    assert np.bincount(sub[active], minlength=4).max() <= cap
    # the eager __call__ and the traceable cut() are the same function
    jitted = np.asarray(jax.jit(p.cut)(state))
    np.testing.assert_array_equal(jitted, sub)


def test_multilevel_jax_refinement_improves_cut():
    rng = np.random.default_rng(5)
    state = random_scenario(rng, 48, 44, 140)
    edges = state_edges(state)
    no_ref = np.asarray(multilevel_jax(state.adj, state.mask, 4, 0))
    refined = np.asarray(multilevel_jax(state.adj, state.mask, 4, 96))
    c0 = cut_metrics(48, edges, no_ref)["cross_edges"]
    c1 = cut_metrics(48, edges, refined)["cross_edges"]
    assert c1 <= c0
    assert c1 < c0        # the sweep must actually move something here


def test_multilevel_jax_empty_mask():
    adj = jnp.zeros((8, 8))
    mask = jnp.zeros(8)
    out = np.asarray(multilevel_jax(adj, mask, 4, 8))
    assert (out == -1).all()


# -- round-trips through the stack -------------------------------------------

@pytest.mark.parametrize("name", ["multilevel", "multilevel_jax"])
def test_controller_step_roundtrip(name):
    rng = np.random.default_rng(6)
    state = random_scenario(rng, 24, 20, 60)
    net = costs.default_network(rng, 24, 3)
    d = GraphEdgeController(net=net, policy="greedy",
                            partitioner=name).step(state)
    active = np.asarray(state.mask) > 0
    assert ((d.servers[active] >= 0) & (d.servers[active] < 3)).all()
    w = costs.assignment_onehot(jnp.asarray(d.servers), 3)
    sc = costs.system_cost(net, state, w)
    assert np.isclose(float(d.cost.c), float(sc.c))


def test_jit_step_fn_with_multilevel_jax():
    """multilevel_jax + greedy_jit trace end to end and match the eager
    controller step (same cut function on both paths)."""
    rng = np.random.default_rng(7)
    state = random_scenario(rng, 20, 16, 40)
    net = costs.default_network(rng, 20, 3)
    ctrl = GraphEdgeController(net=net, policy="greedy_jit",
                               partitioner="multilevel_jax")
    res = jax.jit(ctrl.jit_step_fn())(state)
    eager = ctrl.step(state)
    np.testing.assert_array_equal(np.asarray(res.servers), eager.servers)
    np.testing.assert_array_equal(np.asarray(res.subgraph),
                                  eager.partition.subgraph)
    assert np.isclose(float(res.cost.c), float(eager.cost.c), rtol=1e-6)


def test_serving_roundtrip_single_device():
    """multilevel decision → sparse plan → distributed forward == oracle."""
    from jax.sharding import Mesh

    from repro.gnn.distributed import distributed_gcn_forward
    from repro.gnn.layers import gcn_apply, gcn_init
    rng = np.random.default_rng(0)
    state = random_scenario(rng, 12, 12, 20)
    net = costs.default_network(rng, 12, 3)
    d = GraphEdgeController(net=net, policy="greedy",
                            partitioner="multilevel").step(state)
    plan = d.to_partition_plan(num_devices=1)
    params = gcn_init(jax.random.PRNGKey(0), [8, 6, 4])
    x = rng.normal(size=(12, 8)).astype(np.float32)
    mesh = Mesh(np.array(jax.devices()[:1]), ("servers",))
    out = distributed_gcn_forward(mesh, "servers", plan, params, x)
    oracle = np.asarray(gcn_apply(params, jnp.asarray(x), state.adj,
                                  state.mask))
    np.testing.assert_allclose(out, oracle[:out.shape[0]], atol=1e-5)
