"""Model-component unit tests: RoPE, attention masking variants, MoE
routing invariants, Mamba2/RWKV6 decode-vs-chunked equivalence at the
module level, sharding-rule sanity."""
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import attention as A
from repro.models import ffn as F
from repro.models import rwkv as R
from repro.models import ssm as S
from repro.models.config import LayerSpec, ModelConfig, Stage, reduced

KEY = jax.random.PRNGKey(0)


def test_rope_rotation_properties():
    """RoPE preserves norm and makes q·k depend only on relative offset."""
    dh = 32
    q = jax.random.normal(KEY, (1, 1, 1, dh))
    for pos in (0, 5, 100):
        cos, sin = A.rope_cos_sin(jnp.asarray([pos]), dh, 10000.0)
        q_r = A.apply_rope(q, cos, sin)
        np.testing.assert_allclose(float(jnp.linalg.norm(q_r)),
                                   float(jnp.linalg.norm(q)), rtol=1e-5)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, dh))

    def dot_at(pq, pk):
        cq = A.rope_cos_sin(jnp.asarray([pq]), dh, 10000.0)
        ck = A.rope_cos_sin(jnp.asarray([pk]), dh, 10000.0)
        return float(jnp.sum(A.apply_rope(q, *cq) * A.apply_rope(k, *ck)))
    np.testing.assert_allclose(dot_at(3, 1), dot_at(13, 11), rtol=1e-4)


def test_sliding_window_masks_old_keys():
    b, h, s, dh = 1, 1, 16, 8
    q = jax.random.normal(KEY, (b, s, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, dh))
    v = jnp.eye(s)[None, :, None, :].astype(jnp.float32) * 1.0
    v = jnp.broadcast_to(v, (b, s, h, s)).reshape(b, s, h, s)
    out = A._chunked_scores_softmax(q, k, v, offset=0, causal=True,
                                    window=4, softcap=None)
    # output at position 15 must have zero weight on keys ≤ 11
    w = np.asarray(out[0, 15, 0])       # v one-hot ⇒ out = attention weights
    assert w[:12].max() < 1e-6
    assert w[12:16].sum() > 0.999


def test_softcap_bounds_scores():
    s = jnp.linspace(-300, 300, 101)
    capped = 50.0 * jnp.tanh(s / 50.0)
    assert float(jnp.max(jnp.abs(capped))) <= 50.0


def test_moe_fully_routes_small_batches():
    cfg = reduced(get_config("mixtral-8x7b"))
    p = F.moe_init(jax.random.PRNGKey(2), cfg)
    x = 0.1 * jax.random.normal(KEY, (2, 8, cfg.d_model))
    y, aux = F.moe_apply(cfg, p, x)
    assert y.shape == x.shape
    assert np.isfinite(float(aux))
    # zero input → zero expert output (+ shared expert of zero is zero)
    y0, _ = F.moe_apply(cfg, p, jnp.zeros_like(x))
    np.testing.assert_allclose(np.asarray(y0), 0.0, atol=1e-6)


def test_moe_aux_loss_balanced_is_one():
    """Perfectly uniform routing gives aux ≈ 1 (Switch normalization)."""
    cfg = reduced(get_config("mixtral-8x7b"))
    e = cfg.num_experts
    probs = jnp.full((1024, e), 1.0 / e)
    me = probs.mean(0)
    ce = jnp.full((e,), 1.0 / e)
    aux = e * jnp.sum(me * ce)
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-6)


def test_mamba2_decode_matches_chunked():
    cfg = reduced(get_config("zamba2-2.7b"))
    p = S.mamba2_init(jax.random.PRNGKey(3), cfg)
    b, s = 2, 12
    x = 0.1 * jax.random.normal(KEY, (b, s, cfg.d_model))
    full, _ = S.mamba2_apply(cfg, p, x, cache=None)
    cache = S.mamba2_cache_init(cfg, b)
    outs = []
    for t in range(s):
        o, cache = S.mamba2_apply(cfg, p, x[:, t:t + 1], cache=cache)
        outs.append(o)
    err = float(jnp.max(jnp.abs(full - jnp.concatenate(outs, 1))))
    assert err < 1e-4, err


def test_rwkv6_decode_matches_chunked():
    cfg = reduced(get_config("rwkv6-7b"))
    p = R.rwkv6_init(jax.random.PRNGKey(4), cfg)
    b, s = 2, 12
    x = 0.1 * jax.random.normal(KEY, (b, s, cfg.d_model))
    full, _ = R.rwkv6_apply(cfg, p, x, cache=None)
    cache = R.rwkv6_cache_init(cfg, b)
    outs = []
    for t in range(s):
        o, cache = R.rwkv6_apply(cfg, p, x[:, t:t + 1], cache=cache)
        outs.append(o)
    err = float(jnp.max(jnp.abs(full - jnp.concatenate(outs, 1))))
    assert err < 1e-4, err


def test_gqa_cache_window_sizing():
    cfg = reduced(get_config("h2o-danube-1.8b"))
    spec = LayerSpec(mixer="attn", window=4096)
    c = A.gqa_cache_init(cfg, spec, batch=2, max_len=32768)
    assert c["k"].shape[1] == 4096            # ring buffer = window
    spec_full = LayerSpec(mixer="attn", window=None)
    c = A.gqa_cache_init(cfg, spec_full, batch=2, max_len=32768)
    assert c["k"].shape[1] == 32768


def test_mla_cache_is_compressed():
    cfg = get_config("deepseek-v2-lite-16b")
    spec = cfg.stages[0].unit[0]
    c = A.mla_cache_init(cfg, spec, batch=1, max_len=1024)
    per_tok = c["c_kv"].shape[-1] + c["k_rope"].shape[-1]
    full = cfg.num_heads * cfg.head_dim * 2   # uncompressed k+v
    assert per_tok == 512 + 64
    assert per_tok < full / 5                 # >5× cache compression


def test_sharding_rules_divisible():
    """Every full config's param tree gets mesh-divisible specs on a fake
    16×16 mesh (the production single-pod shape)."""
    from repro.launch import shardings as SH

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    mesh = FakeMesh()
    from repro.configs.registry import ARCHS
    from repro.models import transformer as T
    for arch in ARCHS:
        cfg = get_config(arch)
        sds = jax.eval_shape(lambda c=cfg: T.init_params(
            c, jax.random.PRNGKey(0)))
        flat, _ = jax.tree_util.tree_flatten_with_path(sds)
        for path, leaf in flat:
            spec = SH._spec_for_leaf(path, leaf.shape, mesh)
            for dim, axis in zip(leaf.shape, spec):
                if axis is None:
                    continue
                size = 1
                for a in (axis if isinstance(axis, tuple) else (axis,)):
                    size *= mesh.shape[a]
                assert dim % size == 0, (arch, path, leaf.shape, spec)


def test_int8_kv_decode_close_to_forward():
    """§Perf-3: the int8 KV cache decodes within quantization noise."""
    from repro.models import transformer as T
    cfg = reduced(get_config("qwen3-0.6b"))
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 10
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    tf, _ = T.forward(cfg, params, {"tokens": toks, "targets": toks})
    cache = T.init_cache(cfg, b, max_len=s, dtype=jnp.int8)
    outs = []
    for t in range(s):
        lg, cache = T.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                  jnp.int32(t))
        outs.append(lg)
    rel = float(jnp.max(jnp.abs(tf - jnp.concatenate(outs, 1))) /
                jnp.max(jnp.abs(tf)))
    assert rel < 0.05, rel


def test_moe_identical_experts_equal_single_expert():
    """Routing invariant: if every expert has identical weights, the MoE
    output equals that expert's MLP regardless of the routing decisions
    (gates are renormalized to sum to 1)."""
    cfg = reduced(get_config("mixtral-8x7b"))
    cfg = dataclasses.replace(cfg, num_shared_experts=0)
    p = F.moe_init(jax.random.PRNGKey(5), cfg)
    p = dict(p)
    p.pop("shared", None)
    for name in ("we_gate", "we_up", "we_down"):
        first = p[name][0]
        p[name] = jnp.broadcast_to(first, p[name].shape)
    x = 0.1 * jax.random.normal(KEY, (2, 8, cfg.d_model))
    y, _ = F.moe_apply(cfg, p, x)
    dense = {"w_gate": p["we_gate"][0], "w_up": p["we_up"][0],
             "w_down": p["we_down"][0]}
    expect = F.mlp_apply(dense, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               rtol=2e-3, atol=2e-4)
