"""GNN layers + pretraining + min-cut baseline + substrate pieces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import random_edges
from repro.data.graphs import CITESEER, CORA, make_graph, sample_subgraph, \
    random_graph
from repro.gnn.layers import MODELS, gcn_apply, gcn_init, gcn_norm
from repro.gnn.models import pretrain


def small_graph(rng, n=40, din=16):
    edges = random_edges(rng, n, 2 * n)
    adj = np.zeros((n, n), np.float32)
    for i, j in edges:
        adj[i, j] = adj[j, i] = 1.0
    x = rng.normal(size=(n, din)).astype(np.float32)
    return jnp.asarray(adj), jnp.asarray(x)


def test_gcn_matches_closed_form(rng):
    """gcn_apply == Eq. (2): Ψ = Â_norm ReLU(Â_norm X W0) W1."""
    adj, x = small_graph(rng)
    n = adj.shape[0]
    mask = jnp.ones(n)
    params = gcn_init(jax.random.PRNGKey(0), [16, 8, 4])
    out = gcn_apply(params, x, adj, mask)
    a_hat, dinv = gcn_norm(adj, mask)
    a_norm = dinv[:, None] * a_hat * dinv[None, :]
    expect = a_norm @ jax.nn.relu(a_norm @ x @ params[0]["w"]) @ \
        params[1]["w"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("model", list(MODELS))
def test_permutation_equivariance(model, rng):
    """Relabeling vertices permutes outputs identically (GNN invariant)."""
    adj, x = small_graph(rng, n=24)
    n = adj.shape[0]
    mask = jnp.ones(n)
    init, apply = MODELS[model]
    params = init(jax.random.PRNGKey(1), 16, 8, 4)
    out = np.asarray(apply(params, x, adj, mask))
    perm = rng.permutation(n)
    adj_p = adj[perm][:, perm]
    x_p = x[perm]
    out_p = np.asarray(apply(params, x_p, adj_p, mask))
    np.testing.assert_allclose(out_p, out[perm], rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("model", list(MODELS))
def test_masked_vertices_produce_zero(model, rng):
    adj, x = small_graph(rng, n=20)
    mask = jnp.asarray((rng.random(20) > 0.4).astype(np.float32))
    init, apply = MODELS[model]
    params = init(jax.random.PRNGKey(2), 16, 8, 4)
    out = np.asarray(apply(params, x, adj, mask))
    assert np.all(out[np.asarray(mask) == 0] == 0)


@pytest.mark.slow
def test_pretrain_reaches_accuracy_band():
    """Paper §6.1: pre-trained GNNs hit 60–80% node-classification acc."""
    g = sample_subgraph(make_graph(CORA, seed=0), 300, 4800, seed=0)
    model, stats = pretrain("gcn", g, steps=80)
    assert stats["acc_test"] >= 0.5, stats


def test_dataset_specs():
    for spec in (CITESEER, CORA):
        g = make_graph(spec, seed=0)
        assert g.num_vertices == spec.num_vertices
        assert g.num_edges == spec.num_edges
        assert g.features.shape[1] == spec.feature_dim
        deg = g.degrees()
        assert deg.max() > 3 * max(deg.mean(), 1)   # heavy tail (Fig. 5)


def test_sample_subgraph_protocol():
    g = make_graph(CORA, seed=0)
    sub = sample_subgraph(g, 300, 4800, seed=1)
    assert sub.num_vertices == 300
    assert sub.num_edges <= 4800
    assert sub.edges.max() < 300 if sub.num_edges else True
    kb = sub.task_sizes_kb()
    assert (kb <= 1500.0).all()                    # paper's 1500-dim cap


def test_mincut_baseline_partition_valid(rng):
    from repro.core.mincut_baseline import pairwise_mincut_partition
    g = random_graph(60, 150, seed=3)
    w = rng.integers(1, 101, g.num_edges)
    assign = pairwise_mincut_partition(60, g.edges, w, 4)
    assert assign.shape == (60,)
    assert set(np.unique(assign)) <= set(range(4))


def test_dinic_known_maxflow():
    from repro.core.mincut_baseline import Dinic
    # classic 4-node diamond: s=0, t=3, capacities force maxflow 2 per edge set
    g = Dinic(4)
    g.add_edge(0, 1, 3)
    g.add_edge(0, 2, 2)
    g.add_edge(1, 3, 2)
    g.add_edge(2, 3, 3)
    g.add_edge(1, 2, 1)
    # undirected edges → max flow s→t is min cut = 5 (3+2 both saturate t side)
    assert g.max_flow(0, 3) == 5


def test_checkpoint_roundtrip(tmp_path, rng):
    from repro.checkpoint import ckpt
    tree = {"a": jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32)),
            "b": [jnp.ones(2), {"c": jnp.zeros((1,), jnp.int32)}]}
    path = str(tmp_path / "t.npz")
    ckpt.save(path, tree)
    out = ckpt.restore(path, tree)
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(tree["a"]))
    with pytest.raises(ValueError):
        bad = {"a": jnp.zeros((9, 9)), "b": tree["b"]}
        ckpt.restore(path, bad)


def test_token_pipeline_deterministic():
    from repro.data.tokens import TokenDataConfig, token_batches
    cfg = TokenDataConfig(vocab_size=64, seq_len=16, batch_size=4, seed=7)
    a = next(token_batches(cfg))
    b = next(token_batches(cfg))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)
    # targets are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["targets"][:, :-1])


def test_adamw_matches_numpy_reference(rng):
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
    p = {"w": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
    g = {"w": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, grad_clip=None)
    st = adamw_init(p)
    newp, st2 = adamw_update(cfg, g, st, p)
    gw = np.asarray(g["w"])
    m = 0.1 * gw
    v = 0.001 * gw ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    expect = np.asarray(p["w"]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), expect, rtol=5e-4)
