"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device tests spawn subprocesses with their own flags."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_edges(rng, n, e):
    have = set()
    max_e = n * (n - 1) // 2
    e = min(e, max_e)
    while len(have) < e:
        i, j = int(rng.integers(n)), int(rng.integers(n))
        if i != j:
            have.add((min(i, j), max(i, j)))
    return np.array(sorted(have), np.int64).reshape(-1, 2)
