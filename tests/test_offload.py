"""DRLGO (§5): env invariants, MADDPG mechanics, baselines, ablation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costs
from repro.core.dynamic_graph import random_scenario
from repro.core.offload.baselines import run_greedy, run_random
from repro.core.offload.drlgo import (DRLGOTrainer, DRLGOTrainerConfig,
                                      hicut_partition)
from repro.core.offload.env import ACT_DIM, OBS_DIM, OffloadEnv
from repro.core.offload.maddpg import (MADDPGConfig, ReplayBuffer,
                                       actor_forward, critic_forward,
                                       init_maddpg, maddpg_update,
                                       select_actions)
from repro.nnlib.core import tree_polyak


def make_env(seed=0, n=10, m=3, e=15):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, n, n, e)
    net = costs.default_network(rng, n, m)
    return OffloadEnv(net, state, hicut_partition(state), cost_scale=1.0)


def test_constraint_c1_one_server_per_user():
    env = make_env()
    env.reset()
    rng = np.random.default_rng(1)
    while env.t < env.num_steps:
        env.step(rng.random((env.m, ACT_DIM)).astype(np.float32))
    active = np.asarray(env.state.mask) > 0
    assert (env.assign[active] >= 0).all()
    # exactly one server per user: assign is a single int per user ⇒ C1 holds
    assert ((env.assign[active] >= 0) & (env.assign[active] < env.m)).all()


def test_env_respects_capacity_until_forced():
    env = make_env(n=12, m=2)
    env.reset()
    rng = np.random.default_rng(2)
    while env.t < env.num_steps:
        acts = rng.random((env.m, ACT_DIM)).astype(np.float32)
        _, _, _, _, k = env.step(acts)
    # load counts match assignment
    for m in range(env.m):
        assert env.load[m] == (env.assign == m).sum()


def test_reward_is_negative_cost(monkeypatch):
    env = make_env()
    obs, s = env.reset()
    i = env.current_user()
    dc = env.marginal_cost(i, 0)
    rsp = env._r_sp(i, 0)
    acts = np.zeros((env.m, 2), np.float32)
    acts[:, 1] = 1.0
    acts[0, 0] = 2.0
    _, _, rew, _, k = env.step(acts)
    assert k == 0
    assert np.isclose(rew[0], -(dc + rsp), rtol=1e-5)
    assert (rew[1:] == 0).all()


def test_r_sp_grows_with_spread():
    env = make_env(n=12, m=3)
    env.reset()
    c = env.subgraph[env.current_user()]
    members = np.nonzero(env.subgraph == c)[0]
    if len(members) >= 3:
        env.assign[members[1]] = 0
        env.assign[members[2]] = 1
        spread2 = env._r_sp(int(members[0]), 2)   # 3 servers
        tight = env._r_sp(int(members[0]), 0)     # 2 servers
        assert spread2 > tight


def test_obs_shapes():
    env = make_env()
    obs, s = env.reset()
    assert obs.shape == (env.m, OBS_DIM)
    assert s.shape == (env.m * OBS_DIM,)
    assert np.isfinite(obs).all()


# --- MADDPG mechanics -------------------------------------------------------

def test_maddpg_shapes_and_update():
    cfg = MADDPGConfig(n_agents=3, obs_dim=OBS_DIM)
    st = init_maddpg(cfg, jax.random.PRNGKey(0))
    obs = jnp.zeros((3, OBS_DIM))
    acts = select_actions(cfg, st, obs, jax.random.PRNGKey(1))
    assert acts.shape == (3, ACT_DIM)
    assert bool(jnp.all((acts >= 0) & (acts <= 1)))
    buf = ReplayBuffer(cfg)
    for _ in range(cfg.batch_size + 4):
        buf.add(np.zeros((3, OBS_DIM)), np.zeros(3 * OBS_DIM),
                np.random.rand(3, ACT_DIM), np.random.rand(3),
                np.zeros((3, OBS_DIM)), np.zeros(3 * OBS_DIM), False)
    batch = tuple(jnp.asarray(x) for x in buf.sample())
    st2, losses = maddpg_update(cfg, st, batch)
    # params actually moved
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree_util.tree_map(lambda a, b: a - b, st.actor, st2.actor), 0.0)
    assert delta > 0
    assert all(np.isfinite(float(v)) for v in losses.values())


def test_soft_update_formula():
    a = {"w": jnp.ones((2, 2))}
    b = {"w": jnp.zeros((2, 2))}
    out = tree_polyak(a, b, 0.25)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.25)


def test_replay_buffer_wraps():
    cfg = MADDPGConfig(n_agents=2, obs_dim=3, buffer_size=8)
    buf = ReplayBuffer(cfg)
    for i in range(10):
        buf.add(np.full((2, 3), i), np.zeros(6), np.zeros((2, 2)),
                np.zeros(2), np.zeros((2, 3)), np.zeros(6), False)
    assert len(buf) == 8


# --- training + baselines ---------------------------------------------------

@pytest.mark.slow
def test_drlgo_learns_and_beats_random():
    cfg = DRLGOTrainerConfig(capacity=32, n_users=24, n_assoc=60,
                             episodes=40, warmup_steps=128, cost_scale=1.0)
    tr = DRLGOTrainer(cfg)
    tr.train()
    sc = tr.scenario
    drlgo = tr.evaluate(sc)["system_cost"]
    rand = np.mean([run_random(tr.make_env(sc), seed=s)["system_cost"]
                    for s in range(5)])
    assert drlgo < rand * 1.05        # at least on par with random, usually <


def test_greedy_picks_nearest():
    env = make_env()
    run_greedy(env)
    active = np.nonzero(np.asarray(env.state.mask))[0]
    # each user's server is within the nearest-2 by distance (capacity may
    # push past the strict nearest)
    for i in active:
        order = np.argsort(env.d_im[i])
        assert env.assign[i] in order[:3]


def test_dynamic_graph_changes_are_handled():
    cfg = DRLGOTrainerConfig(capacity=24, n_users=16, n_assoc=30, episodes=3,
                             warmup_steps=10_000)   # no updates, just rollouts
    tr = DRLGOTrainer(cfg)
    hist = tr.train()
    assert len(hist) == 3
    assert all(np.isfinite(h["system_cost"]) for h in hist)


def test_drl_only_ablation_runs():
    cfg = DRLGOTrainerConfig(capacity=24, n_users=16, n_assoc=30, episodes=2,
                             use_hicut=False, warmup_steps=10_000)
    tr = DRLGOTrainer(cfg)
    hist = tr.train()
    assert len(hist) == 2
