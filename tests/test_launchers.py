"""Launcher smoke tests: train/serve CLIs + dry-run structural invariants."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_cli(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-m"] + args,
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_launcher_smoke():
    out = run_cli(["repro.launch.train", "--arch", "qwen3-0.6b",
                   "--steps", "4", "--batch", "2", "--seq", "32",
                   "--d-model", "64"])
    assert "loss" in out


def test_serve_launcher_smoke():
    out = run_cli(["repro.launch.serve", "--arch", "qwen3-0.6b",
                   "--prompt-len", "4", "--gen", "4", "--batch", "1",
                   "--d-model", "64", "--kv-int8"])
    assert "generated ids" in out


def test_dryrun_sets_device_flag_before_jax_import():
    """The assignment requires XLA_FLAGS to be set before ANY jax import
    in dryrun.py — assert it structurally."""
    path = os.path.join(SRC, "repro", "launch", "dryrun.py")
    with open(path) as f:
        src = f.read()
    flag_pos = src.index("xla_force_host_platform_device_count=512")
    jax_pos = src.index("import jax")
    assert flag_pos < jax_pos
    # and nothing from repro is imported before the flag either
    assert src.index("from repro") > flag_pos
