"""Lyapunov drift-plus-penalty scheduler (repro.core.offload.lyapunov):
step-for-step parity with the numpy oracle, virtual-queue boundedness,
the V trade-off, and the round-trips through GraphEdgeController /
ServingEngine / the traced jit_step_fn scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costs
from repro.core.api import (GraphEdgeController, JitPolicy,
                            get_offload_policy)
from repro.core.dynamic_graph import (perturb_scenario, random_scenario,
                                      remove_users)
from repro.core.offload.batched_env import make_scene, stack_states
from repro.core.offload.env import OffloadEnv
from repro.core.offload.lyapunov import (lyapunov_rollout_jit,
                                         lyapunov_scan, run_lyapunov)


def scenario(seed=0, capacity=24, users=20, m=3, e=60):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, capacity, users, e)
    net = costs.default_network(rng, capacity, m)
    return state, net


def make_env_and_scene(state, net, ctrl):
    part = ctrl.partition(state)
    env = OffloadEnv(net, state, part, zeta_sp=ctrl.zeta_sp,
                     cost_scale=ctrl.cost_scale)
    scene = make_scene(net, state, part.subgraph, zeta_sp=ctrl.zeta_sp,
                       cost_scale=ctrl.cost_scale)
    return env, scene


# -- registry ----------------------------------------------------------------

def test_registered_as_jit_policy():
    pol = get_offload_policy("lyapunov")
    assert pol.name == "lyapunov"
    assert isinstance(pol, JitPolicy)


# -- parity with the numpy oracle --------------------------------------------

CASES = [
    dict(seed=0, capacity=24, users=20, m=3, e=60),     # inactive tail
    dict(seed=1, capacity=16, users=16, m=4, e=40),     # fully active
    dict(seed=2, capacity=28, users=12, m=2, e=24),     # mostly inactive
    dict(seed=3, capacity=32, users=30, m=3, e=90),     # servers fill up
    dict(seed=4, capacity=14, users=12, m=6, e=24),     # more servers
]


@pytest.mark.parametrize("case", CASES)
def test_scan_matches_numpy_oracle(case):
    """Same scene → identical placements step for step, rewards to f32
    tolerance (the scan and the oracle share the f32 scene arrays)."""
    state, net = scenario(**case)
    ctrl = GraphEdgeController(net=net, policy="lyapunov")
    env, scene = make_env_and_scene(state, net, ctrl)
    stats = run_lyapunov(env)
    assign, reward = jax.jit(lyapunov_rollout_jit)(scene)
    np.testing.assert_array_equal(np.asarray(assign, np.int64), env.assign)
    assert np.isclose(float(reward), stats["reward"], rtol=1e-4, atol=1e-5)


def test_oracle_reports_queue_stats():
    state, net = scenario()
    ctrl = GraphEdgeController(net=net, policy="lyapunov")
    env, _ = make_env_and_scene(state, net, ctrl)
    stats = run_lyapunov(env)
    assert stats["queue_final"].shape == (env.m,)
    assert stats["queue_max"] >= float(stats["queue_final"].max())
    for key in ("system_cost", "t_all", "i_all", "cross_bits"):
        assert key in stats


# -- virtual-queue boundedness ------------------------------------------------

def test_queues_bounded_over_100_step_rollout():
    """100 placements: the largest backlog any virtual queue ever reaches
    stays O(1) — nowhere near the trivial O(num_steps) drift bound."""
    rng = np.random.default_rng(42)
    state = random_scenario(rng, 110, 100, 300)
    net = costs.default_network(rng, 110, 4)
    ctrl = GraphEdgeController(net=net, policy="lyapunov")
    _, scene = make_env_and_scene(state, net, ctrl)
    assert int(scene.num_steps) >= 100
    _, _, q_final, q_max = jax.jit(lyapunov_scan)(scene)
    assert float(q_max) < 3.0
    assert float(q_max) < 0.1 * int(scene.num_steps)
    assert (np.asarray(q_final) >= 0).all()


def test_v_zero_balances_by_capacity_share():
    """V = 0 ignores cost entirely: placements track the servers' fair
    capacity shares, so final loads are near-proportional to capacity."""
    rng = np.random.default_rng(7)
    state = random_scenario(rng, 64, 60, 180)
    net = costs.default_network(rng, 64, 4)
    ctrl = GraphEdgeController(net=net, policy="lyapunov")
    _, scene = make_env_and_scene(state, net, ctrl)
    assign, _, _, _ = lyapunov_scan(scene, v_weight=0.0)
    a = np.asarray(assign)
    load = np.bincount(a[a >= 0], minlength=4).astype(float)
    share = np.asarray(scene.caps) / float(np.asarray(scene.caps).sum())
    np.testing.assert_allclose(load / load.sum(), share, atol=0.05)


# -- controller / engine round-trips ------------------------------------------

def test_controller_step_valid_and_exact_cost():
    state, net = scenario(seed=5, users=18)
    d = GraphEdgeController(net=net, policy="lyapunov").step(state)
    active = np.asarray(state.mask) > 0
    assert ((d.servers[active] >= 0) & (d.servers[active] < 3)).all()
    assert (d.servers[~active] == -1).all()
    w = costs.assignment_onehot(jnp.asarray(d.servers), 3)
    sc = costs.system_cost(net, state, w)
    assert np.isclose(float(d.cost.c), float(sc.c))
    for key in ("system_cost", "t_all", "i_all", "cross_bits"):
        assert key in d.assignment.stats


def test_policy_call_surface_matches_step():
    """The OffloadPolicy __call__(env) surface and the controller's jitted
    dispatch produce the same assignment."""
    state, net = scenario(seed=6)
    ctrl = GraphEdgeController(net=net, policy="lyapunov")
    d = ctrl.step(state)
    env = ctrl.make_env(state)
    a = get_offload_policy("lyapunov")(env)
    np.testing.assert_array_equal(a.servers, d.servers)
    assert np.isclose(a.reward, d.assignment.reward, rtol=1e-5)


def test_empty_scene_all_inactive():
    state, net = scenario(users=2)
    empty = remove_users(state, jnp.ones(state.capacity, jnp.float32))
    d = GraphEdgeController(net=net, policy="lyapunov").step(empty)
    assert (d.servers == -1).all()
    assert d.assignment.reward == 0.0


def test_serving_engine_roundtrip():
    """lyapunov decisions drive the pipelined engine; outputs match the
    single-device oracle across a perturbed request stream."""
    from jax.sharding import Mesh

    from repro.gnn.layers import gcn_apply, gcn_init
    from repro.serve import ServeRequest, ServingEngine

    rng = np.random.default_rng(0)
    capacity = 20
    state = random_scenario(rng, capacity, 16, 48)
    net = costs.default_network(rng, capacity, 3)
    params = gcn_init(jax.random.PRNGKey(0), [8, 6, 4])
    mesh = Mesh(np.array(jax.devices()[:1]), ("servers",))
    engine = ServingEngine(
        controller=GraphEdgeController(net=net, policy="lyapunov"),
        params=params, mesh=mesh, num_devices=1)
    reqs = []
    for t in range(3):
        if t:
            state = perturb_scenario(rng, state, 0.3)
        x = rng.normal(size=(capacity, 8)).astype(np.float32)
        reqs.append(ServeRequest(state, x))
    for res in engine.serve(reqs):
        st = res.request.state
        oracle = np.asarray(gcn_apply(params, jnp.asarray(res.request.x),
                                      st.adj, st.mask))
        served = np.nonzero(np.asarray(st.mask) > 0)[0]
        assert np.abs(res.output[served] - oracle[served]).max() < 1e-4


# -- the traced end-to-end scan (PR 4-style zero-numpy test) ------------------

def test_jit_step_fn_traced_scan_rollout():
    """partition → lyapunov scan → cost traces as one XLA computation
    (any numpy round-trip would raise a TracerError) and matches eager."""
    state, net = scenario(seed=8, users=14)
    ctrl = GraphEdgeController(net=net, policy="lyapunov",
                               partitioner="hicut_jax")
    fn = ctrl.jit_step_fn()
    rng = np.random.default_rng(9)
    states = [state]
    for _ in range(2):
        states.append(perturb_scenario(rng, states[-1], 0.3))
    stacked = stack_states(states)

    @jax.jit
    def roll(sts):
        def body(carry, st):
            res = fn(st)
            return carry + res.cost.c, res.servers
        return jax.lax.scan(body, jnp.zeros(()), sts)

    total, servers = roll(stacked)
    eager = [ctrl.step(s) for s in states]
    assert np.isclose(float(total),
                      sum(float(d.cost.c) for d in eager), rtol=1e-5)
    for i, d in enumerate(eager):
        np.testing.assert_array_equal(np.asarray(servers[i]), d.servers)
