"""Hypothesis import shim for the property-based tests.

``requirements-dev.txt`` declares the real dependency; when hypothesis is
installed the import below re-exports it untouched. On bare installs (no
dev extras) we fall back to a small deterministic sampler so that
``pytest -q`` still collects and runs every module: each ``@given`` test
executes up to 10 examples drawn from a fixed-seed generator instead of
hypothesis' shrinking search. The fallback supports exactly the strategy
surface this suite uses (``st.integers``, ``st.sampled_from``).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _FALLBACK_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

    st = _Strategies()

    def settings(max_examples: int = _FALLBACK_MAX_EXAMPLES, **_ignored):
        def deco(wrapper):
            wrapper._max_examples = max_examples
            return wrapper
        return deco

    def given(*strategies):
        def deco(fn):
            # zero-arg wrapper (no functools.wraps: pytest must not see the
            # generated-argument signature and treat the names as fixtures)
            def wrapper():
                rng = np.random.default_rng(0)
                n = min(getattr(wrapper, "_max_examples",
                                _FALLBACK_MAX_EXAMPLES),
                        _FALLBACK_MAX_EXAMPLES)
                for _ in range(n):
                    fn(*(s.sample(rng) for s in strategies))
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
