"""Fault injection + live migration (DESIGN.md §9).

Covers the whole chaos path: schedule construction/parsing determinism,
per-server network degradation (``ServerProfile``/``degrade_network``),
the offload scheduler refusing a down server, the injector's cumulative
profile state machine, the engine's network-keyed plan cache and
drain-then-swap migration (bitwise equal to per-phase fresh oracles), the
streaming front-end's migration ledger (conservation:
``admitted + rejected + deferred + migrated == submitted`` with zero lost
requests and a deterministic trace), and the warm-started multilevel
re-cut. The slow lane runs the ``serve_stream --faults`` CLI end to end.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import costs
from repro.core.api import GraphEdgeController
from repro.core.dynamic_graph import (EVENT_ARRIVE, EVENT_DEPART,
                                      EVENT_SERVER_DOWN, EVENT_SERVER_UP,
                                      GraphEvent, random_scenario)
from repro.core.multilevel import multilevel_partition
from repro.gnn.layers import gcn_init
from repro.serve import (FaultInjector, FaultSchedule, ManualClock,
                         ServeRequest, ServingEngine, StreamRequest,
                         StreamingFrontend, network_digest, poisson_workload)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def scenario(seed=0, capacity=24, users=18, servers=4):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, capacity, users, 2 * users)
    net = costs.default_network(rng, capacity, servers)
    return state, net, rng


def make_engine(net, seed=0, devices=1, **kw):
    ctrl = GraphEdgeController(net=net, policy="greedy_jit",
                               partitioner="hicut_jax")
    params = gcn_init(jax.random.PRNGKey(seed), [8, 6, 4])
    mesh = Mesh(np.array(jax.devices()[:devices]), ("servers",))
    return ServingEngine(controller=ctrl, params=params, mesh=mesh, **kw)


# -- FaultSchedule -----------------------------------------------------------

def test_schedule_parse_roundtrip_and_sort():
    sched = FaultSchedule.parse("5:server_up:1,2:server_down:1,3:arrive:4")
    assert [ev.cycle for ev in sched] == [2, 3, 5]       # sorted
    assert sched.events[0] == GraphEvent(2, EVENT_SERVER_DOWN, server=1,
                                         scale=0.5)
    assert sched.events[1] == GraphEvent(3, EVENT_ARRIVE, count=4)
    assert len(sched) == 3
    assert sched == FaultSchedule.parse("2:server_down:1,3:arrive:4,"
                                        "5:server_up:1")


def test_schedule_parse_defaults_and_degrade_scale():
    sched = FaultSchedule.parse("1:arrive,2:depart,3:degrade:2:0.25")
    assert sched.events[0].count == 1                    # user default arg
    assert sched.events[1].count == 1
    ev = sched.events[2]
    assert (ev.server, ev.scale) == (2, 0.25)


def test_schedule_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown event kind"):
        FaultSchedule([GraphEvent(0, "reboot")])
    with pytest.raises(ValueError, match="bad fault item"):
        FaultSchedule.parse("nonsense")


def test_schedule_random_is_deterministic_and_consistent():
    a = FaultSchedule.random(7, cycles=40, num_servers=4)
    b = FaultSchedule.random(7, cycles=40, num_servers=4)
    assert a == b
    assert a != FaultSchedule.random(8, cycles=40, num_servers=4)
    # downs and ups alternate per server: a server never goes down twice
    # without recovering in between
    down = set()
    for ev in a.server_events():
        if ev.kind == EVENT_SERVER_DOWN:
            assert ev.server not in down
            down.add(ev.server)
        elif ev.kind == EVENT_SERVER_UP:
            assert ev.server in down
            down.discard(ev.server)


def test_schedule_views_partition_the_events():
    sched = FaultSchedule.parse("1:server_down:0,1:arrive:2,4:server_up:0")
    assert [ev.kind for ev in sched.user_events()] == [EVENT_ARRIVE]
    assert [ev.kind for ev in sched.server_events()] == [EVENT_SERVER_DOWN,
                                                         EVENT_SERVER_UP]
    assert len(sched.events_at(1)) == 2 and not sched.events_at(3)


# -- ServerProfile / degrade_network -----------------------------------------

def test_degrade_network_down_server_unreachable():
    _, net, _ = scenario()
    m = int(net.f_k.shape[0])
    prof = costs.ServerProfile.healthy(m)
    prof = prof._replace(up=prof.up.at[1].set(0.0))
    deg = costs.degrade_network(net, prof)
    assert float(deg.capacity[1]) == 0.0
    assert np.all(np.asarray(deg.B_im)[:, 1] == 0.0)     # no uplink to it
    assert np.all(np.asarray(deg.eta_kl)[1, :] == 0.0)   # no backhaul
    assert np.all(np.asarray(deg.eta_kl)[:, 1] == 0.0)
    # healthy servers keep their base pricing
    keep = [k for k in range(m) if k != 1]
    np.testing.assert_array_equal(np.asarray(deg.capacity)[keep],
                                  np.asarray(net.capacity)[keep])


def test_degrade_network_scales_compute_and_energy():
    _, net, _ = scenario()
    m = int(net.f_k.shape[0])
    prof = costs.ServerProfile.healthy(m)
    prof = prof._replace(compute_scale=prof.compute_scale.at[0].set(0.5),
                         capacity_scale=prof.capacity_scale.at[0].set(0.5),
                         energy_scale=prof.energy_scale.at[0].set(2.0))
    deg = costs.degrade_network(net, prof)
    np.testing.assert_allclose(float(deg.f_k[0]),
                               max(float(net.f_k[0]) * 0.5, 1.0))
    np.testing.assert_allclose(float(deg.capacity[0]),
                               float(net.capacity[0]) * 0.5)
    # zeta broadcast to arrays, energy doubled on the degraded sender only
    zim = np.broadcast_to(np.asarray(net.zeta_im, np.float32), (m,))
    np.testing.assert_allclose(np.asarray(deg.zeta_im)[0], zim[0] * 2.0,
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(deg.zeta_im)[1:], zim[1:],
                               rtol=1e-6)
    assert np.asarray(deg.zeta_kl).shape == (m, m)


def test_offload_avoids_down_server():
    """The jitted greedy scheduler must never place a user on a
    zero-capacity (down) server — the ``done_m`` reset covers servers that
    are full *from step 0*."""
    state, net, _ = scenario()
    m = int(net.f_k.shape[0])
    prof = costs.ServerProfile.healthy(m)
    prof = prof._replace(up=prof.up.at[2].set(0.0))
    deg = costs.degrade_network(net, prof)
    ctrl = GraphEdgeController(net=deg, policy="greedy_jit",
                               partitioner="hicut_jax")
    decision = ctrl.step(state)
    servers = np.asarray(decision.servers)
    active = np.asarray(state.mask) > 0
    assert not np.any(servers[active] == 2), \
        "user offloaded to a down server"
    assert np.all(servers[active] >= 0)


# -- FaultInjector -----------------------------------------------------------

def test_injector_down_up_restores_healthy_pricing():
    state, net, _ = scenario()
    m = int(net.f_k.shape[0])
    sched = FaultSchedule.parse("1:server_down:1,3:degrade:0:0.5,"
                                "5:server_up:1,5:server_up:0")
    inj = FaultInjector(sched, net)
    up1 = inj.poll(1)
    assert up1.num_up == m - 1 and up1.net is not None
    assert float(up1.net.capacity[1]) == 0.0
    up3 = inj.poll(3)
    assert up3.num_up == m - 1
    np.testing.assert_allclose(float(up3.net.capacity[0]),
                               float(net.capacity[0]) * 0.5)
    up5 = inj.poll(5)
    assert up5.num_up == m
    healthy = costs.degrade_network(net, costs.ServerProfile.healthy(m))
    assert network_digest(up5.net) == network_digest(healthy)


def test_injector_cursor_applies_skipped_cycles_once():
    state, net, _ = scenario()
    sched = FaultSchedule.parse("1:arrive:3,2:depart:1,6:arrive:2")
    inj = FaultInjector(sched, net, state=state, seed=0)
    assert inj.poll(0) is None
    upd = inj.poll(4)            # clock skipped 1..4: both events apply
    assert [ev.cycle for ev in upd.events] == [1, 2]
    assert upd.net is None and upd.state is not None
    assert inj.poll(5) is None   # nothing due, nothing re-applied
    upd6 = inj.poll(6)
    assert [ev.cycle for ev in upd6.events] == [6]
    assert len(inj.applied) == 3


def test_injector_user_churn_is_seed_deterministic():
    state, net, _ = scenario()
    sched = FaultSchedule.parse("1:arrive:4,2:depart:2,3:arrive:1")
    outs = []
    for _ in range(2):
        inj = FaultInjector(sched, net, state=state, seed=11)
        for c in range(4):
            inj.poll(c)
        outs.append(inj.state)
    for a, b in zip(outs[0], outs[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- engine: network-keyed plan cache + drain-then-swap ----------------------

def test_plan_cache_missed_after_network_swap_and_restored():
    """Regression for the stale-plan bug: entries keyed only on
    (topology, assignment) survived capacity changes. The network digest
    in the key makes a swap miss, and swapping the original network back
    hits the old entry again."""
    state, net, rng = scenario()
    engine = make_engine(net)
    _, e0, hit0 = engine.decide_entry(state)
    _, e1, hit1 = engine.decide_entry(state)
    assert not hit0 and hit1 and e1 is e0

    m = int(net.f_k.shape[0])
    prof = costs.ServerProfile.healthy(m)
    prof = prof._replace(up=prof.up.at[1].set(0.0))
    engine.swap_network(costs.degrade_network(net, prof))
    _, e2, hit2 = engine.decide_entry(state)
    assert not hit2 and e2.key != e0.key                 # repriced → rebuilt
    assert engine.net_swaps == 1

    engine.swap_network(net)                             # server recovered
    _, e3, hit3 = engine.decide_entry(state)
    assert hit3 and e3 is e0                             # old pricing aliases


def test_engine_drain_then_swap_matches_per_phase_oracles():
    """Mid-stream server-down: every request before the fault must equal a
    fresh engine on the base network bitwise; every request after it must
    equal a fresh engine on the degraded network bitwise. Nothing lost,
    order preserved."""
    state, net, rng = scenario()
    m = int(net.f_k.shape[0])
    xs = [rng.normal(size=(state.capacity, 8)).astype(np.float32)
          for _ in range(5)]
    reqs = [ServeRequest(state, x) for x in xs]

    sched = FaultSchedule.parse("2:server_down:1")
    inj = FaultInjector(sched, net)
    results = make_engine(net).serve_all(reqs, faults=inj)
    assert [r.step for r in results] == [0, 1, 2, 3, 4]  # none lost

    prof = costs.ServerProfile.healthy(m)
    deg = costs.degrade_network(net, prof._replace(up=prof.up.at[1].set(0.0)))
    base_oracle = make_engine(net).serve_all(reqs[:2])
    deg_oracle = make_engine(deg).serve_all(reqs[2:])
    for got, want in zip(results[:2], base_oracle):
        np.testing.assert_array_equal(got.output, want.output)
        np.testing.assert_array_equal(np.asarray(got.decision.servers),
                                      np.asarray(want.decision.servers))
    for got, want in zip(results[2:], deg_oracle):
        np.testing.assert_array_equal(got.output, want.output)
        np.testing.assert_array_equal(np.asarray(got.decision.servers),
                                      np.asarray(want.decision.servers))
    active = np.asarray(state.mask) > 0
    for r in results[2:]:
        assert not np.any(np.asarray(r.decision.servers)[active] == 1)


# -- frontend: migration ledger + deterministic trace ------------------------

def _faulted_frontend_run(spec="2:server_down:1,5:server_up:1", count=12):
    state, net, _ = scenario()
    engine = make_engine(net)
    inj = FaultInjector(FaultSchedule.parse(spec), net, seed=0)
    fe = StreamingFrontend(engine=engine, clock=ManualClock(tick_per_now=0.02),
                           faults=inj, max_batch=4)
    rng = np.random.default_rng(3)
    x = rng.standard_normal((state.capacity, 8)).astype(np.float32)
    wl = poisson_workload(np.random.default_rng(1), rate=5.0, count=count,
                          make_request=lambda i: StreamRequest(state=state,
                                                               x=x))
    results = fe.run(wl)
    return fe, results


def test_frontend_migration_conserves_requests():
    fe, results = _faulted_frontend_run()
    stats = fe.stats
    assert stats.conservation_ok
    assert stats.submitted == 12 and stats.served == len(results) == 12
    assert stats.requests_migrated > 0                   # fault hit the queue
    assert stats.migrated_served == stats.requests_migrated  # none lost
    assert stats.migrated == 0 and stats.deferred == 0   # fully drained
    assert fe.engine.net_swaps == 2
    for rec in fe.fault_trace:
        assert rec["recovery_cycles"] >= 1               # always recovered
        assert rec["migrated"] == rec["queued"]


def test_frontend_fault_trace_and_outputs_deterministic():
    """Same seed + same schedule ⇒ identical migration trace and
    bitwise-identical served outputs (the acceptance contract)."""
    fe_a, res_a = _faulted_frontend_run()
    fe_b, res_b = _faulted_frontend_run()
    assert fe_a.fault_trace == fe_b.fault_trace
    assert fe_a.stats.as_dict() == fe_b.stats.as_dict()
    a = {r.rid: r.output for r in res_a}
    b = {r.rid: r.output for r in res_b}
    assert a.keys() == b.keys()
    for rid in a:
        np.testing.assert_array_equal(a[rid], b[rid])


def test_frontend_without_faults_keeps_legacy_invariant():
    fe, results = _faulted_frontend_run(spec="", count=6)
    # empty spec parses to an empty schedule: no events, no migrations
    assert fe.stats.requests_migrated == 0
    assert fe.stats.migrated == 0
    assert fe.stats.conservation_ok
    assert not fe.fault_trace and fe.engine.net_swaps == 0


# -- warm-started multilevel re-cut ------------------------------------------

def _cut_weight(edges, assign):
    a = assign[edges[:, 0]]
    b = assign[edges[:, 1]]
    return int(np.sum((a >= 0) & (b >= 0) & (a != b)))


def test_multilevel_warm_start_respects_capacity_and_k():
    state, _, rng = scenario(capacity=48, users=40)
    from repro.core.api import state_edges
    edges = state_edges(state)
    active = np.asarray(state.mask) > 0
    n = state.capacity
    cold = multilevel_partition(n, edges, 4, active=active)
    # shrink to 3 parts warm-started from the 4-part cut (server down)
    warm = multilevel_partition(n, edges, 3, active=active, initial=cold)
    assert np.all(warm[active] >= 0) and np.all(warm[active] < 3)
    assert np.all(warm[~active] == -1)
    na = int(active.sum())
    cap = int(np.ceil(1.1 * na / 3.0))
    counts = np.bincount(warm[active], minlength=3)
    assert np.all(counts <= cap), (counts, cap)
    # deterministic
    again = multilevel_partition(n, edges, 3, active=active, initial=cold)
    np.testing.assert_array_equal(warm, again)


def test_multilevel_warm_start_refines_not_degrades():
    """Warm refinement from a same-k previous cut never produces a worse
    edge cut than the seed it started from."""
    state, _, rng = scenario(capacity=48, users=40)
    from repro.core.api import state_edges
    edges = state_edges(state)
    active = np.asarray(state.mask) > 0
    n = state.capacity
    cold = multilevel_partition(n, edges, 4, active=active)
    warm = multilevel_partition(n, edges, 4, active=active, initial=cold)
    assert _cut_weight(edges, warm) <= _cut_weight(edges, cold)


def test_recut_warm_installs_into_partition_cache():
    state, net, _ = scenario()
    ctrl = GraphEdgeController(net=net, policy="greedy_jit",
                               partitioner="hicut_jax")
    first = ctrl.step(state)                 # hicut cut now cached
    ctrl.invalidate_partitions()
    part = ctrl.recut_warm(state, np.asarray(first.partition.subgraph),
                           num_parts=3)
    assert part.method == "multilevel_warm"
    hits_before = ctrl.cache_hits
    after = ctrl.step(state)                 # must reuse the warm cut
    assert ctrl.cache_hits == hits_before + 1
    assert after.partition.method == "multilevel_warm"
    np.testing.assert_array_equal(np.asarray(after.partition.subgraph),
                                  np.asarray(part.subgraph))


# -- CLI (slow lane) ---------------------------------------------------------

@pytest.mark.slow
def test_serve_stream_faults_cli():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve_stream", "--devices", "2",
         "--users", "16", "--count", "12", "--arrival-rate", "40",
         "--deadline", "0", "--admission", "admit_all", "--max-batch", "2",
         "--faults", "1:server_down:1,2:arrive:3,4:server_up:1"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "conservation=ok" in out.stdout
    assert "faults:" in out.stdout and "net_swaps=2" in out.stdout
