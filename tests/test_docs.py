"""Docs stay runnable: fenced snippets compile, documented CLI flags exist.

Mirrors the CI docs lane (``tools/check_docs.py``) inside tier-1 so a
README/DESIGN edit that drifts from the actual CLIs fails locally too.
"""
import importlib.util
import pathlib


def _load_checker():
    root = pathlib.Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "check_docs", root / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_doc_snippets_and_cli_flags_exist():
    checker = _load_checker()
    errors = checker.collect_errors()
    assert not errors, "\n".join(errors)


def test_checker_catches_bad_flag(tmp_path, monkeypatch):
    checker = _load_checker()
    errors = []
    checker.check_command(
        "README.md",
        "PYTHONPATH=src python examples/train_drlgo.py --no-such-flag",
        errors)
    assert errors and "--no-such-flag" in errors[0]


def test_checker_sees_registered_backends():
    """The register_* call-site scan resolves every shipped backend."""
    checker = _load_checker()
    names = checker.registered_names()
    assert {"hicut_jax", "mincut", "multilevel", "multilevel_jax",
            "greedy_jit", "lyapunov", "drlgo"} <= names


def test_checker_catches_unregistered_doc_name():
    checker = _load_checker()
    errors = []
    text = ("```sh\nPYTHONPATH=src python -m repro.launch.serve_gnn "
            "--policy no_such_policy\n```\n")
    checker.check_registry_names("DOC.md", text,
                                 checker.registered_names(), errors)
    assert errors and "no_such_policy" in errors[0]
    # registry-table extraction: first column of "registry name" tables
    table = ("| registry name | notes |\n|---|---|\n"
             "| `phantom_cut` | nope |\n")
    names = checker.documented_registry_names(table)
    assert names == {"phantom_cut"}
    # a different table stacked directly underneath must not leak
    stacked = (table + "| file | meaning |\n|---|---|\n"
               "| `not_a_backend` | other table |\n")
    assert checker.documented_registry_names(stacked) == {"phantom_cut"}


def test_checker_catches_launch_table_drift(tmp_path):
    """A runnable launch module missing from the entry-point table (or a
    ghost row) fails the launch-table check."""
    checker = _load_checker()
    errors = []
    checker.check_launch_table(errors)
    assert not errors, errors               # the shipped table is in sync
    launch = tmp_path / "launch"
    launch.mkdir()
    (launch / "__init__.py").write_text(
        '"""Entry points.\n\n| ``ghost`` | lane | uses ``--nope`` |\n"""\n')
    (launch / "orphan.py").write_text("def main():\n    pass\n")
    old = checker.LAUNCH_INIT
    checker.LAUNCH_INIT = launch / "__init__.py"
    try:
        errors = []
        checker.check_launch_table(errors)
    finally:
        checker.LAUNCH_INIT = old
    joined = "\n".join(errors)
    assert "ghost" in joined and "orphan" in joined
