"""Docs stay runnable: fenced snippets compile, documented CLI flags exist.

Mirrors the CI docs lane (``tools/check_docs.py``) inside tier-1 so a
README/DESIGN edit that drifts from the actual CLIs fails locally too.
"""
import importlib.util
import pathlib


def _load_checker():
    root = pathlib.Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "check_docs", root / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_doc_snippets_and_cli_flags_exist():
    checker = _load_checker()
    errors = checker.collect_errors()
    assert not errors, "\n".join(errors)


def test_checker_catches_bad_flag(tmp_path, monkeypatch):
    checker = _load_checker()
    errors = []
    checker.check_command(
        "README.md",
        "PYTHONPATH=src python examples/train_drlgo.py --no-such-flag",
        errors)
    assert errors and "--no-such-flag" in errors[0]
