"""Control-plane API (repro.core.api): registries, partitioner parity,
controller ↔ legacy-facade equivalence, and the decision → serving bridge."""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, costs
from repro.core.api import (Assignment, Decision, GraphEdgeController,
                            Partition, available_offload_policies,
                            available_partitioners, get_offload_policy,
                            get_partitioner)
from repro.core.dynamic_graph import (move_users, random_scenario,
                                      remove_users)
from repro.core.offload.drlgo import (DRLGOTrainer, DRLGOTrainerConfig,
                                      hicut_partition)
from repro.core.offload.env import OffloadEnv

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def small(seed=0, n=16, users=12, m=3, e=24):
    rng = np.random.default_rng(seed)
    state = random_scenario(rng, n, users, e)
    net = costs.default_network(rng, n, m)
    return state, net


# -- registries --------------------------------------------------------------

def test_registry_contents():
    assert set(available_partitioners()) >= {"hicut_jax", "hicut_ref",
                                             "mincut", "none"}
    assert set(available_offload_policies()) >= {"drlgo", "ppo", "greedy",
                                                 "random", "local"}


def test_registry_lookup_by_name():
    for name in ("hicut_jax", "hicut_ref", "none"):
        p = get_partitioner(name)
        assert p.name == name
    assert get_partitioner("mincut", num_parts=3).num_parts == 3
    for name in ("greedy", "local"):
        assert get_offload_policy(name).name == name
    assert get_offload_policy("random", seed=7).seed == 7


def test_unknown_names_raise_with_options():
    with pytest.raises(ValueError, match="hicut_jax"):
        get_partitioner("does-not-exist")
    with pytest.raises(ValueError, match="greedy"):
        get_offload_policy("does-not-exist")


def test_registration_decorator():
    @api.register_partitioner("_test_constant")
    class _Const:
        name = "_test_constant"

        def __call__(self, state):
            sub = np.where(np.asarray(state.mask) > 0, 0, -1).astype(np.int64)
            return Partition(sub, self.name)
    try:
        state, _ = small()
        part = get_partitioner("_test_constant")(state)
        assert part.num_subgraphs == 1
    finally:
        del api._PARTITIONERS["_test_constant"]


# -- partitioners ------------------------------------------------------------

def _canonical(labels):
    """Relabel subgraph ids by first appearance (for relabel-invariance)."""
    out = np.full(len(labels), -1, np.int64)
    seen = {}
    for i, v in enumerate(labels):
        if v >= 0:
            out[i] = seen.setdefault(v, len(seen))
    return out


def test_hicut_jax_matches_ref_through_interface():
    for seed in range(5):
        state, _ = small(seed=seed, n=20, users=14 + seed, e=30)
        ref = get_partitioner("hicut_ref")(state)
        jx = get_partitioner("hicut_jax")(state)
        np.testing.assert_array_equal(_canonical(ref.subgraph),
                                      _canonical(jx.subgraph))
        assert ref.cut_metrics["cross_edges"] == jx.cut_metrics["cross_edges"]


def test_partitioners_respect_mask():
    state, _ = small(users=10, n=16)
    active = np.asarray(state.mask) > 0
    for name in ("hicut_jax", "hicut_ref", "mincut", "none"):
        part = get_partitioner(name)(state)
        assert (part.subgraph[~active] == -1).all(), name
        assert (part.subgraph[active] >= 0).all(), name


def test_none_partitioner_isolates_vertices():
    state, _ = small()
    part = get_partitioner("none")(state)
    act = part.subgraph[part.subgraph >= 0]
    assert len(np.unique(act)) == len(act)
    assert part.cut_metrics["cut_fraction"] == 1.0 or \
        part.cut_metrics["total_edges"] == 0


def test_partition_device_assignment():
    state, _ = small()
    part = get_partitioner("hicut_ref")(state)
    dev = part.to_device_assignment(2)
    active = np.asarray(state.mask) > 0
    assert ((dev[active] >= 0) & (dev[active] < 2)).all()
    assert (dev[~active] == -1).all()


# -- controller --------------------------------------------------------------

def test_controller_step_valid_assignment():
    state, net = small()
    active = np.asarray(state.mask) > 0
    for policy in ("greedy", "random", "local"):
        d = GraphEdgeController(net=net, policy=policy).step(state)
        assert ((d.servers[active] >= 0) & (d.servers[active] < 3)).all()
        assert (d.servers[~active] == -1).all()
        # reported cost is exactly the Eqs. 12–14 batch model
        w = costs.assignment_onehot(jnp.asarray(d.servers), 3)
        sc = costs.system_cost(net, state, w)
        assert np.isclose(float(d.cost.c), float(sc.c))


def test_controller_matches_legacy_offload_path():
    """GraphEdgeController.step == the old GraphEdge.offload wiring
    (hicut_ref + deterministic MADDPG rollout) on a fixed seed."""
    cfg = DRLGOTrainerConfig(capacity=16, n_users=12, n_assoc=24,
                             n_servers=3, episodes=1, seed=3)
    tr = DRLGOTrainer(cfg)
    state = tr.scenario
    # legacy path, reconstructed verbatim from the pre-API facade
    sub = hicut_partition(state)
    env = OffloadEnv(tr.net, state, sub, zeta_sp=cfg.zeta_sp,
                     cost_scale=cfg.cost_scale)
    legacy = tr.run_episode(env, explore=False, learn=False)
    legacy_assign = env.assign.copy()

    ctrl = GraphEdgeController(net=tr.net, policy="drlgo",
                               policy_kwargs={"trainer": tr},
                               partitioner="hicut_ref",
                               zeta_sp=cfg.zeta_sp,
                               cost_scale=cfg.cost_scale)
    d = ctrl.step(state)
    np.testing.assert_array_equal(d.servers, legacy_assign)
    assert np.isclose(float(d.cost.c), legacy["system_cost"])
    assert np.isclose(d.assignment.reward, legacy["reward"])


def test_graphedge_shim_deprecated_but_equivalent():
    from repro.core.system import GraphEdge
    cfg = DRLGOTrainerConfig(capacity=12, n_users=9, n_assoc=15,
                             n_servers=3, episodes=1, seed=1)
    tr = DRLGOTrainer(cfg)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        system = GraphEdge(tr)
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    res = system.offload(tr.scenario)
    ctrl = GraphEdgeController(net=tr.net, policy=tr.as_policy(),
                               partitioner="hicut_ref",
                               zeta_sp=cfg.zeta_sp, cost_scale=cfg.cost_scale)
    d = ctrl.step(tr.scenario)
    np.testing.assert_array_equal(res["assignment"], d.servers)
    assert np.isclose(res["system_cost"], float(d.cost.c))
    assert res["num_subgraphs"] == d.partition.num_subgraphs


def test_partition_cache_hits_on_pure_mobility():
    state, net = small()
    ctrl = GraphEdgeController(net=net, policy="greedy")
    ctrl.step(state)
    ctrl.step(state)
    moved = move_users(state, state.pos + 10.0)
    ctrl.step(moved)                               # same topology → hit
    assert (ctrl.cache_hits, ctrl.cache_misses) == (2, 1)
    drop = np.zeros(state.capacity, np.float32)
    drop[0] = 1.0
    ctrl.step(remove_users(state, jnp.asarray(drop)))   # topology changed
    assert ctrl.cache_misses == 2


def test_partition_cache_info_counters():
    """cache_info() mirrors the hit/miss attributes and reports the LRU
    bounds; revisiting an older cached topology is a hit (multi-entry)."""
    state, net = small()
    ctrl = GraphEdgeController(net=net, policy="greedy")
    assert ctrl.cache_info() == api.CacheInfo(0, 0, ctrl.cache_size, 0)
    ctrl.step(state)
    drop = np.zeros(state.capacity, np.float32)
    drop[0] = 1.0
    other = remove_users(state, jnp.asarray(drop))
    ctrl.step(other)
    ctrl.step(state)                        # older topology still cached
    info = ctrl.cache_info()
    assert (info.hits, info.misses, info.currsize) == (1, 2, 2)
    assert (ctrl.cache_hits, ctrl.cache_misses) == (info.hits, info.misses)


def test_rollout_drives_dynamic_model():
    state, net = small()
    ctrl = GraphEdgeController(net=net, policy="greedy")
    decisions = ctrl.rollout(state, 4, np.random.default_rng(0))
    assert len(decisions) == 4
    for d in decisions:
        assert isinstance(d, Decision)
        assert np.isfinite(float(d.cost.c))
    # perturbation must actually change the scenario between steps
    assert any(not np.array_equal(np.asarray(decisions[i].state.adj),
                                  np.asarray(decisions[i + 1].state.adj))
               for i in range(3))


def test_trainer_consumes_partitioner_registry():
    cfg = DRLGOTrainerConfig(capacity=12, n_users=9, n_assoc=15,
                             n_servers=3, partitioner="none")
    tr = DRLGOTrainer(cfg)
    env = tr.make_env(tr.scenario)
    assert env.use_subgraph_reward is False
    act = env.subgraph[np.asarray(tr.scenario.mask) > 0]
    assert len(np.unique(act)) == len(act)       # every vertex isolated
    legacy = DRLGOTrainerConfig(use_hicut=False)
    assert legacy.partitioner_name == "none"
    assert DRLGOTrainerConfig().partitioner_name == "hicut_ref"


# -- decision → serving bridge ----------------------------------------------

def test_to_partition_plan_roundtrip_single_device():
    """Controller decision → plan → distributed forward == gcn_apply."""
    from jax.sharding import Mesh
    from repro.gnn.distributed import distributed_gcn_forward
    from repro.gnn.layers import gcn_apply, gcn_init
    rng = np.random.default_rng(0)
    state = random_scenario(rng, 12, 12, 20)      # fully active
    net = costs.default_network(rng, 12, 3)
    d = GraphEdgeController(net=net, policy="greedy").step(state)
    plan = d.to_partition_plan(num_devices=1)
    params = gcn_init(jax.random.PRNGKey(0), [8, 6, 4])
    x = rng.normal(size=(12, 8)).astype(np.float32)
    mesh = Mesh(np.array(jax.devices()[:1]), ("servers",))
    out = distributed_gcn_forward(mesh, "servers", plan, params, x)
    oracle = np.asarray(gcn_apply(params, jnp.asarray(x), state.adj,
                                  state.mask))
    np.testing.assert_allclose(out, oracle[:out.shape[0]], atol=1e-5)


@pytest.mark.slow
def test_to_partition_plan_roundtrip_multidevice():
    """Same round-trip on a real 4-device mesh (subprocess, virtual CPUs)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import costs
        from repro.core.api import GraphEdgeController
        from repro.core.dynamic_graph import random_scenario
        from repro.gnn.distributed import distributed_gcn_forward
        from repro.gnn.layers import gcn_apply, gcn_init
        rng = np.random.default_rng(1)
        state = random_scenario(rng, 40, 40, 120)
        net = costs.default_network(rng, 40, 4)
        ctrl = GraphEdgeController(net=net, policy="greedy",
                                   partitioner="hicut_jax")
        plan = ctrl.step(state).to_partition_plan(4)
        params = gcn_init(jax.random.PRNGKey(0), [16, 8, 5])
        x = rng.normal(size=(40, 16)).astype(np.float32)
        mesh = Mesh(np.array(jax.devices()), ("servers",))
        out = distributed_gcn_forward(mesh, "servers", plan, params, x)
        oracle = np.asarray(gcn_apply(params, jnp.asarray(x), state.adj,
                                      state.mask))
        print("ERR", float(np.abs(out - oracle[:out.shape[0]]).max()))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    assert float(out.stdout.split("ERR")[1]) < 1e-4
