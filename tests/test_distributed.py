"""Multi-device tests (subprocess with virtual CPU devices): distributed
GNN inference correctness + a reduced-mesh dry-run of the launch stack."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_distributed_gcn_matches_reference():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.gnn.layers import gcn_init, gcn_apply
        from repro.gnn.distributed import make_partition_plan, \\
            distributed_gcn_forward
        from repro.core.hicut import hicut_ref
        rng = np.random.default_rng(1)
        n, din, dh, dout = 80, 24, 16, 5
        adj = (rng.random((n, n)) < 0.08).astype(np.float32)
        adj = np.maximum(adj, adj.T); np.fill_diagonal(adj, 0)
        x = rng.normal(size=(n, din)).astype(np.float32)
        params = gcn_init(jax.random.PRNGKey(0), [din, dh, dout])
        ref = np.asarray(gcn_apply(params, jnp.asarray(x),
                                   jnp.asarray(adj), jnp.ones(n)))
        edges = np.transpose(np.nonzero(np.triu(adj)))
        assign = hicut_ref(n, edges) % 4
        plan = make_partition_plan(adj, assign, 4)
        mesh = Mesh(np.array(jax.devices()), ("servers",))
        out = distributed_gcn_forward(mesh, "servers", plan, params, x)
        print("ERR", float(np.abs(out - ref).max()))
    """, devices=4)
    err = float(out.split("ERR")[1])
    assert err < 1e-4


@pytest.mark.slow
def test_hicut_partition_reduces_halo_bytes():
    out = run_py("""
        import numpy as np
        from repro.core.hicut import hicut_ref
        from repro.gnn.distributed import make_partition_plan
        from repro.data.graphs import CORA, make_graph, sample_subgraph
        g = sample_subgraph(make_graph(CORA, seed=0), 200, 1200, seed=0)
        adj = g.adjacency()
        rng = np.random.default_rng(0)
        hic = hicut_ref(200, g.edges) % 4
        rand = rng.integers(0, 4, 200)
        bh = make_partition_plan(adj, hic, 4).bytes_per_aggregate(64)
        br = make_partition_plan(adj, rand, 4).bytes_per_aggregate(64)
        print("BYTES", bh, br)
    """, devices=4)
    bh, br = map(int, out.split("BYTES")[1].split())
    assert bh <= br


@pytest.mark.slow
def test_reduced_mesh_dryrun_lowers():
    """The launch-stack sharding rules lower + compile a reduced arch on a
    small (2,4) mesh — same code path as the 256/512-chip dry-run."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs.registry import get_config
        from repro.models.config import reduced
        from repro.models import transformer as T
        from repro.launch.shardings import (param_shardings,
                                            batch_shardings,
                                            activation_shard_ctx)
        from repro.launch.shapes import params_specs
        from repro.optim.adamw import AdamWConfig
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = reduced(get_config("qwen3-0.6b"), d_model=128, d_ff=256,
                      vocab=512)
        p_sds = jax.eval_shape(lambda: T.init_params(cfg,
                                                     jax.random.PRNGKey(0)))
        p_sh = param_shardings(p_sds, mesh)
        shard_ctx = activation_shard_ctx(cfg, mesh, 64, 8)
        b_sds = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "targets": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        b_sh = batch_shardings(b_sds, mesh)
        step = T.make_train_step(cfg, AdamWConfig(lr=1e-3),
                                 shard_ctx=shard_ctx)
        from repro.optim.adamw import AdamState
        o_sds = jax.eval_shape(lambda: AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p_sds),
            nu=jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p_sds)))
        from repro.launch.shardings import opt_shardings
        o_sh = opt_shardings(p_sh, mesh)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))
        compiled = fn.lower(p_sds, o_sds, b_sds).compile()
        print("MEM", compiled.memory_analysis().temp_size_in_bytes)
    """, devices=8)
    assert "MEM" in out
