"""Fused gather–normalize–matmul kernel: parity, autotuner, VMEM guards,
aggregate auto-selection and the forward's retrace cache.

All Pallas execution here is interpret mode — the CPU venue for the TPU
kernels (DESIGN.md §4). The oracle throughout is the jnp scan reference
``gather_aggregate_ref`` composed with the layer matmul in float32.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from _hyp import given, settings, st
from repro.data.graphs import random_graph
from repro.gnn.distributed import (DENSE_AUTO_SLOT_RATIO, _forward_blocks,
                                   distributed_gcn_forward, make_forward_fn,
                                   make_partition_plan_sparse,
                                   resolve_aggregate)
from repro.gnn.layers import gcn_apply, gcn_init
from repro.kernels.gnn_aggregate.autotune import (DEFAULT_VMEM_BUDGET,
                                                  KernelConfig,
                                                  autotune_config,
                                                  candidate_configs,
                                                  get_config,
                                                  heuristic_config,
                                                  load_table, save_table,
                                                  shape_key, vmem_bytes)
from repro.kernels.gnn_aggregate.ops import (SPARSE_DENSITY_THRESHOLD,
                                             fused_gather_aggregate,
                                             gather_aggregate,
                                             gather_block_columns,
                                             sort_neighbor_slots)
from repro.kernels.gnn_aggregate.ref import gather_aggregate_ref


def _random_neighbors(rng, n_rows, n_cols, k, hub_frac=0.0):
    """Padded neighbor lists with random per-row degree in [0, k]; with
    ``hub_frac`` > 0 that fraction of slots collapses onto a few hub
    columns (degree-skewed gather traffic)."""
    deg = rng.integers(0, k + 1, size=n_rows)
    idx = np.zeros((n_rows, k), np.int32)
    val = np.zeros((n_rows, k), np.float32)
    for i, d in enumerate(deg):
        if d == 0:
            continue
        cols = rng.integers(0, n_cols, size=d)
        if hub_frac:
            hubs = rng.integers(0, max(1, n_cols // 8), size=d)
            cols = np.where(rng.random(d) < hub_frac, hubs, cols)
        idx[i, :d] = cols
        val[i, :d] = rng.normal(size=d).astype(np.float32)
    return idx, val


def _oracle(idx, val, x, rs, cs, w):
    y = gather_aggregate_ref(idx, val, jnp.asarray(x, jnp.float32), rs, cs)
    return np.asarray(y @ jnp.asarray(w, jnp.float32))


# -- kernel parity ----------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 48), st.integers(1, 10),
       st.sampled_from([3, 8, 17]), st.sampled_from([2, 5, 16]),
       st.sampled_from([(8, 8, 2), (16, 8, 4), (8, 16, 1), None]),
       st.integers(0, 1 << 20))
def test_fused_parity_random(n, k, f_in, f_out, cfg, seed):
    """Interpret-mode fused kernel matches the scan-reference + matmul
    oracle across random shapes, degrees and block configs — including
    rows/slots/features that don't divide the blocking (ops.py pads)."""
    rng = np.random.default_rng(seed)
    idx, val = _random_neighbors(rng, n, n, k)
    idx, val = sort_neighbor_slots(idx, val)
    x = rng.normal(size=(n, f_in)).astype(np.float32)
    w = rng.normal(size=(f_in, f_out)).astype(np.float32)
    rs = rng.random(n).astype(np.float32)
    cs = rng.random(n).astype(np.float32)
    got = fused_gather_aggregate(
        idx, val, jnp.asarray(x), rs, cs, w, impl="interpret",
        config=KernelConfig(*cfg) if cfg else None)
    np.testing.assert_allclose(np.asarray(got),
                               _oracle(idx, val, x, rs, cs, w),
                               rtol=1e-5, atol=1e-5)


def test_fused_parity_degree_skew(rng):
    """Hub-heavy slot traffic (most gathers hit a few columns) is just a
    worst case for the prefetch layout, never for correctness."""
    n, k = 64, 16
    idx, val = _random_neighbors(rng, n, n, k, hub_frac=0.9)
    val *= 10.0                                   # heavy hub magnitudes
    idx, val = sort_neighbor_slots(idx, val)
    x = rng.normal(size=(n, 24)).astype(np.float32)
    w = rng.normal(size=(24, 8)).astype(np.float32)
    rs = rng.random(n).astype(np.float32)
    got = fused_gather_aggregate(idx, val, jnp.asarray(x), rs, rs, w,
                                 impl="interpret")
    np.testing.assert_allclose(np.asarray(got),
                               _oracle(idx, val, x, rs, rs, w),
                               rtol=1e-5, atol=1e-5)


def test_fused_parity_nondivisible_shapes():
    """n=13, F_in=5, F_out=3, K=3 under an (8, 8, 2) blocking: every axis
    needs padding, and the pad rows/slots/columns must stay inert."""
    rng = np.random.default_rng(3)
    idx, val = _random_neighbors(rng, 13, 13, 3)
    idx, val = sort_neighbor_slots(idx, val)
    x = rng.normal(size=(13, 5)).astype(np.float32)
    w = rng.normal(size=(5, 3)).astype(np.float32)
    rs = rng.random(13).astype(np.float32)
    got = fused_gather_aggregate(idx, val, jnp.asarray(x), rs, rs, w,
                                 impl="interpret", config=KernelConfig(8, 8, 2))
    assert got.shape == (13, 3)
    np.testing.assert_allclose(np.asarray(got),
                               _oracle(idx, val, x, rs, rs, w),
                               rtol=1e-5, atol=1e-5)


def test_fused_pad_slots_inert(rng):
    """val=0 slots are numerically inert no matter which (valid) index
    they carry — the padded-CSR contract the kernel relies on."""
    n, k = 24, 6
    idx, val = _random_neighbors(rng, n, n, k)
    x = rng.normal(size=(n, 8)).astype(np.float32)
    w = rng.normal(size=(8, 8)).astype(np.float32)
    rs = np.ones(n, np.float32)
    scrambled = np.where(val == 0, rng.integers(0, n, size=idx.shape),
                         idx).astype(np.int32)
    a = fused_gather_aggregate(*sort_neighbor_slots(idx, val),
                               jnp.asarray(x), rs, rs, w, impl="interpret")
    b = fused_gather_aggregate(*sort_neighbor_slots(scrambled, val),
                               jnp.asarray(x), rs, rs, w, impl="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                               atol=1e-6)


def test_fused_inactive_rows_exact_zero(rng):
    """row_scale = 0 rows (inactive vertices) come out exactly zero — the
    scale is applied inside the kernel before the matmul."""
    n = 20
    idx, val = _random_neighbors(rng, n, n, 4)
    x = rng.normal(size=(n, 8)).astype(np.float32)
    w = rng.normal(size=(8, 8)).astype(np.float32)
    rs = (np.arange(n) % 2).astype(np.float32)    # half the rows inactive
    got = np.asarray(fused_gather_aggregate(
        *sort_neighbor_slots(idx, val), jnp.asarray(x), rs, np.ones(n,
        np.float32), w, impl="interpret"))
    assert np.all(got[rs == 0] == 0.0)
    assert np.any(got[rs == 1] != 0.0)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_fused_dtype_grid(rng, dtype, tol):
    """The kernel computes in f32 and rounds to the input dtype at the end
    — exactly like the impl="xla" twin, so the two agree to ~1 ulp of the
    storage dtype (bf16's is coarse; the oracle there is the twin, not
    the f32 reference)."""
    n = 32
    idx, val = _random_neighbors(rng, n, n, 6)
    idx, val = sort_neighbor_slots(idx, val)
    x = jnp.asarray(rng.normal(size=(n, 16)), dtype)
    w = jnp.asarray(rng.normal(size=(16, 8)), dtype)
    rs = rng.random(n).astype(np.float32)
    got = fused_gather_aggregate(idx, val, x, rs, rs, w, impl="interpret")
    want = fused_gather_aggregate(idx, val, x, rs, rs, w, impl="xla")
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_fused_matches_unfused_kernel_composition(rng):
    """Fused kernel == existing gather kernel followed by the matmul (both
    interpret mode) — the exact pair the fusion replaces."""
    n = 40
    idx, val = _random_neighbors(rng, n, n, 7)
    idx, val = sort_neighbor_slots(idx, val)
    x = jnp.asarray(rng.normal(size=(n, 12)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(12, 6)).astype(np.float32))
    rs = rng.random(n).astype(np.float32)
    fused = fused_gather_aggregate(idx, val, x, rs, rs, w, impl="interpret")
    unfused = gather_aggregate(idx, val, x, rs, rs, impl="interpret") @ w
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-5)


def test_fused_is_jit_compatible(rng):
    """The op resolves its config at trace time, so value-only re-calls
    hit the same executable (benches rely on this)."""
    n = 16
    idx, val = _random_neighbors(rng, n, n, 3)
    idx, val = sort_neighbor_slots(idx, val)
    w = rng.normal(size=(8, 8)).astype(np.float32)
    rs = np.ones(n, np.float32)
    fn = jax.jit(lambda xx: fused_gather_aggregate(
        idx, val, xx, rs, rs, w, impl="interpret"))
    for seed in (0, 1):
        x = np.random.default_rng(seed).normal(size=(n, 8)).astype(
            np.float32)
        np.testing.assert_allclose(np.asarray(fn(jnp.asarray(x))),
                                   _oracle(idx, val, x, rs, rs, w),
                                   rtol=1e-5, atol=1e-5)


def test_sort_neighbor_slots_permutation_only(rng):
    """Slot sorting is a pure per-row permutation (pads last, destinations
    ascending) — the aggregate is unchanged."""
    idx, val = _random_neighbors(rng, 10, 10, 5)
    sidx, sval = sort_neighbor_slots(idx, val)
    for i in range(10):
        d = int((val[i] != 0).sum())
        assert np.all(sval[i, d:] == 0)                     # pads last
        assert np.all(np.diff(sidx[i, :d]) >= 0)            # sorted dsts
        assert sorted(zip(idx[i][val[i] != 0], val[i][val[i] != 0])) == \
            sorted(zip(sidx[i, :d], sval[i, :d]))


# -- autotuner --------------------------------------------------------------

def test_heuristic_config_deterministic_and_budgeted():
    for shape in [(1000, 1000, 64, 64, 35), (5000, 5000, 64, 64, 40),
                  (100, 100, 3, 5, 2), (200_000, 200_000, 64, 64, 48)]:
        a = heuristic_config(*shape)
        assert a == heuristic_config(*shape)
        assert vmem_bytes(a, shape[1], shape[4]) <= DEFAULT_VMEM_BUDGET

def test_heuristic_bf_rounds_to_sublane_not_lane():
    """f=64 features keep a 64-wide tile — rounding to the 128 lane would
    double the gather traffic on every slot (the regression that capped
    the fused speedup at ~1x before the fix)."""
    assert heuristic_config(5000, 5000, 64, 64, 40).bf == 64
    assert heuristic_config(100, 100, 100, 100, 4).bf == 104
    assert heuristic_config(100, 100, 200, 200, 4).bf == 128


def test_candidate_configs_all_fit_budget():
    cands = candidate_configs(5000, 5000, 64, 64, 40)
    assert len(cands) >= 3
    assert len(set(cands)) == len(cands)
    for c in cands:
        assert vmem_bytes(c, 5000, 40) <= DEFAULT_VMEM_BUDGET


def test_get_config_table_hit_and_overbudget_fallback(tmp_path):
    tbl = tmp_path / "tuning.json"
    key = shape_key(64, 64, 8, 8, 4)
    good = KernelConfig(16, 8, 2)
    save_table({key: good}, tbl)
    assert get_config(64, 64, 8, 8, 4, table_path=tbl) == good
    # an entry that no longer fits the budget is ignored, not honored
    save_table({key: KernelConfig(1 << 16, 128, 64)}, tbl)
    assert get_config(64, 64, 8, 8, 4, table_path=tbl) == \
        heuristic_config(64, 64, 8, 8, 4)
    # missing shape key → heuristic
    assert get_config(32, 32, 8, 8, 4, table_path=tbl) == \
        heuristic_config(32, 32, 8, 8, 4)


def test_autotune_deterministic_and_persists(tmp_path):
    tbl = tmp_path / "tuning.json"
    measure = lambda cfg: 1000.0 / cfg.bm + cfg.kc    # pure fn of config
    best1, t1 = autotune_config(64, 64, 8, 8, 4, measure, persist=True,
                                table_path=tbl)
    best2, t2 = autotune_config(64, 64, 8, 8, 4, measure)
    assert best1 == best2 and t1 == t2                # deterministic
    assert load_table(tbl)[shape_key(64, 64, 8, 8, 4)] == best1
    # the persisted winner is what get_config now serves
    assert get_config(64, 64, 8, 8, 4, table_path=tbl) == best1
    # ties break toward candidate order (itself deterministic)
    flat, _ = autotune_config(64, 64, 8, 8, 4, lambda cfg: 7.0)
    assert flat == candidate_configs(64, 64, 8, 8, 4)[0]


def test_autotune_table_env_override(tmp_path, monkeypatch):
    tbl = tmp_path / "env_table.json"
    key = shape_key(48, 48, 8, 8, 3)
    save_table({key: KernelConfig(8, 8, 1)}, tbl)
    monkeypatch.setenv("REPRO_GNN_AGG_TUNING", str(tbl))
    assert get_config(48, 48, 8, 8, 3) == KernelConfig(8, 8, 1)


def test_checked_in_table_entries_fit_model():
    """The committed tuning table parses and every entry passes the VMEM
    model for its own shape key (nC/K parsed back from the key)."""
    import repro.kernels.gnn_aggregate.autotune as at
    table = load_table(at._DEFAULT_TABLE)
    assert table, "checked-in tuning table is empty"
    for key, cfg in table.items():
        n_cols = int(key.split("_")[1][1:])
        k = int(key.split("_k")[1])
        assert vmem_bytes(cfg, n_cols, k) <= DEFAULT_VMEM_BUDGET, key


# -- VMEM guards ------------------------------------------------------------

def test_gather_vmem_guard_shrinks_and_matches(rng):
    """An oversized [n_cols, bf] slab shrinks bf instead of (silently)
    blowing the budget — and the shrunken blocking still matches the
    reference."""
    n, k = 64, 4
    budget = 80_000
    assert gather_block_columns(n, k, vmem_budget=budget) < 128
    idx, val = _random_neighbors(rng, n, n, k)
    x = jnp.asarray(rng.normal(size=(n, 40)).astype(np.float32))
    rs = rng.random(n).astype(np.float32)
    got = gather_aggregate(idx, val, x, rs, rs, impl="interpret",
                           vmem_budget=budget)
    want = gather_aggregate_ref(idx, val, x, rs, rs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gather_vmem_guard_raises_clearly():
    with pytest.raises(ValueError, match="VMEM budget"):
        gather_block_columns(1 << 20, 256, vmem_budget=100_000)


def test_fused_rejects_overbudget_config(rng):
    idx, val = _random_neighbors(rng, 16, 16, 3)
    x = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    w = rng.normal(size=(8, 8)).astype(np.float32)
    rs = np.ones(16, np.float32)
    with pytest.raises(ValueError, match="VMEM budget"):
        fused_gather_aggregate(idx, val, x, rs, rs, w, impl="interpret",
                               config=KernelConfig(1 << 16, 128, 64),
                               vmem_budget=100_000)


# -- aggregate auto-selection (the n=1000 regression) -----------------------

def test_auto_selection_regression_bench_shapes():
    """The auto rule consults per-row *work* (ext_cols vs slot count), not
    density: the BENCH n=1000 plan is sparse by density (0.02 < threshold
    0.05) yet its compact 1000-wide extended block keeps dense faster —
    the old density-only rule picked the 0.85x gather path here."""
    g1 = random_graph(1000, 10_000, seed=1)
    plan1 = make_partition_plan_sparse(
        g1.edges, np.zeros(1000, np.int64), 1, n=1000)
    density = 2 * g1.num_edges / 1000**2
    assert density < SPARSE_DENSITY_THRESHOLD            # misprediction bait
    assert plan1.ext_cols < DENSE_AUTO_SLOT_RATIO * (plan1.max_degree + 1)
    assert resolve_aggregate(plan1) == "dense"

    g5 = random_graph(5000, 50_000, seed=1)
    plan5 = make_partition_plan_sparse(
        g5.edges, np.arange(5000) % 4, 4, n=5000)
    assert resolve_aggregate(plan5) == "fused"

    for explicit in ("dense", "sparse", "fused"):         # pass-through
        assert resolve_aggregate(plan1, explicit) == explicit
    with pytest.raises(ValueError, match="unknown aggregate"):
        resolve_aggregate(plan1, "csr")


# -- distributed forward: fused path parity + retrace cache -----------------

def _small_plan(rng, n=48, e=140, devices=1):
    from conftest import random_edges
    edges = random_edges(rng, n, e)
    assign = np.arange(n) % devices
    plan = make_partition_plan_sparse(edges, assign, devices, n=n)
    adj = np.zeros((n, n), np.float32)
    adj[edges[:, 0], edges[:, 1]] = 1.0
    adj[edges[:, 1], edges[:, 0]] = 1.0
    return plan, adj


@pytest.mark.parametrize("aggregate", ["dense", "sparse", "fused"])
def test_distributed_forward_backends_match_oracle(rng, aggregate):
    """Every per-device contraction — including the fused kernel path —
    reproduces the single-device gcn_apply oracle on one device."""
    plan, adj = _small_plan(rng)
    n = adj.shape[0]
    params = gcn_init(jax.random.PRNGKey(0), [8, 6, 4])
    x = rng.normal(size=(n, 8)).astype(np.float32)
    mesh = Mesh(np.array(jax.devices()[:1]), ("servers",))
    out = distributed_gcn_forward(mesh, "servers", plan, params, x,
                                  aggregate=aggregate)
    oracle = np.asarray(gcn_apply(params, jnp.asarray(x),
                                  jnp.asarray(adj), jnp.ones(n)))
    np.testing.assert_allclose(out, oracle, rtol=2e-4, atol=2e-4)


def test_forward_cache_retrace_once_per_shape(rng):
    """make_forward_fn's jitted core retraces exactly once per new shape
    and not at all on value-only changes (satellite: compile-cache)."""
    plan, _ = _small_plan(rng)
    n = plan.n
    mesh = Mesh(np.array(jax.devices()[:1]), ("servers",))
    fwd = make_forward_fn(mesh, "servers", plan, aggregate="fused")

    p6 = gcn_init(jax.random.PRNGKey(0), [6, 5, 4])
    x6 = plan.scatter(rng.normal(size=(n, 6)).astype(np.float32))
    c0 = _forward_blocks._cache_size()
    fwd(x6, p6)
    c1 = _forward_blocks._cache_size()
    assert c1 == c0 + 1                                   # first shape

    # value-only changes: new x values, new param values — no retrace
    p6b = gcn_init(jax.random.PRNGKey(7), [6, 5, 4])
    fwd(plan.scatter(rng.normal(size=(n, 6)).astype(np.float32)), p6b)
    fwd(x6, p6b)
    assert _forward_blocks._cache_size() == c1

    # a new feature width is a new shape: exactly one more trace
    p7 = gcn_init(jax.random.PRNGKey(1), [7, 5, 4])
    x7 = plan.scatter(rng.normal(size=(n, 7)).astype(np.float32))
    fwd(x7, p7)
    assert _forward_blocks._cache_size() == c1 + 1
    fwd(x7, p7)                                           # and it sticks
    assert _forward_blocks._cache_size() == c1 + 1
