"""HiCut (Algorithm 1): ref↔jax equivalence, partition invariants, and the
paper's Fig. 3 worked example."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from conftest import random_edges
from repro.core.hicut import cut_metrics, hicut_jax, hicut_ref


def _to_adj(n, edges):
    a = np.zeros((n, n), np.float32)
    for i, j in edges:
        a[i, j] = a[j, i] = 1.0
    return a


def test_fig3_style_example():
    """A chain of layers whose edge counts go 3 → 2 → 1 → 4: the cut must
    land where associations weaken before strengthening again (paper §4.2)."""
    # star root 0 with 3 children (d1=3), children chain to 2 nodes (d2=2),
    # then 1 edge (d3=1), then a dense blob (d4 >= 4)
    edges = np.array([
        (0, 1), (0, 2), (0, 3),        # layer 1: d=3 edges out of root
        (1, 4), (2, 4),                # layer 2
        (4, 5),                        # layer 3
        (5, 6), (5, 7), (6, 7), (6, 8), (7, 8),   # blob
    ])
    n = 9
    assigned = hicut_ref(n, edges)
    # every vertex assigned exactly once
    assert (assigned >= 0).all()
    # the blob must not share a subgraph with the root's star
    assert assigned[0] != assigned[8]


def test_all_vertices_assigned(rng):
    for _ in range(10):
        n = int(rng.integers(3, 60))
        edges = random_edges(rng, n, int(rng.integers(0, 3 * n)))
        assigned = hicut_ref(n, edges)
        assert (assigned >= 0).all()
        # ids are 0..K-1 compact
        ids = np.unique(assigned)
        assert ids.min() == 0 and (np.diff(ids) == 1).all()


def test_inactive_vertices_excluded(rng):
    n = 20
    edges = random_edges(rng, n, 30)
    active = rng.random(n) > 0.3
    assigned = hicut_ref(n, edges, active=active)
    assert (assigned[~active] == -1).all()
    assert (assigned[active] >= 0).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 24), st.integers(0, 60), st.integers(0, 10_000))
def test_jax_matches_ref(n, e, seed):
    rng = np.random.default_rng(seed)
    edges = random_edges(rng, n, e)
    ref = hicut_ref(n, edges)
    adj = _to_adj(n, edges)
    jx = np.asarray(hicut_jax(jnp.asarray(adj), jnp.ones(n, np.float32)))
    np.testing.assert_array_equal(ref, jx)


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 20), st.integers(0, 40), st.integers(0, 10_000))
def test_jax_matches_ref_masked(n, e, seed):
    rng = np.random.default_rng(seed)
    edges = random_edges(rng, n, e)
    active = rng.random(n) > 0.3
    ref = hicut_ref(n, edges, active=active)
    adj = _to_adj(n, edges)
    jx = np.asarray(hicut_jax(jnp.asarray(adj),
                              jnp.asarray(active.astype(np.float32))))
    np.testing.assert_array_equal(ref, jx)


def test_cut_quality_on_community_graph(rng):
    """On a graph with planted communities HiCut must beat a random
    partition on cross-edges (the paper's P1 objective)."""
    k, size = 4, 12
    n = k * size
    edges = []
    for c in range(k):
        base = c * size
        for i in range(size):
            for j in range(i + 1, size):
                if rng.random() < 0.5:
                    edges.append((base + i, base + j))
    for _ in range(6):                         # sparse inter-community edges
        a, b = rng.integers(k, size=2)
        if a != b:
            edges.append((a * size + int(rng.integers(size)),
                          b * size + int(rng.integers(size))))
    edges = np.array(sorted(set(map(lambda t: (min(t), max(t)), edges))))
    assigned = hicut_ref(n, edges)
    m = cut_metrics(n, edges, assigned)
    rand = cut_metrics(n, edges, rng.integers(0, m["num_subgraphs"] + 1, n))
    assert m["cut_fraction"] <= rand["cut_fraction"]


def test_cut_metrics_consistency(rng):
    n = 30
    edges = random_edges(rng, n, 60)
    assigned = hicut_ref(n, edges)
    m = cut_metrics(n, edges, assigned)
    assert m["total_edges"] == len(edges)
    assert 0 <= m["cross_edges"] <= m["total_edges"]
