"""``benchmarks.common`` staleness guard: old-schema BENCH files flagged.

``warn_stale_benches`` used to check only the git stamp, so a BENCH file
written by an older-schema writer (whose record fields current readers
misinterpret) silently passed the smoke gates as long as the stamp
matched. It now flags any ``schema`` that differs from
``BENCH_SCHEMA_VERSION`` too.
"""
import json
import subprocess
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import common  # noqa: E402


def _git_repo_with_head(tmp_path) -> str:
    """Init a throwaway repo with one commit; returns its short hash."""
    def git(*args):
        return subprocess.run(
            ["git", "-c", "user.email=t@t", "-c", "user.name=t", *args],
            cwd=tmp_path, capture_output=True, text=True, check=True
        ).stdout.strip()
    git("init", "-q")
    (tmp_path / "code.py").write_text("pass\n")
    git("add", "code.py")
    git("commit", "-qm", "seed")
    return git("log", "-1", "--format=%h")


def test_warn_stale_benches_flags_old_schema(tmp_path, capsys):
    here = _git_repo_with_head(tmp_path)
    good = {"schema": common.BENCH_SCHEMA_VERSION, "git": here,
            "records": []}
    (tmp_path / "BENCH_good.json").write_text(json.dumps(good))
    old = dict(good, schema=common.BENCH_SCHEMA_VERSION - 1)
    (tmp_path / "BENCH_oldschema.json").write_text(json.dumps(old))
    missing = {"git": here, "records": []}      # pre-schema writer
    (tmp_path / "BENCH_noschema.json").write_text(json.dumps(missing))

    stale = common.warn_stale_benches(tmp_path)
    assert stale == ["BENCH_noschema.json", "BENCH_oldschema.json"]
    out = capsys.readouterr().out
    assert "schema" in out and "BENCH_good.json" not in out


def test_warn_stale_benches_still_flags_stamps(tmp_path, capsys):
    here = _git_repo_with_head(tmp_path)
    cur = common.BENCH_SCHEMA_VERSION
    cases = {
        "BENCH_stale.json": {"schema": cur, "git": "0000000"},
        "BENCH_dirty.json": {"schema": cur, "git": here + "-dirty"},
        "BENCH_clean.json": {"schema": cur, "git": here},
    }
    for name, payload in cases.items():
        (tmp_path / name).write_text(json.dumps(dict(payload, records=[])))
    stale = common.warn_stale_benches(tmp_path)
    assert sorted(stale) == ["BENCH_dirty.json", "BENCH_stale.json"]
    assert "BENCH_clean.json" not in capsys.readouterr().out


def test_checked_in_benches_carry_current_schema():
    """The repo's own BENCH files must never lag the writer."""
    root = Path(__file__).resolve().parent.parent
    for path in sorted(root.glob("BENCH_*.json")):
        payload = json.loads(path.read_text())
        assert payload.get("schema") == common.BENCH_SCHEMA_VERSION, \
            path.name
