#!/usr/bin/env python3
"""Docs lane: keep README.md / DESIGN.md / BENCHMARKS.md snippets honest.

Four checks, stdlib-only (no jax/numpy needed, so CI can run it without
installing the stack):

* every fenced ``python`` block must at least *compile* (syntax-valid
  against the current tree);
* every ``python ...`` command in sh/console fences that targets a file or
  ``-m`` module inside this repo must point at an existing file, and every
  ``--flag`` it passes must appear verbatim in that file's source (i.e. in
  an ``add_argument`` call) — so quickstart commands cannot drift from the
  CLIs;
* the entry-point table in ``src/repro/launch/__init__.py`` must list only
  modules that exist, every ``--flag`` a row mentions must exist in that
  module, and every launch module that defines ``main()`` must have a
  table row — so the table cannot drift from the launchers;
* every name the docs present as a registry entry (first column of the
  "registry name" tables, and ``--partitioner``/``--policy`` values in
  shell fences) must resolve against an actual
  ``register_partitioner("...")`` / ``register_offload_policy("...")`` /
  ``register_policy("...")`` call site under ``src/`` — so documented
  backends cannot drift from the registries.

Run directly (exit 1 on problems) or via ``tests/test_docs.py``.
"""
from __future__ import annotations

import pathlib
import re
import shlex
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = ("README.md", "DESIGN.md", "BENCHMARKS.md")
FENCE = re.compile(r"```([\w+-]*)[ \t]*\n(.*?)```", re.S)
SHELL_LANGS = {"", "sh", "bash", "shell", "console", "text"}
LAUNCH_INIT = ROOT / "src" / "repro" / "launch" / "__init__.py"
REGISTER_RE = re.compile(
    r"register_(?:partitioner|offload_policy|policy)\(\s*[\"']([^\"']+)[\"']")
NAME_RE = re.compile(r"[a-z][a-z0-9_]*\Z")   # skip placeholders like X / <n>


def _module_path(module: str) -> pathlib.Path:
    return ROOT / "src" / (module.replace(".", "/") + ".py")


def iter_commands(body: str):
    """Yield logical command lines that invoke python."""
    body = body.replace("\\\n", " ")
    for line in body.splitlines():
        line = line.strip()
        if line.startswith("$"):
            line = line[1:].strip()
        if line and "python" in line:
            yield line


def check_command(doc: str, line: str, errors: list[str]) -> None:
    try:
        toks = shlex.split(line)
    except ValueError:
        return
    while toks and "=" in toks[0] and not toks[0].startswith("-"):
        toks.pop(0)                       # drop env assignments
    if not toks or not toks[0].startswith("python"):
        return
    toks.pop(0)
    if toks and toks[0] == "-m":
        toks.pop(0)
        if not toks:
            return
        module = toks.pop(0)
        if not module.startswith("repro"):
            return                        # pytest, pip, ... — out of scope
        target = _module_path(module)
    elif toks and toks[0].endswith(".py"):
        target = ROOT / toks.pop(0)
    else:
        return
    if not target.exists():
        errors.append(f"{doc}: {line!r} → no such file {target}")
        return
    src = target.read_text()
    for tok in toks:
        if not tok.startswith("--"):
            continue
        flag = tok.split("=", 1)[0]
        if f'"{flag}"' not in src and f"'{flag}'" not in src:
            errors.append(f"{doc}: {line!r} → flag {flag} not found in "
                          f"{target.relative_to(ROOT)}")


# ---------------------------------------------------------------------------
# launch entry-point table (src/repro/launch/__init__.py docstring)
# ---------------------------------------------------------------------------

def _rel(path: pathlib.Path) -> str:
    try:
        return str(path.relative_to(ROOT))
    except ValueError:
        return str(path)


def check_launch_table(errors: list[str]) -> None:
    if not LAUNCH_INIT.exists():
        errors.append(f"{_rel(LAUNCH_INIT)}: missing")
        return
    text = LAUNCH_INIT.read_text()
    listed: set[str] = set()
    for line in text.splitlines():
        m = re.match(r"\|\s*``(\w+)``", line.strip())
        if not m:
            continue
        name = m.group(1)
        listed.add(name)
        target = LAUNCH_INIT.parent / f"{name}.py"
        if not target.exists():
            errors.append(f"launch table: entry point ``{name}`` has no "
                          f"module {_rel(target)}")
            continue
        src = target.read_text()
        for flag in re.findall(r"--[\w][\w-]*", line):
            flag = flag.rstrip("-")
            if f'"{flag}"' not in src and f"'{flag}'" not in src:
                errors.append(f"launch table: row ``{name}`` mentions "
                              f"{flag}, not found in {_rel(target)}")
    for mod in sorted(LAUNCH_INIT.parent.glob("*.py")):
        if mod.name == "__init__.py":
            continue
        if re.search(r"^def main\(", mod.read_text(), re.M) and \
                mod.stem not in listed:
            errors.append(f"launch table: runnable module {mod.stem} "
                          f"(defines main()) is not listed in the "
                          f"entry-point table")


# ---------------------------------------------------------------------------
# registry names documented vs registered
# ---------------------------------------------------------------------------

def registered_names() -> set[str]:
    """Every name passed to a register_* call anywhere under src/."""
    names: set[str] = set()
    for py in (ROOT / "src").rglob("*.py"):
        names.update(REGISTER_RE.findall(py.read_text()))
    return names


_TABLE_SEP = re.compile(r"\|(?:\s*:?-+:?\s*\|)+\s*$")


def documented_registry_names(text: str) -> set[str]:
    """Names the docs present as registry entries: the first column of any
    markdown table whose header contains "registry name", plus every
    ``--partitioner``/``--policy`` value in shell fences. Table scope is
    tracked via the ``|---|`` separator rows, so a different table stacked
    directly underneath never leaks its cells into the name set."""
    names: set[str] = set()
    lines = text.splitlines()
    in_table = False
    for i, line in enumerate(lines):
        stripped = line.strip()
        if not stripped.startswith("|"):
            in_table = False
            continue
        if _TABLE_SEP.match(stripped):         # the row above is a header
            header = lines[i - 1].strip() if i else ""
            in_table = "registry name" in header.lower()
            continue
        nxt = lines[i + 1].strip() if i + 1 < len(lines) else ""
        if _TABLE_SEP.match(nxt):
            continue                           # header row of the next table
        if in_table:
            cell = stripped.split("|")[1]
            for tok in re.findall(r"`([^`]+)`", cell):
                if NAME_RE.match(tok):
                    names.add(tok)
    for lang, body in FENCE.findall(text):
        if lang.lower() in SHELL_LANGS:
            for m in re.finditer(r"--(?:partitioner|policy)[ =](\S+)", body):
                tok = m.group(1).strip("\"'")
                if NAME_RE.match(tok):
                    names.add(tok)
    return names


def check_registry_names(doc: str, text: str, registered: set[str],
                         errors: list[str]) -> None:
    for name in sorted(documented_registry_names(text)):
        if name not in registered:
            errors.append(f"{doc}: documented registry entry {name!r} "
                          f"does not resolve to any register_partitioner/"
                          f"register_offload_policy call site under src/")


def collect_errors() -> list[str]:
    errors: list[str] = []
    registered = registered_names()
    for doc in DOCS:
        path = ROOT / doc
        if not path.exists():
            errors.append(f"{doc}: missing")
            continue
        text = path.read_text()
        for lang, body in FENCE.findall(text):
            if lang == "python":
                try:
                    compile(body, f"{doc}:<fenced python>", "exec")
                except SyntaxError as exc:
                    errors.append(f"{doc}: python block does not compile: "
                                  f"{exc}")
            elif lang.lower() in SHELL_LANGS:
                for line in iter_commands(body):
                    check_command(doc, line, errors)
        check_registry_names(doc, text, registered, errors)
    check_launch_table(errors)
    return errors


def main() -> int:
    errors = collect_errors()
    for err in errors:
        print(f"ERROR: {err}", file=sys.stderr)
    if not errors:
        print(f"docs OK: {', '.join(DOCS)} + launch table + registries")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
