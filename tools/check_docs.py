#!/usr/bin/env python3
"""Docs lane: keep README.md / DESIGN.md snippets honest.

Two checks, stdlib-only (no jax/numpy needed, so CI can run it without
installing the stack):

* every fenced ``python`` block must at least *compile* (syntax-valid
  against the current tree);
* every ``python ...`` command in sh/console fences that targets a file or
  ``-m`` module inside this repo must point at an existing file, and every
  ``--flag`` it passes must appear verbatim in that file's source (i.e. in
  an ``add_argument`` call) — so quickstart commands cannot drift from the
  CLIs.

Run directly (exit 1 on problems) or via ``tests/test_docs.py``.
"""
from __future__ import annotations

import pathlib
import re
import shlex
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = ("README.md", "DESIGN.md")
FENCE = re.compile(r"```([\w+-]*)[ \t]*\n(.*?)```", re.S)
SHELL_LANGS = {"", "sh", "bash", "shell", "console", "text"}


def _module_path(module: str) -> pathlib.Path:
    return ROOT / "src" / (module.replace(".", "/") + ".py")


def iter_commands(body: str):
    """Yield logical command lines that invoke python."""
    body = body.replace("\\\n", " ")
    for line in body.splitlines():
        line = line.strip()
        if line.startswith("$"):
            line = line[1:].strip()
        if line and "python" in line:
            yield line


def check_command(doc: str, line: str, errors: list[str]) -> None:
    try:
        toks = shlex.split(line)
    except ValueError:
        return
    while toks and "=" in toks[0] and not toks[0].startswith("-"):
        toks.pop(0)                       # drop env assignments
    if not toks or not toks[0].startswith("python"):
        return
    toks.pop(0)
    if toks and toks[0] == "-m":
        toks.pop(0)
        if not toks:
            return
        module = toks.pop(0)
        if not module.startswith("repro"):
            return                        # pytest, pip, ... — out of scope
        target = _module_path(module)
    elif toks and toks[0].endswith(".py"):
        target = ROOT / toks.pop(0)
    else:
        return
    if not target.exists():
        errors.append(f"{doc}: {line!r} → no such file {target}")
        return
    src = target.read_text()
    for tok in toks:
        if not tok.startswith("--"):
            continue
        flag = tok.split("=", 1)[0]
        if f'"{flag}"' not in src and f"'{flag}'" not in src:
            errors.append(f"{doc}: {line!r} → flag {flag} not found in "
                          f"{target.relative_to(ROOT)}")


def collect_errors() -> list[str]:
    errors: list[str] = []
    for doc in DOCS:
        path = ROOT / doc
        if not path.exists():
            errors.append(f"{doc}: missing")
            continue
        for lang, body in FENCE.findall(path.read_text()):
            if lang == "python":
                try:
                    compile(body, f"{doc}:<fenced python>", "exec")
                except SyntaxError as exc:
                    errors.append(f"{doc}: python block does not compile: "
                                  f"{exc}")
            elif lang.lower() in SHELL_LANGS:
                for line in iter_commands(body):
                    check_command(doc, line, errors)
    return errors


def main() -> int:
    errors = collect_errors()
    for err in errors:
        print(f"ERROR: {err}", file=sys.stderr)
    if not errors:
        print(f"docs OK: {', '.join(DOCS)}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
