"""Profiling lane: capture a ``jax.profiler`` trace of a hot path.

Writes a TensorBoard-loadable trace directory (``xplane.pb`` under
``plugins/profile/<run>/``) for one of three workloads:

* ``fused_aggregate`` — the fused gather–normalize–matmul kernel vs the
  unfused gather-kernel + matmul pair on the BENCH_kernels n=5000 shape
  (interpret mode, jitted — the kernel-vs-kernel comparison venue);
* ``kernels``        — the whole ``benchmarks/bench_kernels.py`` quick run;
* ``serving``        — the whole ``benchmarks/bench_serving.py`` quick run.

Usage (from the repo root)::

    python tools/profile_trace.py --workload fused_aggregate --out /tmp/tr
    python tools/profile_trace.py --workload serving --out /tmp/tr

The per-bench ``--profile DIR`` flags on ``benchmarks/bench_kernels.py``
and ``benchmarks/bench_serving.py`` capture the same traces without this
wrapper. Load the output with ``tensorboard --logdir DIR`` (or
``xprof``); on this CPU-only box the trace shows XLA/interpreter op
spans, on TPU the same lane captures device timelines.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_ROOT / "src"), str(_ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)


def _trace_fused_aggregate(out: str) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data.graphs import random_graph
    from repro.gnn.layers import gcn_norm_sparse
    from repro.kernels.gnn_aggregate.ops import (fused_gather_aggregate,
                                                 gather_aggregate,
                                                 sort_neighbor_slots)

    n, e, f = 5000, 50_000, 64
    rng = np.random.default_rng(0)
    g = random_graph(n, e, seed=1)
    idx, val, dinv = gcn_norm_sparse(g.edges, n)
    idx, val = sort_neighbor_slots(idx, val)
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(f, f)).astype(np.float32) * 0.1)
    ij, vj, dj = jnp.asarray(idx), jnp.asarray(val), jnp.asarray(dinv)
    fused = jax.jit(lambda xx: fused_gather_aggregate(
        ij, vj, xx, dj, dj, w, impl="interpret"))
    unfused = jax.jit(lambda xx: gather_aggregate(
        ij, vj, xx, dj, dj, impl="interpret") @ w)
    fused(x).block_until_ready()        # compile outside the trace
    unfused(x).block_until_ready()
    with jax.profiler.trace(out):
        for _ in range(3):
            with jax.profiler.TraceAnnotation("fused_kernel"):
                fused(x).block_until_ready()
            with jax.profiler.TraceAnnotation("unfused_kernel_matmul"):
                unfused(x).block_until_ready()


def main() -> None:
    ap = argparse.ArgumentParser(
        description="capture a jax.profiler trace of a hot path")
    ap.add_argument("--workload", required=True,
                    choices=["fused_aggregate", "kernels", "serving"])
    ap.add_argument("--out", required=True, metavar="DIR",
                    help="trace output directory (TensorBoard logdir)")
    args = ap.parse_args()

    if args.workload == "fused_aggregate":
        _trace_fused_aggregate(args.out)
    elif args.workload == "kernels":
        from benchmarks import bench_kernels
        bench_kernels.run(quick=True, profile_dir=args.out)
    else:
        from benchmarks import bench_serving
        bench_serving.run(quick=True, profile_dir=args.out)

    arts = sorted(str(p.relative_to(args.out))
                  for p in pathlib.Path(args.out).rglob("*") if p.is_file())
    print(f"trace artifacts under {args.out}:")
    for a in arts:
        print(f"  {a}")


if __name__ == "__main__":
    main()
