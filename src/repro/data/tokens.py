"""Synthetic token pipeline for the LM training examples / smoke tests.

Deterministic, seedable, infinite iterator of (tokens, targets) batches; a
tiny zipf-ish unigram sampler with induced bigram structure so that a model
can actually reduce loss (pure-uniform data has no learnable signal).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class TokenDataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0


def token_batches(cfg: TokenDataConfig) -> Iterator[dict]:
    rng = np.random.default_rng(cfg.seed)
    v = cfg.vocab_size
    # zipf unigram
    ranks = np.arange(1, v + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    # learnable structure: each token deterministically biases its successor
    shift = rng.integers(1, v, size=v)
    while True:
        first = rng.choice(v, size=(cfg.batch_size, 1), p=probs)
        seq = [first]
        for _ in range(cfg.seq_len):
            prev = seq[-1][:, 0]
            nxt = np.where(rng.random(cfg.batch_size) < 0.5,
                           (prev + shift[prev]) % v,
                           rng.choice(v, size=cfg.batch_size, p=probs))
            seq.append(nxt[:, None])
        toks = np.concatenate(seq, axis=1).astype(np.int32)
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
