"""Graph datasets for the GraphEdge experiments.

CiteSeer / Cora / PubMed are not downloadable in this offline container, so
we generate synthetic citation networks matched to each dataset's published
statistics (paper §6.1 + Fig. 5): vertex count, edge count, feature dim,
class count, and a heavy-tailed degree distribution produced by preferential
attachment. Benchmarks label these ``synth-citeseer`` etc.

The paper samples 300 documents / 4800 links from PubMed for DRL training and
re-samples at evaluation; ``sample_subgraph`` reproduces that protocol.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class GraphSpec:
    name: str
    num_vertices: int
    num_edges: int       # citation links (undirected edges)
    feature_dim: int
    num_classes: int


# Published statistics (paper §6.1: "Datasets in experiment").
CITESEER = GraphSpec("synth-citeseer", 3327, 9104 // 2, 3703, 6)
CORA = GraphSpec("synth-cora", 2708, 10556 // 2, 1433, 7)
PUBMED = GraphSpec("synth-pubmed", 19717, 88648 // 2, 500, 3)

DATASETS = {s.name: s for s in (CITESEER, CORA, PUBMED)}
# Paper: "dimensions greater than 1500 are considered 1500" (kb per dim).
FEATURE_DIM_CAP = 1500


@dataclass
class GraphData:
    """An undirected graph with vertex features and labels."""
    name: str
    edges: np.ndarray        # [E, 2] int32, i < j, unique
    features: np.ndarray     # [N, F] float32 (bag-of-words-ish, sparse 0/1)
    labels: np.ndarray       # [N] int32
    num_classes: int

    @property
    def num_vertices(self) -> int:
        return self.features.shape[0]

    @property
    def num_edges(self) -> int:
        return self.edges.shape[0]

    def adjacency(self) -> np.ndarray:
        n = self.num_vertices
        a = np.zeros((n, n), np.float32)
        a[self.edges[:, 0], self.edges[:, 1]] = 1.0
        a[self.edges[:, 1], self.edges[:, 0]] = 1.0
        return a

    def degrees(self) -> np.ndarray:
        n = self.num_vertices
        d = np.zeros(n, np.int64)
        np.add.at(d, self.edges[:, 0], 1)
        np.add.at(d, self.edges[:, 1], 1)
        return d

    def task_sizes_kb(self) -> np.ndarray:
        """Paper: each feature dim = 1 kb of user task data, capped at 1500."""
        dim = min(self.features.shape[1], FEATURE_DIM_CAP)
        return np.full(self.num_vertices, float(dim), np.float32)


def _preferential_attachment_edges(rng: np.random.Generator, n: int,
                                   e_target: int,
                                   labels: np.ndarray | None = None,
                                   homophily: float = 0.7) -> np.ndarray:
    """Barabasi-Albert-ish generator hitting ~e_target undirected edges.

    With ``labels``, same-class targets are preferred (citation networks are
    homophilous — this is also what gives HiCut communities to find)."""
    m = max(1, round(e_target / max(n - 1, 1)))
    targets = list(range(m))
    repeated: list[int] = list(range(m))
    edges = set()
    for v in range(m, n):
        # sample m distinct targets weighted by degree (repeated list trick)
        chosen = set()
        tries = 0
        while len(chosen) < m and tries < 50 * m:
            tries += 1
            pick = repeated[rng.integers(len(repeated))] if repeated else int(
                rng.integers(v))
            if pick == v:
                continue
            if labels is not None and labels[pick] != labels[v] and \
                    rng.random() < homophily:
                continue                        # resample: prefer same class
            chosen.add(pick)
        for u in chosen:
            edges.add((min(u, v), max(u, v)))
            repeated.extend((u, v))
    edges = np.array(sorted(edges), np.int32)
    # trim or top-up with random edges to match e_target
    if len(edges) > e_target:
        idx = rng.choice(len(edges), e_target, replace=False)
        edges = edges[np.sort(idx)]
    else:
        have = set(map(tuple, edges.tolist()))
        while len(have) < e_target:
            i, j = rng.integers(n), rng.integers(n)
            if i != j:
                have.add((min(i, j), max(i, j)))
        edges = np.array(sorted(have), np.int32)
    return edges


def make_graph(spec: GraphSpec, seed: int = 0,
               feature_density: float = 0.02,
               class_signal: float = 0.6) -> GraphData:
    """Synthetic citation network matched to the spec's published stats.

    Labels drive both features (each class owns a block of "topic words";
    ``class_signal`` of each document's words come from its class block)
    and edges (homophily) — so node classification is learnable to the
    paper's 60–80% band and the graph has community structure."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, spec.num_classes,
                          spec.num_vertices).astype(np.int32)
    edges = _preferential_attachment_edges(rng, spec.num_vertices,
                                           spec.num_edges, labels=labels)
    nnz = max(2, int(spec.feature_dim * feature_density))
    block = spec.feature_dim // spec.num_classes
    feats = np.zeros((spec.num_vertices, spec.feature_dim), np.float32)
    for v in range(spec.num_vertices):
        c = labels[v]
        n_class = int(nnz * class_signal)
        own = rng.integers(c * block, (c + 1) * block, n_class)
        other = rng.integers(0, spec.feature_dim, nnz - n_class)
        feats[v, np.concatenate([own, other])] = 1.0
    return GraphData(spec.name, edges, feats, labels, spec.num_classes)


def sample_subgraph(g: GraphData, num_vertices: int, max_edges: int,
                    seed: int = 0, mode: str = "bfs") -> GraphData:
    """Paper protocol: sample documents + their citation links.

    mode="bfs" grows a connected neighborhood from a random seed (keeps the
    induced link count near the paper's 300-doc/4800-link density);
    mode="uniform" samples vertices independently."""
    rng = np.random.default_rng(seed)
    if mode == "bfs":
        nbrs: dict[int, list[int]] = {}
        for i, j in g.edges:
            nbrs.setdefault(int(i), []).append(int(j))
            nbrs.setdefault(int(j), []).append(int(i))
        from collections import deque
        keep_set: set[int] = set()
        while len(keep_set) < num_vertices:
            seed_v = int(rng.integers(g.num_vertices))
            q = deque([seed_v])
            while q and len(keep_set) < num_vertices:
                v = q.popleft()
                if v in keep_set:
                    continue
                keep_set.add(v)
                q.extend(u for u in nbrs.get(v, []) if u not in keep_set)
        keep = np.sort(np.fromiter(keep_set, np.int64))
    else:
        keep = np.sort(rng.choice(g.num_vertices, num_vertices,
                                  replace=False))
    remap = -np.ones(g.num_vertices, np.int64)
    remap[keep] = np.arange(num_vertices)
    mask = (remap[g.edges[:, 0]] >= 0) & (remap[g.edges[:, 1]] >= 0)
    edges = g.edges[mask]
    edges = np.stack([remap[edges[:, 0]], remap[edges[:, 1]]],
                     1).astype(np.int32)
    if len(edges) > max_edges:
        idx = rng.choice(len(edges), max_edges, replace=False)
        edges = edges[np.sort(idx)]
    return GraphData(g.name, edges, g.features[keep], g.labels[keep],
                     g.num_classes)


def random_graph(n: int, e: int, seed: int = 0, feature_dim: int = 16,
                 num_classes: int = 4) -> GraphData:
    """Uniform random graph (used by the Fig. 6 sparse/non-sparse bench)."""
    rng = np.random.default_rng(seed)
    have: set[tuple[int, int]] = set()
    max_e = n * (n - 1) // 2
    e = min(e, max_e)
    while len(have) < e:
        need = e - len(have)
        i = rng.integers(0, n, 2 * need + 8)
        j = rng.integers(0, n, 2 * need + 8)
        for a, b in zip(i, j):
            if a != b:
                have.add((min(a, b), max(a, b)))
                if len(have) == e:
                    break
    edges = np.array(sorted(have), np.int32)
    feats = rng.normal(size=(n, feature_dim)).astype(np.float32)
    labels = rng.integers(0, num_classes, n).astype(np.int32)
    return GraphData(f"random-{n}-{e}", edges, feats, labels, num_classes)


def community_graph(n: int, e: int, parts: int, cross_frac: float = 0.01,
                    seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Planted-community graph at million-vertex scale, fully vectorized.

    Returns ``(edges [E, 2] unique undirected pairs, assign [n])`` where
    vertices split into ``parts`` contiguous communities; a ``cross_frac``
    fraction of edge draws connects uniformly random endpoints and the
    rest stay inside one community — the locality structure a HiCut-style
    cut recovers, so the plan's halo stays a small fraction of the block.
    Unlike :func:`random_graph` (a Python set loop — fine at 10⁴ edges,
    hopeless at 10⁶) this generates ~3×10⁶ edges in a couple of seconds;
    dedup may return slightly fewer than ``e`` edges. ``assign`` is the
    community id per vertex, the natural device placement."""
    rng = np.random.default_rng(seed)
    block = -(-n // parts)
    assign = np.minimum(np.arange(n) // block, parts - 1).astype(np.int64)
    base = np.minimum(np.arange(parts) * block, n - 1)
    width = np.minimum(base + block, n) - base
    n_cross = int(e * cross_frac)
    ci = rng.integers(0, parts, e - n_cross)
    i = base[ci] + rng.integers(0, width[ci])
    j = base[ci] + rng.integers(0, width[ci])
    src = np.concatenate([i, rng.integers(0, n, n_cross)])
    dst = np.concatenate([j, rng.integers(0, n, n_cross)])
    keep = src != dst
    edges = np.stack([np.minimum(src[keep], dst[keep]),
                      np.maximum(src[keep], dst[keep])], 1)
    return np.unique(edges, axis=0), assign
