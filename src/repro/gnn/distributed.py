"""Distributed GNN inference over a device mesh (shard_map halo exchange).

TPU-native mapping of the paper's multi-edge-server GNN inference (Fig. 1):
edge server → mesh device, cross-server message passing → halo-exchange
all-gather over the mesh axis. The HiCut-optimized layout (few cross-
subgraph edges) directly shrinks the halo buffer — the static per-device
bound ``halo`` below — and therefore the collective bytes, realizing the
paper's objective P1 (Eq. 15) in ICI bytes.

Vertices are permuted so each device owns a contiguous, equally-padded
block. Each layer: (1) every device publishes its *boundary rows* (owned
rows with a cross-partition edge) into a fixed [halo, F] buffer,
(2) ``all_gather`` over the axis, (3) blocked aggregation against the
device's extended adjacency slice [L, L + P·halo].
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


@dataclass
class PartitionPlan:
    num_devices: int
    block: int                 # L — owned vertices per device (padded)
    halo: int                  # B — max boundary rows any device publishes
    perm: np.ndarray           # [P*L] global vertex id per slot (−1 = pad)
    send_idx: np.ndarray       # [P, B] local slot of each published row
    send_mask: np.ndarray      # [P, B] 1 where send_idx is real
    adj_ext: np.ndarray        # [P, L, L + P*B] extended adjacency slices
    mask: np.ndarray           # [P, L] active-vertex mask per slot

    @property
    def padded_n(self) -> int:
        return self.num_devices * self.block

    def bytes_per_aggregate(self, feature_dim: int,
                            dtype_bytes: int = 4) -> int:
        """All-gather traffic per layer: every device receives the other
        devices' halo buffers (ring all-gather model)."""
        p, b = self.num_devices, self.halo
        return p * (p - 1) * b * feature_dim * dtype_bytes

    def scatter(self, x: np.ndarray, fill: float = 0.0) -> np.ndarray:
        """[N, ...] global array → [P, L, ...] per-device blocks."""
        out = np.full((self.padded_n,) + x.shape[1:], fill, x.dtype)
        valid = self.perm >= 0
        out[valid] = x[self.perm[valid]]
        return out.reshape((self.num_devices, self.block) + x.shape[1:])

    def gather(self, blocks: np.ndarray) -> np.ndarray:
        """[P, L, ...] → [N, ...] (inverse of scatter)."""
        flat = np.asarray(blocks).reshape((self.padded_n,) + blocks.shape[2:])
        n = int(self.perm.max()) + 1
        out = np.zeros((n,) + flat.shape[1:], flat.dtype)
        valid = self.perm >= 0
        out[self.perm[valid]] = flat[valid]
        return out


def make_partition_plan(adj: np.ndarray, assign: np.ndarray,
                        num_devices: int) -> PartitionPlan:
    """Build the static halo-exchange plan for a vertex→device assignment."""
    n = adj.shape[0]
    assign = np.asarray(assign)
    active = assign >= 0
    owned = [np.nonzero(assign == p)[0] for p in range(num_devices)]
    block = max(1, max(len(o) for o in owned))
    perm = -np.ones(num_devices * block, np.int64)
    local_slot = -np.ones(n, np.int64)
    for p, o in enumerate(owned):
        perm[p * block:p * block + len(o)] = o
        local_slot[o] = np.arange(len(o))

    cross = adj * (assign[:, None] != assign[None, :]) * \
        active[:, None] * active[None, :]
    boundary = [np.nonzero((cross[o] > 0).any(1))[0] if len(o) else
                np.zeros(0, np.int64) for o in owned]     # local indices
    halo = max(1, max(len(b) for b in boundary))
    send_idx = np.zeros((num_devices, halo), np.int64)
    send_mask = np.zeros((num_devices, halo), np.float32)
    for p, b in enumerate(boundary):
        send_idx[p, :len(b)] = b
        send_mask[p, :len(b)] = 1.0

    # global position of each published row in the flattened halo buffer
    halo_of: dict[int, int] = {}
    for p, b in enumerate(boundary):
        for slot, li in enumerate(b):
            halo_of[int(owned[p][li])] = p * halo + slot

    ext_cols = block + num_devices * halo
    adj_ext = np.zeros((num_devices, block, ext_cols), np.float32)
    for p, o in enumerate(owned):
        for li, g in enumerate(o):
            for gj in np.nonzero(adj[g])[0]:
                if not active[gj]:
                    continue
                if assign[gj] == p:
                    adj_ext[p, li, local_slot[gj]] = adj[g, gj]
                else:
                    adj_ext[p, li, block + halo_of[int(gj)]] = adj[g, gj]

    mask = np.zeros((num_devices, block), np.float32)
    for p, o in enumerate(owned):
        mask[p, :len(o)] = 1.0
    return PartitionPlan(num_devices, block, halo, perm, send_idx,
                         send_mask, adj_ext, mask)


def _halo_aggregate(x_blk, adj_ext_blk, send_idx, send_mask,
                    rs, cs_own, cs_halo, axis: str):
    """One distributed normalized aggregation step (runs per device).

    x_blk [L, F]; returns rs·A_ext·cs @ [x_own ; halo]."""
    published = x_blk[send_idx] * send_mask[:, None]
    halo = jax.lax.all_gather(published, axis)        # [P, B, F]
    x_ext = jnp.concatenate([x_blk, halo.reshape(-1, halo.shape[-1])], 0)
    cs = jnp.concatenate([cs_own, cs_halo], 0)
    a = adj_ext_blk * rs[:, None] * cs[None, :]
    return a @ x_ext


def distributed_gcn_forward(mesh: Mesh, axis: str, plan: PartitionPlan,
                            params, x: np.ndarray) -> np.ndarray:
    """Two-(or more-)layer GCN inference, vertex-partitioned over ``axis``.

    Matches ``repro.gnn.layers.gcn_apply`` exactly (tested); collective
    traffic = plan.bytes_per_aggregate per layer."""
    n_real = int(plan.perm.max()) + 1
    # global GCN normalization (Â = A+I, D̃^-1/2) computed from the plan mask
    deg_blocks = plan.adj_ext.sum(2) + plan.mask       # self-loop
    dinv = np.where(deg_blocks > 0, 1.0 / np.sqrt(np.maximum(deg_blocks,
                                                             1e-9)), 0.0)
    dinv = dinv.astype(np.float32)
    # column scales: own block + halo rows (their global dinv)
    cs_halo = np.zeros((plan.num_devices, plan.num_devices * plan.halo),
                       np.float32)
    dinv_flat_by_slot = dinv.reshape(-1)               # per (p, local)
    for p in range(plan.num_devices):
        for q in range(plan.num_devices):
            for s in range(plan.halo):
                li = plan.send_idx[q, s]
                if plan.send_mask[q, s] > 0:
                    cs_halo[p, q * plan.halo + s] = \
                        dinv_flat_by_slot[q * plan.block + li]

    # add self-loops to the extended adjacency (own-block diagonal)
    adj_ext = plan.adj_ext.copy()
    for p in range(plan.num_devices):
        adj_ext[p, :, :plan.block] += np.diag(plan.mask[p])

    x_blocks = plan.scatter(x.astype(np.float32))

    def device_fn(x_blk, adj_blk, sidx, smask, rs, cs_own, cs_h, mask_blk,
                  *ws):
        # strip the sharded leading axis (block size 1 per device)
        x_blk, adj_blk, sidx, smask = x_blk[0], adj_blk[0], sidx[0], smask[0]
        rs, cs_own, cs_h, mask_blk = rs[0], cs_own[0], cs_h[0], mask_blk[0]
        h = x_blk
        for i, w in enumerate(ws):
            h = _halo_aggregate(h @ w, adj_blk, sidx, smask, rs, cs_own,
                                cs_h, axis)
            if i < len(ws) - 1:
                h = jax.nn.relu(h)
        return (h * mask_blk[:, None])[None]

    specs_in = (P(axis), P(axis), P(axis), P(axis), P(axis), P(axis),
                P(axis), P(axis)) + tuple(P() for _ in params)
    fn = shard_map(device_fn, mesh=mesh, in_specs=specs_in,
                   out_specs=P(axis), check_rep=False)
    ws = [jnp.asarray(layer["w"]) for layer in params]
    out = fn(jnp.asarray(x_blocks), jnp.asarray(adj_ext),
             jnp.asarray(plan.send_idx), jnp.asarray(plan.send_mask),
             jnp.asarray(dinv), jnp.asarray(dinv), jnp.asarray(cs_halo),
             jnp.asarray(plan.mask), *ws)
    return plan.gather(np.asarray(out))[:n_real]
