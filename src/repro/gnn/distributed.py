"""Distributed GNN inference over a device mesh (shard_map halo exchange).

TPU-native mapping of the paper's multi-edge-server GNN inference (Fig. 1):
edge server → mesh device, cross-server message passing → halo-exchange
all-gather over the mesh axis. The HiCut-optimized layout (few cross-
subgraph edges) directly shrinks the halo buffer — the static per-device
bound ``halo`` below — and therefore the collective bytes, realizing the
paper's objective P1 (Eq. 15) in ICI bytes.

Vertices are permuted so each device owns a contiguous, equally-padded
block. Each layer: (1) every device publishes its *boundary rows* (owned
rows with a cross-partition edge) into a fixed [halo, F] buffer,
(2) ``all_gather`` over the axis, (3) blocked aggregation against the
device's extended adjacency slice [L, L + P·halo].

Plans are built **sparse-first**: :func:`make_partition_plan_sparse` is
vectorized numpy over a COO edge list — O(E) work and memory, no N×N array
anywhere — and stores the extended adjacency as blocked-sparse padded
neighbor lists (``nbr_idx``/``nbr_val``, per-device local cols + halo
cols). The dense entry point :func:`make_partition_plan` is a thin wrapper
that also materializes the dense ``adj_ext`` blocks (small graphs, and the
oracle form the dense Pallas kernel consumes);
:func:`make_partition_plan_dense_reference` keeps the original triple-loop
builder as the parity oracle for tests and the perf baseline for
``benchmarks/bench_partition_plan.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels.gnn_aggregate.ops import (padded_neighbors_from_coo,
                                             rank_within_sorted_groups,
                                             sort_neighbor_slots)


@dataclass
class PartitionPlan:
    num_devices: int
    block: int                 # L — owned vertices per device (padded)
    halo: int                  # B — max boundary rows any device publishes
    n: int                     # global vertex-slot count (gather/forward size)
    perm: np.ndarray           # [P*L] global vertex id per slot (−1 = pad)
    send_idx: np.ndarray       # [P, B] local slot of each published row
    send_mask: np.ndarray      # [P, B] 1 where send_idx is real
    nbr_idx: np.ndarray        # [P, L, K] extended-col id per neighbor slot
    nbr_val: np.ndarray        # [P, L, K] edge weight (0 = pad slot)
    mask: np.ndarray           # [P, L] active-vertex mask per slot
    adj_ext: np.ndarray | None = None   # dense [P, L, L+P*B] blocks (lazy)

    # Two exchange layouts share this dataclass (DESIGN.md §8):
    #  * "gather" — send_idx/send_mask are [P, B]: every device publishes
    #    the union of its boundary rows, all-gathered to every peer.
    #  * "pair" — send_idx/send_mask are [P, P, B]: entry [q, p] lists the
    #    rows device q sends to device p, exchanged with one all_to_all
    #    over exactly the cut edges — no row travels to a device that
    #    doesn't read it. ``halo`` is then the max *per-pair* send count.

    @property
    def exchange(self) -> str:
        return "pair" if self.send_idx.ndim == 3 else "gather"

    @property
    def padded_n(self) -> int:
        return self.num_devices * self.block

    @property
    def ext_cols(self) -> int:
        return self.block + self.num_devices * self.halo

    @property
    def max_degree(self) -> int:
        """K — padded neighbor slots per row."""
        return self.nbr_idx.shape[2]

    @property
    def num_edges(self) -> int:
        """Directed (both-ways) edge count stored in the plan."""
        return int(np.count_nonzero(self.nbr_val))

    @property
    def density(self) -> float:
        """Global edge density nnz/N² of the planned layout."""
        return self.num_edges / max(self.n * self.n, 1)

    def bytes_per_aggregate(self, feature_dim: int,
                            dtype_bytes: int = 4) -> int:
        """Cross-device traffic per layer. "gather" layout: every device
        receives the other devices' [B, F] halo buffers (ring all-gather
        model). "pair" layout: the all_to_all moves one [B, F] chunk per
        *ordered pair* of distinct devices — same formula, but B is the
        per-pair send bound, which only counts rows the receiver reads."""
        p, b = self.num_devices, self.halo
        return p * (p - 1) * b * feature_dim * dtype_bytes

    def replicate_bytes_per_aggregate(self, feature_dim: int,
                                      dtype_bytes: int = 4) -> int:
        """Traffic of the replicate-everything baseline: every device ships
        its whole [L, F] block to every peer each layer — what serving
        would pay without the halo layout (the multihost bench's
        denominator)."""
        p = self.num_devices
        return p * (p - 1) * self.block * feature_dim * dtype_bytes

    def dense_adj_ext(self) -> np.ndarray:
        """Materialize (and memoize) the dense [P, L, L+P*B] blocks from the
        blocked-sparse form. Only for small layouts / the dense kernel."""
        if self.adj_ext is None:
            out = np.zeros((self.num_devices, self.block, self.ext_cols),
                           np.float32)
            pp = np.arange(self.num_devices)[:, None, None]
            ll = np.arange(self.block)[None, :, None]
            np.add.at(out, (np.broadcast_to(pp, self.nbr_idx.shape),
                            np.broadcast_to(ll, self.nbr_idx.shape),
                            self.nbr_idx), self.nbr_val)
            self.adj_ext = out
        return self.adj_ext

    def scatter(self, x: np.ndarray, fill: float = 0.0) -> np.ndarray:
        """[N, ...] global array → [P, L, ...] per-device blocks."""
        out = np.full((self.padded_n,) + x.shape[1:], fill, x.dtype)
        valid = self.perm >= 0
        out[valid] = x[self.perm[valid]]
        return out.reshape((self.num_devices, self.block) + x.shape[1:])

    def gather(self, blocks: np.ndarray) -> np.ndarray:
        """[P, L, ...] → [N, ...] (inverse of scatter)."""
        flat = np.asarray(blocks).reshape((self.padded_n,) + blocks.shape[2:])
        out = np.zeros((self.n,) + flat.shape[1:], flat.dtype)
        valid = self.perm >= 0
        out[self.perm[valid]] = flat[valid]
        return out

    def scatter_batch(self, xs, pad_to: int | None = None) -> np.ndarray:
        """Stack B global [N, F] arrays into the batched-forward layout
        [P, B', L, F] (device-major, so the mesh sharding spec is the same
        as the single-request path). ``pad_to`` zero-pads the batch axis to
        a fixed bucket size so batch shapes — and therefore jit compiles —
        stay bounded."""
        b = len(xs) if pad_to is None else int(pad_to)
        assert b >= len(xs), (b, len(xs))
        blocks = [self.scatter(np.asarray(x, np.float32)) for x in xs]
        out = np.zeros((self.num_devices, b) + blocks[0].shape[1:],
                       np.float32)
        for i, blk in enumerate(blocks):
            out[:, i] = blk
        return out

    def gather_batch(self, blocks: np.ndarray, count: int | None = None
                     ) -> list[np.ndarray]:
        """[P, B', L, ...] → ``count`` global [N, ...] arrays (padded batch
        slots beyond ``count`` are dropped)."""
        blocks = np.asarray(blocks)
        count = blocks.shape[1] if count is None else int(count)
        return [self.gather(blocks[:, i]) for i in range(count)]


def make_partition_plan_sparse(edges: np.ndarray, assign: np.ndarray,
                               num_devices: int, n: int | None = None,
                               weights: np.ndarray | None = None,
                               exchange: str = "gather") -> PartitionPlan:
    """Build the halo-exchange plan from a COO edge list — O(E), no N×N.

    ``edges`` is [E, 2] *unique undirected* pairs (i ≠ j, any order); an
    optional ``weights`` [E] carries per-edge values (default 1.0).
    With ``exchange="gather"`` semantics match
    :func:`make_partition_plan_dense_reference` exactly: same perm (owned
    vertices ascending per device), same boundary order, same
    extended-column layout. ``exchange="pair"`` builds the halo-only
    layout instead: per-(sender, receiver) send lists and extended columns
    addressing the all_to_all receive buffer, so cross-device traffic is
    exactly the cut rows (see :class:`PartitionPlan`)."""
    if exchange not in ("gather", "pair"):
        raise ValueError(f"unknown exchange {exchange!r}")
    assign = np.asarray(assign, np.int64)
    n = len(assign) if n is None else int(n)
    assert len(assign) == n, (len(assign), n)
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    w = (np.ones(len(edges), np.float32) if weights is None
         else np.asarray(weights, np.float32))
    active = assign >= 0

    # perm / local slots: actives grouped by device, ascending global id
    act_ids = np.nonzero(active)[0]
    order = np.argsort(assign[act_ids], kind="stable")
    owned = act_ids[order]                       # sorted by (device, id)
    dev = assign[owned]
    rank, counts = rank_within_sorted_groups(dev, num_devices)
    block = max(1, int(counts.max(initial=0)))
    perm = -np.ones(num_devices * block, np.int64)
    perm[dev * block + rank] = owned
    local_slot = -np.ones(n, np.int64)
    local_slot[owned] = rank
    mask = (np.arange(block)[None, :] < counts[:, None]).astype(np.float32)

    # symmetrize to directed edges between active endpoints
    i, j = edges.T if len(edges) else (np.zeros(0, np.int64),) * 2
    keep = active[i] & active[j] & (i != j) if len(edges) else \
        np.zeros(0, bool)
    src = np.concatenate([i[keep], j[keep]])
    dst = np.concatenate([j[keep], i[keep]])
    w2 = np.concatenate([w[keep], w[keep]])
    cross = assign[src] != assign[dst]

    if exchange == "pair":
        # per-ordered-pair send lists: device q sends row u to device p iff
        # some row p owns has u as a cross neighbor. One sorted unique pass
        # over (q, p, u) keys yields each list in ascending-global-id order.
        cq = assign[dst[cross]]                  # sender (owns the row)
        cp = assign[src[cross]]                  # receiver (reads the row)
        key = (cq * num_devices + cp) * n + dst[cross]
        uniq = np.unique(key)
        uq, rem = np.divmod(uniq, num_devices * n)
        up, uu = np.divmod(rem, n)
        p_rank, p_counts = rank_within_sorted_groups(
            uq * num_devices + up, num_devices * num_devices)
        halo = max(1, int(p_counts.max(initial=0)))
        send_idx = np.zeros((num_devices, num_devices, halo), np.int64)
        send_mask = np.zeros((num_devices, num_devices, halo), np.float32)
        send_idx[uq, up, p_rank] = local_slot[uu]
        send_mask[uq, up, p_rank] = 1.0
        # receive-buffer position of each cross edge's source row: the
        # receiver's all_to_all output stacks sender chunks [q, s, F], so
        # the extended column is block + q·halo + rank-in-(q→p)-list
        halo_col = cq * halo + p_rank[np.searchsorted(uniq, key)]
        col = local_slot[dst].copy()
        col[cross] = block + halo_col
    else:
        # boundary rows: owned vertices with ≥1 cross-device edge publish
        # once, to everyone (union of destinations)
        is_boundary = np.zeros(n, bool)
        is_boundary[src[cross]] = True
        b_ids = np.nonzero(is_boundary)[0]       # ascending global id
        b_order = np.argsort(assign[b_ids], kind="stable")
        b_sorted = b_ids[b_order]
        b_dev = assign[b_sorted]
        b_rank, b_counts = rank_within_sorted_groups(b_dev, num_devices)
        halo = max(1, int(b_counts.max(initial=0)))
        send_idx = np.zeros((num_devices, halo), np.int64)
        send_mask = np.zeros((num_devices, halo), np.float32)
        send_idx[b_dev, b_rank] = local_slot[b_sorted]
        send_mask[b_dev, b_rank] = 1.0
        halo_of = -np.ones(n, np.int64)          # flat halo-buffer position
        halo_of[b_sorted] = b_dev * halo + b_rank
        col = np.where(cross, block + halo_of[dst], local_slot[dst])

    flat_row = assign[src] * block + local_slot[src]
    nbr_idx, nbr_val = padded_neighbors_from_coo(flat_row, col, w2,
                                                 num_devices * block)
    k = nbr_idx.shape[1]
    return PartitionPlan(num_devices, block, halo, n, perm, send_idx,
                         send_mask, nbr_idx.reshape(num_devices, block, k),
                         nbr_val.reshape(num_devices, block, k), mask)


def make_partition_plan(adj: np.ndarray, assign: np.ndarray,
                        num_devices: int) -> PartitionPlan:
    """Dense entry point: N×N (symmetric, no self-loop) adjacency → plan.

    Thin wrapper over :func:`make_partition_plan_sparse` (the adjacency is
    converted to its upper-triangular edge list); the dense ``adj_ext``
    blocks are materialized eagerly so dense-input callers keep the
    blocked-matmul serving path."""
    adj = np.asarray(adj)
    i, j = np.nonzero(np.triu(adj, k=1))
    plan = make_partition_plan_sparse(np.stack([i, j], 1), assign,
                                      num_devices, n=adj.shape[0],
                                      weights=adj[i, j].astype(np.float32))
    plan.dense_adj_ext()
    return plan


def make_partition_plan_dense_reference(adj: np.ndarray, assign: np.ndarray,
                                        num_devices: int) -> PartitionPlan:
    """The original O(N²) triple-loop builder — parity oracle + perf
    baseline for the sparse path (tests/test_partition_sparse.py,
    benchmarks/bench_partition_plan.py)."""
    n = adj.shape[0]
    assign = np.asarray(assign)
    active = assign >= 0
    owned = [np.nonzero(assign == p)[0] for p in range(num_devices)]
    block = max(1, max(len(o) for o in owned))
    perm = -np.ones(num_devices * block, np.int64)
    local_slot = -np.ones(n, np.int64)
    for p, o in enumerate(owned):
        perm[p * block:p * block + len(o)] = o
        local_slot[o] = np.arange(len(o))

    cross = adj * (assign[:, None] != assign[None, :]) * \
        active[:, None] * active[None, :]
    boundary = [np.nonzero((cross[o] > 0).any(1))[0] if len(o) else
                np.zeros(0, np.int64) for o in owned]     # local indices
    halo = max(1, max(len(b) for b in boundary))
    send_idx = np.zeros((num_devices, halo), np.int64)
    send_mask = np.zeros((num_devices, halo), np.float32)
    for p, b in enumerate(boundary):
        send_idx[p, :len(b)] = b
        send_mask[p, :len(b)] = 1.0

    # global position of each published row in the flattened halo buffer
    halo_of: dict[int, int] = {}
    for p, b in enumerate(boundary):
        for slot, li in enumerate(b):
            halo_of[int(owned[p][li])] = p * halo + slot

    ext_cols = block + num_devices * halo
    adj_ext = np.zeros((num_devices, block, ext_cols), np.float32)
    for p, o in enumerate(owned):
        for li, g in enumerate(o):
            for gj in np.nonzero(adj[g])[0]:
                if not active[gj]:
                    continue
                if assign[gj] == p:
                    adj_ext[p, li, local_slot[gj]] = adj[g, gj]
                else:
                    adj_ext[p, li, block + halo_of[int(gj)]] = adj[g, gj]

    mask = np.zeros((num_devices, block), np.float32)
    for p, o in enumerate(owned):
        mask[p, :len(o)] = 1.0
    # padded neighbor form of the same blocks (row-major nonzero order)
    pidx, li, ci = np.nonzero(adj_ext)
    nbr_idx, nbr_val = padded_neighbors_from_coo(
        pidx * block + li, ci, adj_ext[pidx, li, ci], num_devices * block)
    k = nbr_idx.shape[1]
    return PartitionPlan(num_devices, block, halo, n, perm, send_idx,
                         send_mask, nbr_idx.reshape(num_devices, block, k),
                         nbr_val.reshape(num_devices, block, k), mask,
                         adj_ext)


# ---------------------------------------------------------------------------
# cross-topology shape buckets (DESIGN.md §7 "Cross-topology batching")
# ---------------------------------------------------------------------------

# Plans pad their (block, halo, max_degree) slot shapes up to multiples of
# this quantum before joining a cross-topology batch, so dynamically
# perturbed topologies whose plans differ by a few vertices/edges land in
# the SAME shape bucket (one compiled executable, one dispatch) instead of
# one bucket each. Larger quanta share more but pad more.
PLAN_BUCKET_QUANTUM = 8


def _ceil_to(v: int, q: int) -> int:
    return max(q, -(-int(v) // q) * q)


def plan_bucket(plan: PartitionPlan,
                quantum: int = PLAN_BUCKET_QUANTUM) -> tuple:
    """Shape bucket of a plan: ``(P, n, block', halo', k')`` with the slot
    dims rounded up to ``quantum``. Two plans in the same bucket can be
    padded (:func:`pad_plan`) to identical array shapes and served by one
    dispatch of :func:`_forward_blocks_multi` — the bucket tuple *is* the
    cross-topology batch key (the jit cache then keys on these shapes)."""
    base = (plan.num_devices, plan.n, _ceil_to(plan.block, quantum),
            _ceil_to(plan.halo, quantum), _ceil_to(plan.max_degree, quantum))
    # the two exchange layouts are never batch-compatible: same dims mean
    # different extended-column semantics, so pair plans get their own key
    return base + (("pair",) if plan.exchange == "pair" else ())


def pad_plan(plan: PartitionPlan, block: int, halo: int,
             k: int) -> PartitionPlan:
    """Pad a plan to ``(block, halo, k)`` slot shapes, exactly preserving
    its forward semantics.

    Padding appends inert slots only: pad rows carry ``mask = 0`` and zero
    neighbor values, pad halo slots carry ``send_mask = 0`` (they publish
    zero rows), pad neighbor slots carry value 0. Extended-column ids are
    remapped to the widened ``[block' | P × halo']`` layout — a cross-edge
    at old position ``q·halo + s`` of the flattened halo buffer moves to
    ``q·halo' + s``, so every gathered value is unchanged and the padded
    forward is numerically identical to the original (the scan-based
    aggregates are *bitwise* identical: pads only ever add exact zeros)."""
    p = plan.num_devices
    assert block >= plan.block and halo >= plan.halo \
        and k >= plan.max_degree, ((block, halo, k),
                                   (plan.block, plan.halo, plan.max_degree))
    perm = -np.ones((p, block), np.int64)
    perm[:, :plan.block] = plan.perm.reshape(p, plan.block)
    # send maps pad on the slot axis only — [P, H] (gather) and [P, P, H]
    # (pair) both keep their leading layout axes
    send_idx = np.zeros(plan.send_idx.shape[:-1] + (halo,), np.int64)
    send_idx[..., :plan.halo] = plan.send_idx
    send_mask = np.zeros(plan.send_mask.shape[:-1] + (halo,), np.float32)
    send_mask[..., :plan.halo] = plan.send_mask
    mask = np.zeros((p, block), np.float32)
    mask[:, :plan.block] = plan.mask
    # neighbor slots: remap extended cols into the widened layout, then pad
    old_idx, old_val = plan.nbr_idx, plan.nbr_val
    flat_halo = old_idx - plan.block          # q·halo + s for cross edges
    remapped = np.where(
        old_idx >= plan.block,
        block + (flat_halo // plan.halo) * halo + flat_halo % plan.halo,
        old_idx)
    remapped = np.where(old_val != 0, remapped, 0)   # pad slots → col 0
    nbr_idx = np.zeros((p, block, k), np.int64)
    nbr_val = np.zeros((p, block, k), np.float32)
    nbr_idx[:, :plan.block, :plan.max_degree] = remapped
    nbr_val[:, :plan.block, :plan.max_degree] = old_val
    return PartitionPlan(p, block, halo, plan.n, perm.reshape(-1), send_idx,
                         send_mask, nbr_idx, nbr_val, mask)


def pad_plan_to_bucket(plan: PartitionPlan, bucket: tuple) -> PartitionPlan:
    """Pad a plan to its (or a compatible) :func:`plan_bucket` shape."""
    p, n, block, halo, k = bucket[:5]
    exch = bucket[5] if len(bucket) > 5 else "gather"
    assert (p, n, plan.exchange) == (plan.num_devices, plan.n, exch), \
        (bucket, plan.num_devices, plan.n, plan.exchange)
    return pad_plan(plan, block, halo, k)


def scatter_multi(plans: Sequence[PartitionPlan], xs,
                  pad_to: int | None = None) -> np.ndarray:
    """Per-member scatter into one [P, B', L, F] cross-topology batch:
    member i's features are laid out by *its own* plan's perm (the plans
    must share a shape bucket). ``pad_to`` zero-fills the batch axis."""
    b = len(xs) if pad_to is None else int(pad_to)
    assert b >= len(xs) and len(plans) >= len(xs), (b, len(xs), len(plans))
    blocks = [plan.scatter(np.asarray(x, np.float32))
              for plan, x in zip(plans, xs)]
    out = np.zeros((blocks[0].shape[0], b) + blocks[0].shape[1:], np.float32)
    for i, blk in enumerate(blocks):
        out[:, i] = blk
    return out


def gather_multi(plans: Sequence[PartitionPlan], blocks: np.ndarray,
                 count: int | None = None) -> list[np.ndarray]:
    """Inverse of :func:`scatter_multi`: member i's output is gathered by
    its own plan's perm (padded batch slots beyond ``count`` dropped)."""
    blocks = np.asarray(blocks)
    count = blocks.shape[1] if count is None else int(count)
    return [plans[i].gather(blocks[:, i]) for i in range(count)]


def _halo_exchange(x_blk, send_idx, send_mask, axis: str):
    """Exchange boundary rows: [L, F] → extended rows [L + P·B, F].

    Dispatches on the send map's rank (static at trace time, so every
    jitted forward gains both paths without signature changes):

    * gather layout (``send_idx`` [B]): publish the boundary-row union
      once and ``all_gather`` every device's buffer — each device receives
      P·B rows whether it reads them or not.
    * pair layout (``send_idx`` [P, B]): build one [B, F] chunk per
      destination and ``all_to_all`` them — device p's chunk q holds
      exactly the rows q sends to p, so the wire carries only cut rows
      and the receive buffer is already in extended-column order."""
    if send_idx.ndim == 2:
        published = x_blk[send_idx] * send_mask[..., None]   # [P, B, F]
        halo = jax.lax.all_to_all(published, axis, 0, 0)     # [P, B, F]
    else:
        published = x_blk[send_idx] * send_mask[:, None]
        halo = jax.lax.all_gather(published, axis)           # [P, B, F]
    return jnp.concatenate([x_blk, halo.reshape(-1, halo.shape[-1])], 0)


def _halo_aggregate(x_blk, adj_ext_blk, send_idx, send_mask,
                    rs, cs_ext, axis: str):
    """One distributed normalized aggregation step (runs per device).

    x_blk [L, F]; returns rs·A_ext·cs @ [x_own ; halo]."""
    x_ext = _halo_exchange(x_blk, send_idx, send_mask, axis)
    a = adj_ext_blk * rs[:, None] * cs_ext[None, :]
    return a @ x_ext


def _halo_aggregate_sparse(x_blk, nbr_idx_blk, nbr_val_blk, send_idx,
                           send_mask, rs, cs_ext, axis: str):
    """Sparse variant: gather/scan over the padded neighbor slots instead
    of the [L, L + P·B] dense contraction — O(L·K·F)."""
    x_ext = _halo_exchange(x_blk, send_idx, send_mask, axis)
    xc = x_ext * cs_ext[:, None]

    def step(acc, slot):
        idx_k, val_k = slot
        return acc + val_k[:, None] * xc[idx_k], None

    acc, _ = jax.lax.scan(
        step, jnp.zeros_like(x_blk),
        (nbr_idx_blk.T.astype(jnp.int32), nbr_val_blk.T))
    return acc * rs[:, None]


# Per-layer aggregation step, one per `aggregate` mode. Uniform signature
# (h, w, a_args, sidx, smask, rs, cs_e, axis) → aggregated [L, F_out]: each
# mode places the layer matmul itself, because the fused mode reorders it —
# aggregate the *pre-matmul* activations at F_in width, then project, the
# formulation the fused Pallas kernel (kernels.gnn_aggregate.fused)
# executes on TPU as one gather→MXU pass. Linearity makes all three equal.
# Note the fused halo exchange consequently carries F_in-wide rows where
# dense/sparse exchange F_out-wide ones.

def _agg_step_dense(h, w, a_args, sidx, smask, rs, cs_e, axis: str):
    return _halo_aggregate(h @ w, a_args[0], sidx, smask, rs, cs_e, axis)


def _agg_step_sparse(h, w, a_args, sidx, smask, rs, cs_e, axis: str):
    return _halo_aggregate_sparse(h @ w, a_args[0], a_args[1], sidx, smask,
                                  rs, cs_e, axis)


def _agg_step_fused(h, w, a_args, sidx, smask, rs, cs_e, axis: str):
    agg = _halo_aggregate_sparse(h, a_args[0], a_args[1], sidx, smask, rs,
                                 cs_e, axis)
    return agg @ w


_AGG_STEPS = {"dense": _agg_step_dense, "sparse": _agg_step_sparse,
              "fused": _agg_step_fused}


# Per-slot cost ratio of the gather path vs one dense MAC column: a padded
# neighbor slot costs a random-access row load + FMA where the dense matmul
# streams MXU-aligned tiles. Calibrated on the BENCH_kernels /
# BENCH_partition shapes: dense wins at n=1000 (ext_cols=1004, K=34–35,
# 1004 < 32·35) and loses from n=2000 up (ext_cols≥4154, K≈36–39) — the
# crossover sits well between those, so the exact ratio has margin on
# both sides.
DENSE_AUTO_SLOT_RATIO = 32


def resolve_aggregate(plan: PartitionPlan, aggregate: str = "auto") -> str:
    """Select the per-device contraction: "dense", "sparse" or "fused".

    "auto" compares per-row *work*, not density: the dense path does
    ``ext_cols`` streaming MACs per row, the gather path ``max_degree + 1``
    random-access slot gathers (self-loop included), each worth roughly
    ``DENSE_AUTO_SLOT_RATIO`` dense MACs. Small extended blocks → "dense",
    else "fused" (the gather+normalize+matmul kernel,
    ``kernels.gnn_aggregate.fused``). Density alone mispredicts compact
    layouts — the BENCH_partition n=1000 plan has density 0.02 (well under
    ``SPARSE_DENSITY_THRESHOLD``) yet its 1004-wide extended block keeps
    the dense matmul faster than any gather (agg_speedup 0.85× under the
    old rule). ``bytes_per_aggregate`` (the collective volume) does not
    discriminate: it is layout-independent at equal feature width — only
    the per-device contraction differs between the paths."""
    if aggregate == "auto":
        dense_cols = DENSE_AUTO_SLOT_RATIO * (plan.max_degree + 1)
        return "dense" if plan.ext_cols < dense_cols else "fused"
    if aggregate not in ("dense", "sparse", "fused"):
        raise ValueError(f"unknown aggregate {aggregate!r}")
    return aggregate


def _plan_consts(plan: PartitionPlan, aggregate: str):
    """One-time numpy prep of everything the forward needs from a plan:
    (dinv, cs_ext, agg_args) — the fused-normalization scales and the
    extended adjacency in the selected layout (all jnp, ready to ship)."""
    p_dev, block, halo = plan.num_devices, plan.block, plan.halo
    # global GCN normalization (Â = A+I, D̃^-1/2) computed from the plan mask
    deg_blocks = plan.nbr_val.sum(2) + plan.mask       # self-loop
    dinv = np.where(deg_blocks > 0, 1.0 / np.sqrt(np.maximum(deg_blocks,
                                                             1e-9)), 0.0)
    dinv = dinv.astype(np.float32)
    # extended column scales: own block + halo rows (their global dinv).
    dinv_flat = dinv.reshape(-1)                       # per (p, local)
    if plan.exchange == "pair":
        # per-destination halo segments: device p's slot (q, s) holds the
        # row q sends *to p* (send_idx[q, p, s]) — each device has its own
        # receive buffer, unlike the broadcast gather layout below
        src_slots = np.arange(p_dev)[:, None, None] * block + plan.send_idx
        vals = dinv_flat[src_slots] * plan.send_mask   # [q, p, s]
        cs_halo = vals.transpose(1, 0, 2).reshape(p_dev, p_dev * halo)
    else:
        # the halo segment is the same on every device: slot (q, s) of the
        # flat buffer holds the row published from device q's send_idx[q,s]
        src_slots = np.arange(p_dev)[:, None] * block + plan.send_idx
        flat = (dinv_flat[src_slots] * plan.send_mask).reshape(-1)
        cs_halo = np.broadcast_to(flat, (p_dev, p_dev * halo))
    cs_ext = np.concatenate([dinv, cs_halo], axis=1).astype(np.float32)

    if aggregate == "dense":
        # add self-loops to the extended adjacency (own-block diagonal)
        adj_ext = plan.dense_adj_ext().copy()
        idx = np.arange(block)
        adj_ext[:, idx, idx] += plan.mask
        agg_args = (jnp.asarray(adj_ext),)
    else:
        # self-loops as one extra neighbor slot: col = own slot, val = mask
        self_idx = np.broadcast_to(np.arange(block, dtype=np.int32),
                                   (p_dev, block))[..., None]
        nbr_idx = np.concatenate([plan.nbr_idx.astype(np.int32), self_idx],
                                 axis=2)
        nbr_val = np.concatenate([plan.nbr_val, plan.mask[..., None]],
                                 axis=2)
        if aggregate == "fused":
            # the blocked kernel's sort-by-slot prefetch pass (host-side)
            nbr_idx, nbr_val = sort_neighbor_slots(nbr_idx, nbr_val)
        agg_args = (jnp.asarray(nbr_idx), jnp.asarray(nbr_val))
    return jnp.asarray(dinv), jnp.asarray(cs_ext), agg_args


def _device_layers(x_blk, sidx, smask, rs, cs_e, mask_blk, a_args, ws_,
                   agg_fn, axis: str):
    """The per-device multi-layer GCN body shared by the single-request and
    batched forwards: x_blk [L, F_in] → masked [L, F_out]."""
    h = x_blk
    for i, w in enumerate(ws_):
        h = agg_fn(h, w, a_args, sidx, smask, rs, cs_e, axis)
        if i < len(ws_) - 1:
            h = jax.nn.relu(h)
    return h * mask_blk[:, None]


@partial(jax.jit, static_argnames=("mesh", "axis", "aggregate"))
def _forward_blocks(mesh: Mesh, axis: str, aggregate: str, x_blocks,
                    send_idx, send_mask, dinv, cs_ext, mask, agg_args, ws):
    """Jitted multi-layer forward over the plan's block layout. Returns the
    [P, L, F_out] output blocks as a device array (no host sync). The jit
    cache is keyed on (mesh, axis, aggregate) + array shapes, so repeated
    serving steps — and different plans with equal block/halo/K shapes —
    reuse one compiled executable."""
    agg_fn = _AGG_STEPS[aggregate]

    def device_fn(x_blk, sidx, smask, rs, cs_e, mask_blk, a_args, ws_):
        # strip the sharded leading axis (block size 1 per device)
        x_blk, sidx, smask = x_blk[0], sidx[0], smask[0]
        rs, cs_e, mask_blk = rs[0], cs_e[0], mask_blk[0]
        a_args = tuple(a[0] for a in a_args)
        return _device_layers(x_blk, sidx, smask, rs, cs_e, mask_blk,
                              a_args, ws_, agg_fn, axis)[None]

    specs_in = (P(axis),) * 7 + (P(),)       # agg_args sharded, ws replicated
    fn = shard_map(device_fn, mesh=mesh, in_specs=specs_in,
                   out_specs=P(axis), check_rep=False)
    return fn(x_blocks, send_idx, send_mask, dinv, cs_ext, mask, agg_args,
              ws)


@partial(jax.jit, static_argnames=("mesh", "axis", "aggregate"))
def _forward_blocks_batched(mesh: Mesh, axis: str, aggregate: str, x_blocks,
                            send_idx, send_mask, dinv, cs_ext, mask,
                            agg_args, ws):
    """Batched twin of :func:`_forward_blocks`: ``x_blocks`` is
    [P, B, L, F] (device-major so the sharding spec is unchanged) and every
    batch element runs the same plan's forward — the halo all-gather and
    the per-device aggregation are vmapped over B *inside* the shard_map
    body, so B concurrent requests on one cached plan cost a single XLA
    dispatch and one collective stream instead of B. The jit cache is
    keyed on shapes, so each batch-size bucket compiles once."""
    agg_fn = _AGG_STEPS[aggregate]

    def device_fn(x_bb, sidx, smask, rs, cs_e, mask_blk, a_args, ws_):
        x_bb, sidx, smask = x_bb[0], sidx[0], smask[0]     # [B, L, F]
        rs, cs_e, mask_blk = rs[0], cs_e[0], mask_blk[0]
        a_args = tuple(a[0] for a in a_args)

        def one(x_blk):
            return _device_layers(x_blk, sidx, smask, rs, cs_e, mask_blk,
                                  a_args, ws_, agg_fn, axis)
        return jax.vmap(one)(x_bb)[None]

    specs_in = (P(axis),) * 7 + (P(),)
    fn = shard_map(device_fn, mesh=mesh, in_specs=specs_in,
                   out_specs=P(axis), check_rep=False)
    return fn(x_blocks, send_idx, send_mask, dinv, cs_ext, mask, agg_args,
              ws)


class PlanConsts(NamedTuple):
    """Everything the forward needs from one plan, prepped as jnp arrays
    (:func:`prepare_plan_consts`). Cross-topology batches stack B of these
    — one per member plan, all padded to a shared :func:`plan_bucket` —
    along a batch axis and vmap the device body over them."""
    send_idx: jnp.ndarray     # [P, H]
    send_mask: jnp.ndarray    # [P, H]
    dinv: jnp.ndarray         # [P, L]
    cs_ext: jnp.ndarray       # [P, L + P·H]
    mask: jnp.ndarray         # [P, L]
    agg_args: tuple           # aggregate-layout arrays, each [P, ...]


def prepare_plan_consts(plan: PartitionPlan, aggregate: str) -> PlanConsts:
    """One-time per-plan prep (:func:`_plan_consts` + send maps) in the
    stackable :class:`PlanConsts` form. ``aggregate`` must be resolved."""
    dinv, cs_ext, agg_args = _plan_consts(plan, aggregate)
    return PlanConsts(jnp.asarray(plan.send_idx),
                      jnp.asarray(plan.send_mask), dinv, cs_ext,
                      jnp.asarray(plan.mask), agg_args)


@partial(jax.jit, static_argnames=("mesh", "axis", "aggregate"))
def _forward_blocks_multi(mesh: Mesh, axis: str, aggregate: str, x_blocks,
                          consts: PlanConsts, ws):
    """Cross-topology twin of :func:`_forward_blocks_batched`: ``x_blocks``
    is [P, B, L, F] and every per-plan constant in ``consts`` carries the
    same batch axis ([P, B, ...]) — batch member i is served against *its
    own* plan's send maps, normalization scales and extended adjacency,
    so one dispatch serves B requests resolved against B **different**
    cached plans (padded to one shape bucket). The per-member math is the
    single-plan :func:`_device_layers` body vmapped over (x, consts)
    inside the shard_map, so the collective stream stays single. The jit
    cache keys on shapes = the bucket, so each bucket compiles once per
    batch-size bucket."""
    agg_fn = _AGG_STEPS[aggregate]

    def device_fn(x_bb, sidx, smask, rs, cs_e, mask_blk, a_args, ws_):
        x_bb, sidx, smask = x_bb[0], sidx[0], smask[0]     # [B, ...]
        rs, cs_e, mask_blk = rs[0], cs_e[0], mask_blk[0]
        a_args = tuple(a[0] for a in a_args)

        def one(x_blk, sidx_b, smask_b, rs_b, cs_b, mask_b, args_b):
            return _device_layers(x_blk, sidx_b, smask_b, rs_b, cs_b,
                                  mask_b, args_b, ws_, agg_fn, axis)
        return jax.vmap(one)(x_bb, sidx, smask, rs, cs_e, mask_blk,
                             a_args)[None]

    specs_in = (P(axis),) * 7 + (P(),)
    fn = shard_map(device_fn, mesh=mesh, in_specs=specs_in,
                   out_specs=P(axis), check_rep=False)
    return fn(x_blocks, consts.send_idx, consts.send_mask, consts.dinv,
              consts.cs_ext, consts.mask, consts.agg_args, ws)


def make_multi_forward_fn(mesh: Mesh, axis: str, aggregate: str,
                          consts: Sequence[PlanConsts]):
    """B per-plan :class:`PlanConsts` (same bucket shapes) → one reusable
    non-blocking cross-topology forward.

    Stacks the members' constants along the batch axis once and returns
    ``forward(x_blocks, params)`` over [P, B, L, F] blocks
    (:func:`scatter_multi`) dispatching :func:`_forward_blocks_multi` —
    the cross-topology continuous-batching hot path of
    :class:`repro.serve.frontend.StreamingFrontend`. ``aggregate`` must be
    pre-resolved (resolve on any padded member: bucket mates agree)."""
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=1),
                                     *consts)

    def forward(x_blocks, params):
        ws = tuple(jnp.asarray(layer["w"]) for layer in params)
        return _forward_blocks_multi(mesh, axis, aggregate,
                                     jnp.asarray(x_blocks), stacked, ws)
    return forward


def make_forward_fn(mesh: Mesh, axis: str, plan: PartitionPlan,
                    aggregate: str = "auto"):
    """Plan → reusable non-blocking forward.

    Does the per-plan numpy prep (normalization scales, extended adjacency,
    send maps) exactly once and returns ``forward(x_blocks, params)`` which
    dispatches the jitted computation and immediately returns the [P, L, F]
    output blocks as a device array — callers overlap host work with the
    in-flight computation and block only when they fetch
    (``plan.gather(np.asarray(out))``). This is the serving engine's hot
    path (``repro.serve.engine``)."""
    aggregate = resolve_aggregate(plan, aggregate)
    dinv, cs_ext, agg_args = _plan_consts(plan, aggregate)
    send_idx = jnp.asarray(plan.send_idx)
    send_mask = jnp.asarray(plan.send_mask)
    mask = jnp.asarray(plan.mask)

    def forward(x_blocks, params):
        ws = tuple(jnp.asarray(layer["w"]) for layer in params)
        return _forward_blocks(mesh, axis, aggregate, jnp.asarray(x_blocks),
                               send_idx, send_mask, dinv, cs_ext, mask,
                               agg_args, ws)
    return forward


def make_batched_forward_fn(mesh: Mesh, axis: str, plan: PartitionPlan,
                            aggregate: str = "auto"):
    """Plan → reusable non-blocking *batched* forward.

    Same one-time prep as :func:`make_forward_fn`, but the returned
    ``forward(x_blocks, params)`` takes [P, B, L, F] blocks
    (``plan.scatter_batch``) and serves all B requests as one dispatch of
    :func:`_forward_blocks_batched` — the continuous-batching hot path of
    :class:`repro.serve.frontend.StreamingFrontend`. Each distinct B
    compiles once; callers bound compile count by padding B to buckets."""
    aggregate = resolve_aggregate(plan, aggregate)
    dinv, cs_ext, agg_args = _plan_consts(plan, aggregate)
    send_idx = jnp.asarray(plan.send_idx)
    send_mask = jnp.asarray(plan.send_mask)
    mask = jnp.asarray(plan.mask)

    def forward(x_blocks, params):
        ws = tuple(jnp.asarray(layer["w"]) for layer in params)
        return _forward_blocks_batched(mesh, axis, aggregate,
                                       jnp.asarray(x_blocks), send_idx,
                                       send_mask, dinv, cs_ext, mask,
                                       agg_args, ws)
    return forward


def distributed_gcn_forward(mesh: Mesh, axis: str, plan: PartitionPlan,
                            params, x: np.ndarray,
                            aggregate: str = "auto") -> np.ndarray:
    """Two-(or more-)layer GCN inference, vertex-partitioned over ``axis``.

    Matches ``repro.gnn.layers.gcn_apply`` exactly (tested); collective
    traffic = plan.bytes_per_aggregate per layer. ``aggregate`` selects the
    per-device contraction: "dense" (blocked matmul over adj_ext), "sparse"
    (gather/scan over the plan's padded neighbor lists), "fused" (the
    gather+normalize+matmul formulation of
    ``kernels.gnn_aggregate.fused``, slot-sorted layout), or "auto"
    (:func:`resolve_aggregate`). One-shot blocking wrapper over
    :func:`make_forward_fn` — pipelined callers build the forward once and
    dispatch asynchronously."""
    forward = make_forward_fn(mesh, axis, plan, aggregate)
    out = forward(plan.scatter(np.asarray(x, np.float32)), params)
    return plan.gather(np.asarray(out))
