"""Multi-host SPMD serving: sharded plans, resident features, halo-only wire.

Single-process serving builds every :class:`PartitionPlan` whole — O(E·K)
neighbor arrays for all P devices on one host — and feeds numpy blocks to
the jitted forward, which XLA *replicates* to every device before the
shard_map slices its block back out. Fine on one host; at a 10⁶-vertex
graph over a process grid it ships the whole feature tensor to every
process every step. This module promotes the stack to true SPMD
(DESIGN.md §8):

* **Sharded plan construction** — :func:`make_partition_plan_shard` runs
  the cheap O(N)+O(cut) layout metadata passes (perm, send maps, degree
  scales) identically on every process, but builds the heavyweight padded
  neighbor arrays *only for the devices this process owns*. The one
  global scalar the shards must agree on — the padded slot width K —
  is a max over per-process maxima, agreed through a small metadata
  allgather (:func:`agree_metadata`) exactly as the issue prescribes.
* **Resident features** — :func:`put_feature_blocks` materializes the
  [P, L, F] block layout as a global ``jax.Array`` where each process
  places only its own blocks (``jax.make_array_from_callback``), so no
  feature row ever lands on a host that doesn't own it and the
  replicate-then-slice copy disappears from the hot path.
* **Halo-only exchange** — plans default to the ``"pair"`` layout
  (:func:`repro.gnn.distributed.make_partition_plan_sparse`), so the only
  cross-process bytes per layer are the ``all_to_all`` chunks covering
  exactly the cut edges HiCut minimized.
* **Plan cache agreement** — :class:`ShardedPlanCache` keys entries on a
  content digest of (edges, assign, P, exchange), a pure function of data
  every process holds identically, so the per-host shard caches stay in
  lockstep without coordination.

The jitted forward itself is unchanged:
:func:`repro.gnn.distributed._forward_blocks` already runs per-device
under shard_map, and with a process-spanning mesh plus globally-sharded
inputs XLA lowers the same program to multi-host SPMD. ``repro.launch.
serve_multihost`` is the CLI; ``tests/test_multihost.py`` gates bitwise
parity across process counts.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.api import LruCache
from repro.gnn.distributed import (PartitionPlan, _forward_blocks,
                                   rank_within_sorted_groups,
                                   resolve_aggregate)
from repro.kernels.gnn_aggregate.ops import (padded_neighbors_from_coo,
                                             sort_neighbor_slots)


def process_device_range(num_devices: int, process_id: int,
                         num_processes: int) -> tuple[int, int]:
    """[start, stop) of the mesh devices process ``process_id`` owns.

    Devices are split contiguously so each process's blocks are one slab
    of the [P, L, ...] layout — the order ``jax.devices()`` yields on a
    homogeneous multi-process CPU/TPU mesh."""
    assert num_devices % num_processes == 0, (num_devices, num_processes)
    per = num_devices // num_processes
    return process_id * per, (process_id + 1) * per


def agree_metadata(local: np.ndarray) -> np.ndarray:
    """Elementwise max of a small int vector across processes.

    The metadata allgather of the sharded plan build: each process offers
    the maxima it can see locally (padded slot width K of its own rows)
    and every process adopts the global max, so all shards pad to
    identical array shapes. A no-op on a single process."""
    if jax.process_count() == 1:
        return np.asarray(local)
    from jax.experimental import multihost_utils
    gathered = multihost_utils.process_allgather(np.asarray(local))
    return np.asarray(gathered).max(axis=0)


@dataclass
class PlanShard:
    """A :class:`PartitionPlan` as one process sees it: full (small)
    layout metadata, but neighbor arrays only for the locally-owned
    devices ``[dev0, dev1)``. ``wdeg`` carries every row's weighted degree
    (an O(E) ``np.add.at`` pass — float32 in-order accumulation, bitwise
    equal to the full plan's per-slot ``nbr_val.sum``), because the halo
    normalization scales need the *senders'* degrees, which live on other
    processes."""
    num_devices: int
    block: int
    halo: int
    n: int
    k: int                      # padded neighbor slots (globally agreed)
    exchange: str
    perm: np.ndarray            # [P·L] global vertex id per slot (−1 pad)
    send_idx: np.ndarray        # [P, B] or [P, P, B] (pair)
    send_mask: np.ndarray
    mask: np.ndarray            # [P, L]
    wdeg: np.ndarray            # [P, L] weighted degree (no self-loop)
    dev0: int                   # first locally-owned device
    dev1: int                   # one past the last locally-owned device
    nbr_idx: np.ndarray         # [P_local, L, K] — local devices only
    nbr_val: np.ndarray         # [P_local, L, K]

    @property
    def ext_cols(self) -> int:
        return self.block + self.num_devices * self.halo

    def bytes_per_aggregate(self, feature_dim: int,
                            dtype_bytes: int = 4) -> int:
        p, b = self.num_devices, self.halo
        return p * (p - 1) * b * feature_dim * dtype_bytes

    def replicate_bytes_per_aggregate(self, feature_dim: int,
                                      dtype_bytes: int = 4) -> int:
        p = self.num_devices
        return p * (p - 1) * self.block * feature_dim * dtype_bytes

    def to_plan(self) -> PartitionPlan:
        """The full :class:`PartitionPlan` (single-process shards only —
        the parity bridge back into ``distributed_gcn_forward``)."""
        assert (self.dev0, self.dev1) == (0, self.num_devices), \
            (self.dev0, self.dev1, self.num_devices)
        return PartitionPlan(self.num_devices, self.block, self.halo,
                             self.n, self.perm, self.send_idx,
                             self.send_mask, self.nbr_idx, self.nbr_val,
                             self.mask)

    def gather(self, blocks: np.ndarray) -> np.ndarray:
        """[P, L, ...] host blocks → [n, ...] global rows (inverse perm)."""
        flat = np.asarray(blocks).reshape(
            (self.num_devices * self.block,) + blocks.shape[2:])
        out = np.zeros((self.n,) + flat.shape[1:], flat.dtype)
        valid = self.perm >= 0
        out[self.perm[valid]] = flat[valid]
        return out


def plan_shard_key(edges: np.ndarray, assign: np.ndarray, num_devices: int,
                   exchange: str) -> str:
    """Content digest keying the per-host plan-shard caches. A pure
    function of arrays every process derives from the same request state,
    so all hosts' caches hit and miss in lockstep — the multi-host twin of
    the engine's ``(topology_key, assignment_digest)`` key."""
    h = hashlib.blake2b(digest_size=16)
    h.update(np.ascontiguousarray(edges, np.int64).tobytes())
    h.update(np.ascontiguousarray(assign, np.int64).tobytes())
    h.update(np.int64(num_devices).tobytes())
    h.update(exchange.encode())
    return h.hexdigest()


def make_partition_plan_shard(edges: np.ndarray, assign: np.ndarray,
                              num_devices: int, n: int | None = None,
                              weights: np.ndarray | None = None,
                              exchange: str = "pair",
                              process_id: int | None = None,
                              num_processes: int | None = None) -> PlanShard:
    """Sharded twin of :func:`make_partition_plan_sparse`.

    Every process runs the identical O(N) perm pass and O(cut) send-map
    pass (deterministic, so the layouts agree without communication), an
    O(E) degree pass (``np.add.at``), and then builds the padded neighbor
    arrays **only for rows its own devices serve** — the O(E·K) sort and
    materialization that dominates plan build time and memory is divided
    across the process grid. The padded slot width is agreed through
    :func:`agree_metadata`. ``process_id``/``num_processes`` default to
    the live ``jax.distributed`` topology."""
    if exchange not in ("gather", "pair"):
        raise ValueError(f"unknown exchange {exchange!r}")
    pid = jax.process_index() if process_id is None else int(process_id)
    nproc = jax.process_count() if num_processes is None \
        else int(num_processes)
    dev0, dev1 = process_device_range(num_devices, pid, nproc)

    assign = np.asarray(assign, np.int64)
    n = len(assign) if n is None else int(n)
    assert len(assign) == n, (len(assign), n)
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    w = (np.ones(len(edges), np.float32) if weights is None
         else np.asarray(weights, np.float32))
    active = assign >= 0

    # -- global layout metadata (identical on every process) -----------------
    act_ids = np.nonzero(active)[0]
    order = np.argsort(assign[act_ids], kind="stable")
    owned = act_ids[order]
    dev = assign[owned]
    rank, counts = rank_within_sorted_groups(dev, num_devices)
    block = max(1, int(counts.max(initial=0)))
    perm = -np.ones(num_devices * block, np.int64)
    perm[dev * block + rank] = owned
    local_slot = -np.ones(n, np.int64)
    local_slot[owned] = rank
    mask = (np.arange(block)[None, :] < counts[:, None]).astype(np.float32)

    i, j = edges.T if len(edges) else (np.zeros(0, np.int64),) * 2
    keep = active[i] & active[j] & (i != j) if len(edges) else \
        np.zeros(0, bool)
    src = np.concatenate([i[keep], j[keep]])
    dst = np.concatenate([j[keep], i[keep]])
    w2 = np.concatenate([w[keep], w[keep]])
    cross = assign[src] != assign[dst]

    if exchange == "pair":
        cq = assign[dst[cross]]
        cp = assign[src[cross]]
        key = (cq * num_devices + cp) * n + dst[cross]
        uniq = np.unique(key)
        uq, rem = np.divmod(uniq, num_devices * n)
        up, uu = np.divmod(rem, n)
        p_rank, p_counts = rank_within_sorted_groups(
            uq * num_devices + up, num_devices * num_devices)
        halo = max(1, int(p_counts.max(initial=0)))
        send_idx = np.zeros((num_devices, num_devices, halo), np.int64)
        send_mask = np.zeros((num_devices, num_devices, halo), np.float32)
        send_idx[uq, up, p_rank] = local_slot[uu]
        send_mask[uq, up, p_rank] = 1.0
        halo_col = cq * halo + p_rank[np.searchsorted(uniq, key)]
        col = local_slot[dst].copy()
        col[cross] = block + halo_col
    else:
        is_boundary = np.zeros(n, bool)
        is_boundary[src[cross]] = True
        b_ids = np.nonzero(is_boundary)[0]
        b_order = np.argsort(assign[b_ids], kind="stable")
        b_sorted = b_ids[b_order]
        b_dev = assign[b_sorted]
        b_rank, b_counts = rank_within_sorted_groups(b_dev, num_devices)
        halo = max(1, int(b_counts.max(initial=0)))
        send_idx = np.zeros((num_devices, halo), np.int64)
        send_mask = np.zeros((num_devices, halo), np.float32)
        send_idx[b_dev, b_rank] = local_slot[b_sorted]
        send_mask[b_dev, b_rank] = 1.0
        halo_of = -np.ones(n, np.int64)
        halo_of[b_sorted] = b_dev * halo + b_rank
        col = np.where(cross, block + halo_of[dst], local_slot[dst])

    flat_row = assign[src] * block + local_slot[src]
    wdeg = np.zeros(num_devices * block, np.float32)
    np.add.at(wdeg, flat_row, w2)               # in-order f32 accumulation

    # -- per-shard neighbor build (only this process's rows) -----------------
    local = (flat_row >= dev0 * block) & (flat_row < dev1 * block)
    k_local = int(np.bincount(flat_row[local] - dev0 * block,
                              minlength=1).max(initial=0))
    k = max(1, int(agree_metadata(np.array([k_local], np.int64))[0]))
    nbr_idx, nbr_val = padded_neighbors_from_coo(
        flat_row[local] - dev0 * block, col[local], w2[local],
        (dev1 - dev0) * block, min_k=k)
    return PlanShard(num_devices, block, halo, n, k, exchange, perm,
                     send_idx, send_mask, mask,
                     wdeg.reshape(num_devices, block), dev0, dev1,
                     nbr_idx.reshape(dev1 - dev0, block, k),
                     nbr_val.reshape(dev1 - dev0, block, k))


# ---------------------------------------------------------------------------
# global-array assembly (each process contributes only its own shards)
# ---------------------------------------------------------------------------

def global_blocks(mesh: Mesh, axis: str, local_np: np.ndarray,
                  dev0: int) -> jax.Array:
    """Local [P_local, ...] host blocks → global [P, ...] ``jax.Array``
    sharded one block per device along ``axis``. Only locally-addressable
    shards are materialized — the callback never touches rows this process
    doesn't own, which is what keeps per-host memory at 1/num_processes of
    the global layout."""
    p = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    local_np = np.ascontiguousarray(local_np)
    shape = (p,) + local_np.shape[1:]
    sharding = NamedSharding(mesh, P(axis))

    def cb(index):
        d = index[0].start or 0
        return local_np[d - dev0:d - dev0 + 1]

    return jax.make_array_from_callback(shape, sharding, cb)


def replicated(mesh: Mesh, value: np.ndarray) -> jax.Array:
    """Host value → fully-replicated global array (small metadata only)."""
    sharding = NamedSharding(mesh, P())
    return jax.make_array_from_callback(
        np.asarray(value).shape, sharding, lambda idx: np.asarray(value))


def put_feature_blocks(mesh: Mesh, axis: str, shard: PlanShard,
                       x: np.ndarray) -> jax.Array:
    """Global [n, F] host features → resident [P, L, F] device blocks.

    Each process permutes only the rows its devices own and places them
    shard-by-shard; no feature row is ever replicated to a non-owning
    host. This replaces the engine's ``plan.scatter`` + replicate-then-
    slice input path on the multi-host grid."""
    x = np.asarray(x, np.float32)
    p_local = shard.dev1 - shard.dev0
    out = np.zeros((p_local, shard.block) + x.shape[1:], np.float32)
    seg = shard.perm[shard.dev0 * shard.block:shard.dev1 * shard.block]
    valid = seg >= 0
    out.reshape((p_local * shard.block,) + x.shape[1:])[valid] = x[seg[valid]]
    return global_blocks(mesh, axis, out, shard.dev0)


def sharded_forward_fn(mesh: Mesh, axis: str, shard: PlanShard,
                       aggregate: str = "auto"):
    """Shard → reusable SPMD forward over resident blocks.

    Assembles the forward constants exactly as
    :func:`repro.gnn.distributed._plan_consts` does — same self-loop slot,
    same normalization — but from the shard's local arrays, placed as
    globally-sharded ``jax.Array``s (:func:`global_blocks`), then closes
    over :func:`_forward_blocks`. The returned ``forward(x_blocks,
    params)`` takes resident [P, L, F] blocks (:func:`put_feature_blocks`)
    and returns the sharded [P, L, F_out] output without ever gathering
    to a host. Returns ``(forward, aggregate)``."""
    p_dev, block, halo = shard.num_devices, shard.block, shard.halo
    p_local = shard.dev1 - shard.dev0
    lo, hi = shard.dev0, shard.dev1

    deg = shard.wdeg + shard.mask                    # self-loop
    dinv = np.where(deg > 0,
                    1.0 / np.sqrt(np.maximum(deg, 1e-9)), 0.0)
    dinv = dinv.astype(np.float32)
    dinv_flat = dinv.reshape(-1)
    if shard.exchange == "pair":
        src_slots = np.arange(p_dev)[:, None, None] * block + shard.send_idx
        vals = dinv_flat[src_slots] * shard.send_mask
        cs_halo = vals.transpose(1, 0, 2).reshape(p_dev, p_dev * halo)
    else:
        src_slots = np.arange(p_dev)[:, None] * block + shard.send_idx
        flat = (dinv_flat[src_slots] * shard.send_mask).reshape(-1)
        cs_halo = np.broadcast_to(flat, (p_dev, p_dev * halo))
    cs_ext = np.concatenate([dinv, cs_halo], axis=1).astype(np.float32)

    # aggregate selection needs only layout scalars — replicate the
    # resolve_aggregate inputs through a tiny plan-shaped proxy
    proxy = PartitionPlan(p_dev, block, halo, shard.n, shard.perm,
                          shard.send_idx, shard.send_mask,
                          np.zeros((p_dev, 1, shard.k), np.int64),
                          np.zeros((p_dev, 1, shard.k), np.float32),
                          shard.mask)
    aggregate = resolve_aggregate(proxy, aggregate)

    self_idx = np.broadcast_to(np.arange(block, dtype=np.int32),
                               (p_local, block))[..., None]
    nbr_idx = np.concatenate([shard.nbr_idx.astype(np.int32), self_idx],
                             axis=2)
    nbr_val = np.concatenate(
        [shard.nbr_val, shard.mask[lo:hi, :, None]], axis=2)
    if aggregate == "fused":
        nbr_idx, nbr_val = sort_neighbor_slots(nbr_idx, nbr_val)
    if aggregate == "dense":
        adj = np.zeros((p_local, block, shard.ext_cols), np.float32)
        pp = np.arange(p_local)[:, None, None]
        ll = np.arange(block)[None, :, None]
        np.add.at(adj, (np.broadcast_to(pp, nbr_idx.shape),
                        np.broadcast_to(ll, nbr_idx.shape), nbr_idx),
                  nbr_val)
        agg_args = (global_blocks(mesh, axis, adj, lo),)
    else:
        agg_args = (global_blocks(mesh, axis, nbr_idx, lo),
                    global_blocks(mesh, axis, nbr_val, lo))

    g_send_idx = global_blocks(mesh, axis, shard.send_idx[lo:hi], lo)
    g_send_mask = global_blocks(mesh, axis, shard.send_mask[lo:hi], lo)
    g_dinv = global_blocks(mesh, axis, dinv[lo:hi], lo)
    g_cs_ext = global_blocks(mesh, axis, cs_ext[lo:hi], lo)
    g_mask = global_blocks(mesh, axis, shard.mask[lo:hi], lo)

    def forward(x_blocks, params):
        ws = tuple(jnp.asarray(layer["w"]) for layer in params)
        return _forward_blocks(mesh, axis, aggregate, x_blocks, g_send_idx,
                               g_send_mask, g_dinv, g_cs_ext, g_mask,
                               agg_args, ws)

    return forward, aggregate


def fetch_global(out: jax.Array) -> np.ndarray:
    """Sharded [P, L, F] output → full host array on *every* process
    (allgather across the grid when distributed). Parity/bench tooling
    only — serving keeps outputs resident."""
    if jax.process_count() == 1:
        return np.asarray(out)
    from jax.experimental import multihost_utils
    return np.asarray(multihost_utils.process_allgather(out, tiled=True))


class ShardedPlanCache:
    """Per-host LRU of (shard, prepared forward) entries keyed on
    :func:`plan_shard_key` — the digest is derived from data every process
    holds, so the hosts' caches stay key-identical without coordination
    (the multi-host counterpart of ``ServingEngine._plan_cache``)."""

    def __init__(self, mesh: Mesh, axis: str, size: int = 16,
                 exchange: str = "pair", aggregate: str = "auto"):
        self.mesh, self.axis = mesh, axis
        self.exchange, self.aggregate = exchange, aggregate
        self._lru = LruCache(size)

    def entry(self, edges: np.ndarray, assign: np.ndarray,
              num_devices: int) -> tuple[str, PlanShard, object, bool]:
        """(key, shard, forward, cache_hit) for a (topology, assignment)."""
        key = plan_shard_key(edges, assign, num_devices, self.exchange)
        hit = self._lru.get(key)
        if hit is not None:
            return (key,) + hit + (True,)
        shard = make_partition_plan_shard(edges, assign, num_devices,
                                          exchange=self.exchange)
        forward, _ = sharded_forward_fn(self.mesh, self.axis, shard,
                                        self.aggregate)
        self._lru.put(key, (shard, forward))
        return key, shard, forward, False

    def info(self):
        return self._lru.info()
