"""GNN model management for the EC system.

The paper deploys *pre-trained* GNN models (node-classification accuracy
60–80%) on every edge server; user tasks are vertex-classification requests.
``pretrain`` trains a model on a (synthetic) citation graph to that accuracy
band; ``ServedModel`` bundles params + apply for the serving path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.graphs import GraphData
from repro.gnn.layers import MODELS
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


@dataclass
class ServedModel:
    name: str
    params: object
    apply: Callable
    hidden: int
    num_classes: int

    def __call__(self, x, adj, mask, impl: str = "xla"):
        return self.apply(self.params, x, adj, mask, impl=impl)


def pretrain(model_name: str, graph: GraphData, hidden: int = 64,
             steps: int = 60, lr: float = 1e-2, seed: int = 0,
             train_frac: float = 0.6) -> tuple[ServedModel, dict]:
    """Full-batch node-classification training on one citation graph."""
    init, apply = MODELS[model_name]
    key = jax.random.PRNGKey(seed)
    n = graph.num_vertices
    din = graph.features.shape[1]
    params = init(key, din, hidden, graph.num_classes)
    x = jnp.asarray(graph.features)
    adj = jnp.asarray(graph.adjacency())
    mask = jnp.ones(n, jnp.float32)
    labels = jnp.asarray(graph.labels)
    rng = np.random.default_rng(seed)
    train_mask = jnp.asarray(
        (rng.random(n) < train_frac).astype(np.float32))
    opt_cfg = AdamWConfig(lr=lr, weight_decay=5e-4)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt):
        def loss_fn(p):
            logits = apply(p, x, adj, mask)
            logp = jax.nn.log_softmax(logits)
            nll = -logp[jnp.arange(n), labels] * train_mask
            return jnp.sum(nll) / jnp.maximum(jnp.sum(train_mask), 1.0)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(opt_cfg, grads, opt, params)
        return params, opt, loss

    loss = jnp.inf
    for _ in range(steps):
        params, opt, loss = step(params, opt)

    logits = apply(params, x, adj, mask)
    pred = jnp.argmax(logits, axis=-1)
    test = 1.0 - train_mask
    acc_train = float(jnp.sum((pred == labels) * train_mask)
                      / jnp.maximum(jnp.sum(train_mask), 1.0))
    acc_test = float(jnp.sum((pred == labels) * test)
                     / jnp.maximum(jnp.sum(test), 1.0))
    model = ServedModel(model_name, params, apply, hidden, graph.num_classes)
    return model, {"loss": float(loss), "acc_train": acc_train,
                   "acc_test": acc_test}
