"""GNN models used by the paper's experiments: GCN, GAT, GraphSAGE, SGC.

Dense-adjacency JAX implementations (the EC scenarios have ≤ a few thousand
vertices; dense `A @ H` is the MXU-native formulation — see DESIGN.md
hardware-adaptation notes). All models share the signature

    params = <model>_init(key, dims...)
    logits = <model>_apply(params, x, adj, mask, *, impl="xla")

where ``adj`` is the raw 0/1 symmetric adjacency (no self-loops) and ``mask``
marks active vertices. ``impl`` selects the aggregation backend: plain XLA
einsum or the Pallas blocked-SpMM kernel (``repro.kernels.gnn_aggregate``).

Large sparse graphs (PubMed-scale, Fig. 6 sparse axis) take the **gather
fast path** automatically: when the (concrete) adjacency has ≥
``SPARSE_MIN_VERTICES`` vertices and density below
``SPARSE_DENSITY_THRESHOLD``, ``gcn_apply``/``sgc_apply`` convert Â to
slot-sorted padded neighbor lists once and every layer aggregates in
O(E·F) instead of O(N²·F) — ``gcn_apply`` through the *fused*
gather+normalize+matmul op
(``repro.kernels.gnn_aggregate.ops.fused_gather_aggregate``, one kernel
pass per layer), ``sgc_apply`` through the plain ``gather_aggregate``
(its hops carry no per-hop weights to fuse). Under jit tracing (or for
small/dense graphs) the dense path is kept.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.nnlib.core import glorot_init
from repro.kernels.gnn_aggregate.ops import (SPARSE_DENSITY_THRESHOLD,
                                             dense_to_padded_neighbors,
                                             fused_gather_aggregate,
                                             gather_aggregate,
                                             normalized_aggregate,
                                             padded_neighbors_from_coo,
                                             sort_neighbor_slots)

# below this the dense contraction is trivially cheap; skip the conversion
SPARSE_MIN_VERTICES = 256


def _masked_adj(adj: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    return adj * mask[:, None] * mask[None, :]


def gcn_norm(adj: jnp.ndarray, mask: jnp.ndarray
             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Return (Â, D̃^{-1/2}) for Eq. (1): Â = A + I (active vertices only)."""
    a = _masked_adj(adj, mask) + jnp.diag(mask)
    deg = jnp.sum(a, axis=1)
    dinv = jnp.where(deg > 0, jax.lax.rsqrt(jnp.maximum(deg, 1e-9)), 0.0)
    return a, dinv


def gcn_norm_sparse(edges: np.ndarray, n: int
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sparse Eq. (1) normalization: unique undirected [E, 2] edge list →
    (nbr_idx, nbr_val, D̃^{-1/2}) for Â = A + I, ready for
    :func:`~repro.kernels.gnn_aggregate.ops.gather_aggregate` — O(E), no
    dense adjacency. All n vertices are treated as active."""
    i, j = np.asarray(edges, np.int64).reshape(-1, 2).T
    loops = np.arange(n)
    src = np.concatenate([i, j, loops])
    dst = np.concatenate([j, i, loops])
    nbr_idx, nbr_val = padded_neighbors_from_coo(src, dst, 1.0, n)
    deg = np.bincount(src, minlength=n).astype(np.float32)
    dinv = np.where(deg > 0, 1.0 / np.sqrt(deg), 0.0).astype(np.float32)
    return nbr_idx, nbr_val, dinv


def maybe_padded_neighbors(adj_hat) -> tuple[jnp.ndarray, jnp.ndarray] | None:
    """(nbr_idx, nbr_val) when the gather fast path pays off, else None.

    Requires a concrete (non-traced) adjacency — under jit we cannot
    inspect nnz, and the conversion is host-side numpy anyway."""
    if isinstance(adj_hat, jax.core.Tracer):
        return None
    a = np.asarray(adj_hat)
    n = a.shape[0]
    if n < SPARSE_MIN_VERTICES or a.shape[0] != a.shape[1]:
        return None
    if np.count_nonzero(a) > SPARSE_DENSITY_THRESHOLD * n * n:
        return None
    idx, val = sort_neighbor_slots(*dense_to_padded_neighbors(a))
    return jnp.asarray(idx), jnp.asarray(val)


def propagate(adj_hat: jnp.ndarray, dinv: jnp.ndarray, h: jnp.ndarray,
              impl: str = "xla", neighbors=None) -> jnp.ndarray:
    """D̃^{-1/2} Â D̃^{-1/2} H — the aggregation hot spot (Eq. 1).

    ``neighbors`` (from :func:`maybe_padded_neighbors`) routes the layer
    through the sparse gather kernel; callers with several layers convert
    once and reuse."""
    if neighbors is not None:
        return gather_aggregate(neighbors[0], neighbors[1], h, dinv, dinv,
                                impl=impl)
    return normalized_aggregate(adj_hat, h, dinv, dinv, impl=impl)


# ---------------------------------------------------------------------------
# GCN (Kipf & Welling; paper Eqs. 1–2)
# ---------------------------------------------------------------------------

def gcn_init(key, dims: list[int]):
    keys = jax.random.split(key, len(dims) - 1)
    return [{"w": glorot_init(k, (i, o))}
            for k, i, o in zip(keys, dims[:-1], dims[1:])]


def gcn_apply(params, x, adj, mask, impl: str = "xla"):
    a_hat, dinv = gcn_norm(adj, mask)
    nbrs = maybe_padded_neighbors(a_hat)
    h = x
    for i, layer in enumerate(params):
        if nbrs is not None:
            # fused gather+normalize+matmul: the whole layer hot path in
            # one kernel pass (kernels.gnn_aggregate.fused)
            h = fused_gather_aggregate(nbrs[0], nbrs[1], h, dinv, dinv,
                                       layer["w"], impl=impl)
        else:
            h = propagate(a_hat, dinv, h @ layer["w"], impl=impl)
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h * mask[:, None]


# ---------------------------------------------------------------------------
# SGC (Wu et al. [51]): Â^K X W, no intermediate nonlinearity
# ---------------------------------------------------------------------------

SGC_HOPS = 2


def sgc_init(key, in_dim: int, out_dim: int):
    return {"w": glorot_init(key, (in_dim, out_dim))}


def sgc_apply(params, x, adj, mask, impl: str = "xla"):
    a_hat, dinv = gcn_norm(adj, mask)
    nbrs = maybe_padded_neighbors(a_hat)
    h = x
    for _ in range(SGC_HOPS):
        h = propagate(a_hat, dinv, h, impl=impl, neighbors=nbrs)
    return (h @ params["w"]) * mask[:, None]


# ---------------------------------------------------------------------------
# GraphSAGE (Hamilton et al. [30]) — mean aggregator
# ---------------------------------------------------------------------------

def sage_init(key, dims: list[int]):
    keys = jax.random.split(key, 2 * (len(dims) - 1))
    return [{"w_self": glorot_init(keys[2 * i], (dims[i], dims[i + 1])),
             "w_nbr": glorot_init(keys[2 * i + 1], (dims[i], dims[i + 1]))}
            for i in range(len(dims) - 1)]


def sage_apply(params, x, adj, mask, impl: str = "xla"):
    a = _masked_adj(adj, mask)
    deg = jnp.maximum(jnp.sum(a, axis=1), 1.0)
    h = x
    for i, layer in enumerate(params):
        mean_nbr = normalized_aggregate(a, h, 1.0 / deg,
                                        jnp.ones_like(deg), impl=impl)
        h = h @ layer["w_self"] + mean_nbr @ layer["w_nbr"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
            h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True),
                                1e-6)
    return h * mask[:, None]


# ---------------------------------------------------------------------------
# GAT (Velickovic et al. [50]) — single-head dense attention
# ---------------------------------------------------------------------------

def gat_init(key, dims: list[int]):
    keys = jax.random.split(key, 3 * (len(dims) - 1))
    out = []
    for i in range(len(dims) - 1):
        out.append({
            "w": glorot_init(keys[3 * i], (dims[i], dims[i + 1])),
            "a_src": glorot_init(keys[3 * i + 1], (dims[i + 1], 1)),
            "a_dst": glorot_init(keys[3 * i + 2], (dims[i + 1], 1)),
        })
    return out


def gat_apply(params, x, adj, mask, impl: str = "xla"):
    a = _masked_adj(adj, mask) + jnp.diag(mask)   # self-attention edge
    h = x
    for i, layer in enumerate(params):
        z = h @ layer["w"]
        e = (z @ layer["a_src"]) + (z @ layer["a_dst"]).T   # e_ij broadcast
        e = jax.nn.leaky_relu(e, 0.2)
        e = jnp.where(a > 0, e, -1e9)
        att = jax.nn.softmax(e, axis=1) * (a > 0)
        h = att @ z
        if i < len(params) - 1:
            h = jax.nn.elu(h)
    return h * mask[:, None]


MODELS = {
    "gcn": (lambda key, din, dh, dout: gcn_init(key, [din, dh, dout]),
            gcn_apply),
    "sgc": (lambda key, din, dh, dout: sgc_init(key, din, dout), sgc_apply),
    "graphsage": (lambda key, din, dh, dout: sage_init(key, [din, dh, dout]),
                  sage_apply),
    "gat": (lambda key, din, dh, dout: gat_init(key, [din, dh, dout]),
            gat_apply),
}
