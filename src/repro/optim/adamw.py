"""AdamW + schedules, self-contained (no optax in this container).

The optimizer state is a pytree mirroring the params, so it inherits any
sharding we assign to params (ZeRO falls out of FSDP param sharding).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class AdamState(NamedTuple):
    step: jnp.ndarray          # scalar int32
    mu: Params                 # first moment  (f32)
    nu: Params                 # second moment (f32)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float | None = 1.0


def adamw_init(params: Params) -> AdamState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, grads: Params, state: AdamState,
                 params: Params) -> tuple[Params, AdamState]:
    step = state.step + 1
    if cfg.grad_clip is not None:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    newp = treedef.unflatten([o[0] for o in out])
    newm = treedef.unflatten([o[1] for o in out])
    newv = treedef.unflatten([o[2] for o in out])
    return newp, AdamState(step=step, mu=newm, nu=newv)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return sched


def constant_schedule(base_lr: float) -> Callable:
    return lambda step: jnp.full((), base_lr, jnp.float32)
