"""zamba2-2.7b [hybrid]: 54L d_model=2560, Mamba2 backbone (ssm_state=64)
with interleaved attention blocks (32H kv=32, d_ff=10240 MLP).
Pattern: 9 × (5 mamba2 + 1 attention) = 54 layers. Zamba2 shares the
attention block weights globally; we keep per-repetition weights
(DESIGN.md notes the deviation). [arXiv:2411.15242]"""
from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    m = LayerSpec(mixer="mamba2", ffn="none")
    a = LayerSpec(mixer="attn", ffn="dense")
    return ModelConfig(
        name="zamba2-2.7b", arch_type="hybrid",
        d_model=2560, vocab_size=32000,
        num_heads=32, num_kv_heads=32, head_dim=80,
        d_ff=10240,
        ssm_state=64, ssm_headdim=64, ssm_expand=2,
        rope_theta=10000.0,
        stages=(Stage(unit=(m, m, m, m, m, a), reps=9),),
        long_context_ok=True,    # Mamba2 state; attn blocks windowed at 500k
        source="arXiv:2411.15242",
    )
