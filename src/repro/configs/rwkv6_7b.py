"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536 — Finch, data-dependent decay, time-mix + channel-mix.
[arXiv:2404.05892]"""
from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", arch_type="ssm",
        d_model=4096, vocab_size=65536,
        d_ff=14336, rwkv_head_dim=64,
        stages=(Stage(unit=(LayerSpec(mixer="rwkv6", ffn="rwkv_cm"),),
                      reps=32),),
        long_context_ok=True,    # O(1) recurrent state
        source="arXiv:2404.05892",
    )
