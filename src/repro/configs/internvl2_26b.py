"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT vision frontend STUBBED per the assignment:
input_specs() provides projected patch embeddings prepended to text.
[arXiv:2404.16821]"""
from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", arch_type="vlm",
        d_model=6144, vocab_size=92553,
        num_heads=48, num_kv_heads=8, head_dim=128,
        d_ff=16384, rope_theta=1e6,
        stages=(Stage(unit=(LayerSpec(mixer="attn", ffn="dense"),),
                      reps=48),),
        num_prefix_tokens=256,   # one tile of ViT patches (stub)
        prefix_dim=3200,         # InternViT-6B embedding dim (stub)
        long_context_ok=False,   # pure full attention (DESIGN.md skip)
        source="arXiv:2404.16821",
    )
