"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, GQA. [hf:Qwen/Qwen3-8B family card]"""
from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", arch_type="dense",
        d_model=1024, vocab_size=151936,
        num_heads=16, num_kv_heads=8, head_dim=128,
        d_ff=3072, qk_norm=True, rope_theta=1e6,
        stages=(Stage(unit=(LayerSpec(mixer="attn", ffn="dense"),),
                      reps=28),),
        long_context_ok=False,   # pure full attention (DESIGN.md skip table)
        source="hf:Qwen/Qwen3-8B",
    )
