"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, SWA. [arXiv:2401.04088]"""
from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", arch_type="moe",
        d_model=4096, vocab_size=32000,
        num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=14336, moe_d_ff=14336,
        num_experts=8, num_experts_per_tok=2,
        rope_theta=1e6,
        stages=(Stage(unit=(LayerSpec(mixer="attn", ffn="moe",
                                      window=4096),), reps=32),),
        long_context_ok=True,    # native SWA
        source="arXiv:2401.04088",
    )
