"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408
vocab=102400 — MLA kv_lora=512, 2 shared + 64 routed experts top-6,
first layer dense FFN. The assignment's primary spec line (64e top-6) is
followed; V2-Lite's dense first-layer FFN is 10944. [arXiv:2405.04434]"""
from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    first = LayerSpec(mixer="mla", ffn="dense")
    moe = LayerSpec(mixer="mla", ffn="moe")
    return ModelConfig(
        name="deepseek-v2-lite-16b", arch_type="moe",
        d_model=2048, vocab_size=102400,
        num_heads=16, num_kv_heads=16, head_dim=128,
        d_ff=10944, moe_d_ff=1408,
        kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        num_experts=64, num_experts_per_tok=6, num_shared_experts=2,
        rope_theta=10000.0,
        stages=(Stage(unit=(first,), reps=1),
                Stage(unit=(moe,), reps=26)),
        long_context_ok=True,    # MLA rank-512 cache; decode O(S)/token
        source="arXiv:2405.04434",
    )
