"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — alternating local(4096)/global layers, logit softcaps,
pre+post RMSNorm, sqrt(d) embed scale. [arXiv:2408.00118]"""
from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    local = LayerSpec(mixer="attn", ffn="dense", window=4096,
                      post_norm=True)
    glob = LayerSpec(mixer="attn", ffn="dense", window=None,
                     post_norm=True)
    return ModelConfig(
        name="gemma2-9b", arch_type="dense",
        d_model=3584, vocab_size=256000,
        num_heads=16, num_kv_heads=8, head_dim=256,
        d_ff=14336, attn_logit_softcap=50.0, final_logit_softcap=30.0,
        embed_scale=True, rope_theta=10000.0,
        stages=(Stage(unit=(local, glob), reps=21),),
        long_context_ok=True,    # local layers SWA; global decode is O(S)
        source="arXiv:2408.00118",
    )
