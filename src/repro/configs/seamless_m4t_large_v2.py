"""seamless-m4t-large-v2 [audio]: 24L enc + 24L dec, d_model=1024 16H
(GQA kv=16) d_ff=8192 vocab=256206 — encoder-decoder; the speech frontend
(mel + conformer feature extractor) is STUBBED per the assignment:
input_specs() provides precomputed frame embeddings. [arXiv:2308.11596]"""
from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    dec = LayerSpec(mixer="attn", ffn="dense", cross_attn=True)
    enc = LayerSpec(mixer="attn", ffn="dense")
    return ModelConfig(
        name="seamless-m4t-large-v2", arch_type="audio",
        d_model=1024, vocab_size=256206,
        num_heads=16, num_kv_heads=16, head_dim=64,
        d_ff=8192, rope_theta=10000.0,
        stages=(Stage(unit=(dec,), reps=24),),
        encoder_stages=(Stage(unit=(enc,), reps=24),),
        encoder_seq_len=1024,    # stub speech-frame count
        prefix_dim=1024,         # stub frame embedding dim
        long_context_ok=False,   # enc-dec full attention (DESIGN.md skip)
        source="arXiv:2308.11596",
    )
