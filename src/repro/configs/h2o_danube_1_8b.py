"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix, sliding-window attention.
[arXiv:2401.16818]"""
from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", arch_type="dense",
        d_model=2560, vocab_size=32000,
        num_heads=32, num_kv_heads=8, head_dim=80,
        d_ff=6912, rope_theta=10000.0,
        stages=(Stage(unit=(LayerSpec(mixer="attn", ffn="dense",
                                      window=4096),), reps=24),),
        long_context_ok=True,    # native SWA
        source="arXiv:2401.16818",
    )
