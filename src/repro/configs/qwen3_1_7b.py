"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, GQA. [hf:Qwen/Qwen3-8B family card]"""
from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b", arch_type="dense",
        d_model=2048, vocab_size=151936,
        num_heads=16, num_kv_heads=8, head_dim=128,
        d_ff=6144, qk_norm=True, rope_theta=1e6,
        stages=(Stage(unit=(LayerSpec(mixer="attn", ffn="dense"),),
                      reps=28),),
        long_context_ok=False,
        source="hf:Qwen/Qwen3-8B",
    )
