"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

One module per assigned architecture (exact numbers from the assignment,
source cited in each config's ``source`` field), plus the paper's own GNN
scenario configs (``graphedge_*``).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen3-0.6b",
    "deepseek-v2-lite-16b",
    "h2o-danube-1.8b",
    "seamless-m4t-large-v2",
    "zamba2-2.7b",
    "gemma2-9b",
    "mixtral-8x7b",
    "internvl2-26b",
    "qwen3-1.7b",
    "rwkv6-7b",
]


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCHS}")
    return importlib.import_module(_module_name(arch_id)).config()


def list_archs() -> list[str]:
    return list(ARCHS)
