"""Flat-npz checkpointing for arbitrary pytrees (no orbax offline).

Keys encode the tree path; restore requires a matching ``like`` pytree, which
keeps it safe across refactors (shape/dtype mismatches fail loudly).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

SEP = "|"


def _flatten_with_paths(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten_with_paths(tree))


def restore(path: str, like: Any) -> Any:
    with np.load(path) as data:
        stored = dict(data)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths:
        key = SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        if key not in stored:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = stored[key]
        if arr.shape != leaf.shape:
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
