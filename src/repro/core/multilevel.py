"""Multilevel METIS-style graph partitioner: coarsen → cut → refine.

The HiCut transcription (``repro.core.hicut``) and the pairwise max-flow
baseline (``repro.core.mincut_baseline``) are the paper's own algorithms;
this module adds the classic multilevel k-way pipeline ("GNN at the Edge",
Zeng et al., arXiv:2210.17281, partitions GNN serving over edge servers
with exactly this family) as a third ``Partitioner`` registry backend:

1. **Coarsen** — repeated *heavy-edge matching*: every vertex proposes its
   heaviest incident edge, mutual proposals collapse into one coarse
   vertex, edge weights accumulate. The matching is vectorized numpy in
   the style of :func:`repro.kernels.gnn_aggregate.ops.
   rank_within_sorted_groups` (lexsort + group-boundary scatter, no
   per-vertex Python), so coarsening one level is O(E log E).
2. **Initial cut** — greedy balanced growth on the coarsest graph:
   vertices in descending-weight order go to the already-connected part
   with room (capacity ``ceil(Σweight / k · imbalance)``), falling back to
   the least-loaded part.
3. **Refine** — project each level back and run boundary
   Kernighan–Lin-style sweeps: move the vertex with the largest positive
   cut-gain to its best-connected other part, subject to the capacity
   constraint, with exact incremental connectivity updates (every applied
   move strictly decreases the cut, so sweeps terminate). A final
   rebalance pass guarantees the capacity constraint holds at the finest
   level whenever it is feasible (``k · cap ≥ N`` by construction).

:func:`multilevel_jax` is the fixed-shape jnp twin of the *refinement*
stage (balanced initial chunks over active ranks + ``moves`` best-gain
boundary moves under ``lax.fori_loop``) — pure and jit-able, so the
``multilevel_jax`` registry entry satisfies the ``JitPartitioner``
protocol and runs inside ``GraphEdgeController.jit_step_fn()`` next to
``hicut_jax`` (coarsening stays host-side; see DESIGN.md §6).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# coarsening: heavy-edge matching + contraction (vectorized numpy)
# ---------------------------------------------------------------------------

def heavy_edge_matching(n: int, edges: np.ndarray, weights: np.ndarray,
                        rounds: int = 8, seed: int = 0) -> np.ndarray:
    """Greedy matching preferring heavy edges, fully vectorized.

    Each round every still-free vertex proposes its heaviest free neighbor
    (lexsort by (vertex, weight); the last entry of each vertex group is
    the heaviest — the ``rank_within_sorted_groups`` bucketing idiom);
    mutual proposals become matches (Luby-style hand-shaking). Weight ties
    are broken by a fresh random jitter each round — without it uniform-
    weight graphs stall on deterministic non-mutual proposals. Returns
    ``match [n]`` with ``match[v]`` = partner (``v`` itself for
    unmatched/isolated vertices).
    """
    match = np.full(n, -1, np.int64)
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    if len(edges):
        rng = np.random.default_rng(seed)
        src = np.concatenate([edges[:, 0], edges[:, 1]])
        dst = np.concatenate([edges[:, 1], edges[:, 0]])
        w = np.concatenate([weights, weights]).astype(np.float64)
        # symmetric per-edge jitter so both endpoints see the same ranking
        scale = max(float(w.max()), 1.0)
        for _ in range(rounds):
            free = match < 0
            ok = free[src] & free[dst]
            if not ok.any():
                break
            jitter = rng.uniform(0.0, 1e-3 * scale, len(edges))
            wj = w + np.concatenate([jitter, jitter])
            s, d, ww = src[ok], dst[ok], wj[ok]
            order = np.lexsort((ww, s))          # by vertex, then weight
            s_s, d_s = s[order], d[order]
            last = np.ones(len(s_s), bool)
            last[:-1] = s_s[1:] != s_s[:-1]      # heaviest entry per vertex
            prop = np.full(n, -1, np.int64)
            prop[s_s[last]] = d_s[last]
            v = np.nonzero(prop >= 0)[0]
            mutual = v[prop[prop[v]] == v]       # hand-shake
            a = mutual[mutual < prop[mutual]]
            if len(a) == 0:
                continue                          # re-jitter and retry
            b = prop[a]
            match[a] = b
            match[b] = a
    unmatched = np.nonzero(match < 0)[0]
    match[unmatched] = unmatched
    return match


def contract(n: int, edges: np.ndarray, weights: np.ndarray,
             vwgt: np.ndarray, match: np.ndarray):
    """Collapse matched pairs → ``(n_c, cmap, c_edges, c_weights, c_vwgt)``.

    ``cmap [n]`` maps fine → coarse ids; parallel coarse edges merge with
    summed weights; coarse vertex weights are the summed cluster weights.
    """
    rep = np.minimum(np.arange(n), match)
    uniq, cmap = np.unique(rep, return_inverse=True)
    n_c = len(uniq)
    c_vwgt = np.bincount(cmap, weights=vwgt, minlength=n_c)
    if len(edges):
        ci, cj = cmap[edges[:, 0]], cmap[edges[:, 1]]
        keep = ci != cj
        lo = np.minimum(ci[keep], cj[keep])
        hi = np.maximum(ci[keep], cj[keep])
        key = lo * n_c + hi
        uk, inv = np.unique(key, return_inverse=True)
        c_w = np.bincount(inv, weights=weights[keep])
        c_edges = np.stack([uk // n_c, uk % n_c], axis=1)
    else:
        c_edges = np.zeros((0, 2), np.int64)
        c_w = np.zeros(0, np.float64)
    return n_c, cmap, c_edges, c_w, c_vwgt


# ---------------------------------------------------------------------------
# initial cut + refinement (numpy)
# ---------------------------------------------------------------------------

def _csr(n: int, edges: np.ndarray, weights: np.ndarray):
    """Symmetric CSR (indptr, nbr, wt) from an undirected edge list."""
    src = np.concatenate([edges[:, 0], edges[:, 1]])
    dst = np.concatenate([edges[:, 1], edges[:, 0]])
    w = np.concatenate([weights, weights])
    order = np.argsort(src, kind="stable")
    counts = np.bincount(src, minlength=n)
    indptr = np.concatenate([[0], np.cumsum(counts)])
    return indptr, dst[order], w[order]


def initial_partition(n_c: int, edges: np.ndarray, weights: np.ndarray,
                      vwgt: np.ndarray, k: int, cap: float,
                      rng: np.random.Generator | None = None) -> np.ndarray:
    """Greedy graph growing on the coarsest graph (GGGP-style).

    Parts are grown one at a time from a random seed vertex, always
    absorbing the unassigned vertex most connected to the growing part
    (ties → heavier vertex) until the part reaches its balanced share;
    leftovers join the best-connected part with room (least-loaded when
    nothing fits — refinement + the rebalance pass restore the constraint
    on the finer levels)."""
    rng = np.random.default_rng(0) if rng is None else rng
    assign = np.full(n_c, -1, np.int64)
    load = np.zeros(k)
    conn = np.zeros((n_c, k))
    indptr, nbr, wt = _csr(n_c, edges, weights) if len(edges) else \
        (np.zeros(n_c + 1, np.int64), np.zeros(0, np.int64),
         np.zeros(0, np.float64))
    total = float(vwgt.sum())

    def absorb(v: int, p: int) -> None:
        assign[v] = p
        load[p] += vwgt[v]
        js = nbr[indptr[v]:indptr[v + 1]]
        # add.at: parallel edges contribute once each (fancy-index += drops
        # duplicate-neighbor contributions)
        np.add.at(conn, (js, p), wt[indptr[v]:indptr[v + 1]])

    for p in range(k - 1):
        share = total * (p + 1) / k - load[:p + 1].sum() + load[p]
        free = np.nonzero(assign < 0)[0]
        if len(free) == 0:
            break
        absorb(int(rng.choice(free)), p)        # random seed vertex
        while load[p] < min(share, cap):
            free = np.nonzero(assign < 0)[0]
            if len(free) == 0:
                break
            fits = free[load[p] + vwgt[free] <= cap]
            if len(fits) == 0:
                break
            v = int(fits[np.argmax(conn[fits, p] + 1e-9 * vwgt[fits])])
            absorb(v, p)
    # the last part takes what's left; spill anything over cap by best fit
    for v in np.nonzero(assign < 0)[0]:
        fits = load + vwgt[v] <= cap
        if fits.any():
            absorb(v, int(np.argmax(np.where(fits, conn[v] - 1e-9 * load,
                                             -np.inf))))
        else:
            absorb(v, int(np.argmin(load)))
    return assign


def refine(n: int, edges: np.ndarray, weights: np.ndarray, vwgt: np.ndarray,
           assign: np.ndarray, k: int, cap: float,
           sweeps: int = 4) -> np.ndarray:
    """Boundary KL-style refinement sweeps with a capacity constraint.

    Each sweep ranks boundary vertices by cut-gain (vectorized), then
    applies moves in that order with *exact* incremental connectivity
    updates — a move is taken only if its re-checked gain is still
    positive and the target part has room, so the cut strictly decreases.
    A leading rebalance pass drains any over-capacity part (allowing
    zero/negative-gain moves) so the constraint holds whenever feasible.
    """
    assign = np.asarray(assign, np.int64).copy()
    if len(edges) == 0 and (np.bincount(assign, weights=vwgt,
                                        minlength=k) <= cap).all():
        return assign
    indptr, nbr, wt = _csr(n, edges, weights) if len(edges) else \
        (np.zeros(n + 1, np.int64), np.zeros(0, np.int64),
         np.zeros(0, np.float64))
    conn = np.zeros((n, k))
    if len(edges):
        np.add.at(conn, (edges[:, 0], assign[edges[:, 1]]), weights)
        np.add.at(conn, (edges[:, 1], assign[edges[:, 0]]), weights)
    load = np.bincount(assign, weights=vwgt, minlength=k).astype(np.float64)

    def move(v: int, b: int) -> None:
        a = assign[v]
        assign[v] = b
        load[a] -= vwgt[v]
        load[b] += vwgt[v]
        js = nbr[indptr[v]:indptr[v + 1]]
        ws = wt[indptr[v]:indptr[v + 1]]
        np.add.at(conn, (js, a), -ws)
        np.add.at(conn, (js, b), ws)

    # rebalance: drain over-capacity parts into the best-connected part
    # with room (gain may be negative; balance beats cut here)
    for a in range(k):
        while load[a] > cap:
            vs = np.nonzero(assign == a)[0]
            if len(vs) == 0:
                break
            # evacuate the least-attached vertex (per unit weight) first
            v = int(vs[np.argmin(conn[vs, a] / np.maximum(vwgt[vs], 1e-9))])
            fits = load + vwgt[v] <= cap
            fits[a] = False
            if not fits.any():
                break
            move(v, int(np.argmax(np.where(fits, conn[v], -np.inf))))

    rows = np.arange(n)
    for _ in range(sweeps):
        cur = conn[rows, assign]
        ext = conn.copy()
        ext[rows, assign] = -np.inf
        best = np.argmax(ext, axis=1)
        gain = ext[rows, best] - cur
        cand = np.nonzero(gain > 0)[0]
        if len(cand) == 0:
            break
        moved = 0
        for v in cand[np.argsort(-gain[cand], kind="stable")]:
            a = assign[v]
            row = conn[v].copy()
            row[a] = -np.inf
            b = int(np.argmax(row))
            if row[b] - conn[v, a] <= 0 or load[b] + vwgt[v] > cap:
                continue
            move(v, b)
            moved += 1
        if moved == 0:
            break
    return assign


# ---------------------------------------------------------------------------
# the full pipeline
# ---------------------------------------------------------------------------

def _cut_cost(edges: np.ndarray, weights: np.ndarray,
              assign: np.ndarray) -> float:
    if len(edges) == 0:
        return 0.0
    cross = assign[edges[:, 0]] != assign[edges[:, 1]]
    return float(weights[cross].sum())


def multilevel_partition(n: int, edges: np.ndarray, num_parts: int,
                         weights: np.ndarray | None = None,
                         active: np.ndarray | None = None,
                         coarsen_to: int | None = None, sweeps: int = 4,
                         imbalance: float = 1.1, restarts: int = 4,
                         seed: int = 0,
                         initial: np.ndarray | None = None) -> np.ndarray:
    """Coarsen → initial cut → refine. Returns [n] part ids (−1 inactive).

    ``restarts`` independent graph-growing initial cuts are refined on the
    coarsest graph and the best one is projected back (the coarsest graph
    is small, so restarts are nearly free). The capacity constraint is
    ``cap = ceil(#active / k · imbalance)`` vertices per part — always
    feasible (``k · cap ≥ #active``), and the returned assignment respects
    it at the finest level.

    ``initial`` enables a **warm start** (the fault-migration path,
    DESIGN.md §9): a previous [n] assignment is taken as the starting cut
    — coarsening and graph growing are skipped entirely, vertices with
    ids outside [0, k) (newly-arrived users, parts of a now-down server)
    are filled into the least-loaded parts, and ``refine`` runs directly
    on the finest level (its leading rebalance pass restores the capacity
    constraint)."""
    active = np.ones(n, bool) if active is None else np.asarray(active, bool)
    ids = np.nonzero(active)[0]
    na = len(ids)
    out = np.full(n, -1, np.int64)
    if na == 0:
        return out
    k = max(1, min(int(num_parts), na))
    cap = float(np.ceil(na / k * imbalance))
    coarsen_to = max(8 * k, 32) if coarsen_to is None else int(coarsen_to)

    # compact to the active subgraph
    local = np.full(n, -1, np.int64)
    local[ids] = np.arange(na)
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    keep = np.zeros(len(edges), bool)
    if len(edges):
        keep = (active[edges[:, 0]] & active[edges[:, 1]]
                & (edges[:, 0] != edges[:, 1]))
    e = local[edges[keep]]
    w = (np.ones(len(e), np.float64) if weights is None
         else np.asarray(weights, np.float64)[keep])
    vwgt = np.ones(na, np.float64)

    if initial is not None:
        # warm start: refine the previous cut on the finest active subgraph
        prev = np.asarray(initial, np.int64)[ids].copy()
        prev[(prev < 0) | (prev >= k)] = -1
        load = np.bincount(prev[prev >= 0], minlength=k).astype(np.float64)
        for v in np.nonzero(prev < 0)[0]:
            p = int(np.argmin(load))
            prev[v] = p
            load[p] += vwgt[v]
        out[ids] = refine(na, e, w, vwgt, prev, k, cap, sweeps=sweeps)
        return out

    # coarsen until the graph is small or matching stalls
    levels: list[tuple] = []       # (cmap, finer (n, e, w, vwgt))
    cn, ce, cw, cv = na, e, w, vwgt
    while cn > coarsen_to and len(ce):
        match = heavy_edge_matching(cn, ce, cw, seed=seed + len(levels))
        n2, cmap, e2, w2, v2 = contract(cn, ce, cw, cv, match)
        if n2 >= 0.95 * cn:        # matching stalled — stop coarsening
            break
        levels.append((cmap, (cn, ce, cw, cv)))
        cn, ce, cw, cv = n2, e2, w2, v2

    rng = np.random.default_rng(seed)
    assign, best = None, np.inf
    for _ in range(max(1, int(restarts))):
        cand = initial_partition(cn, ce, cw, cv, k, cap, rng=rng)
        cand = refine(cn, ce, cw, cv, cand, k, cap, sweeps=sweeps)
        cost = _cut_cost(ce, cw, cand)
        if cost < best:
            assign, best = cand, cost
    for cmap, (fn, fe, fw, fv) in reversed(levels):
        assign = assign[cmap]      # project back one level
        assign = refine(fn, fe, fw, fv, assign, k, cap, sweeps=sweeps)
    out[ids] = assign
    return out


def multilevel_partition_state(state, num_parts: int,
                               coarsen_to: int | None = None,
                               sweeps: int = 4,
                               imbalance: float = 1.1) -> np.ndarray:
    """Run the pipeline on a ``GraphState`` layout (the ``multilevel``
    entry of the ``repro.core.api`` partitioner registry)."""
    from repro.core.api import state_edges   # function-level: keep this
    return multilevel_partition(              # module numpy-only otherwise
        state.capacity, state_edges(state), num_parts,
        active=np.asarray(state.mask) > 0, coarsen_to=coarsen_to,
        sweeps=sweeps, imbalance=imbalance)


# ---------------------------------------------------------------------------
# jnp refinement (fixed shape, jit-able — the JitPartitioner path)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_parts", "moves"))
def multilevel_jax(adj: jnp.ndarray, mask: jnp.ndarray, num_parts: int = 4,
                   moves: int = 128,
                   imbalance: float = 1.1) -> jnp.ndarray:
    """Fixed-shape jnp twin of the refinement stage.

    adj [N, N] {0,1} symmetric, mask [N] {0,1}. Starts from balanced
    contiguous chunks over the active ranks and applies up to ``moves``
    best-gain boundary moves (one vertex per iteration, exact incremental
    connectivity updates, capacity-guarded) under ``lax.fori_loop``.
    Returns [N] int32 part ids (−1 for masked-out vertices). Pure and
    traceable — the ``multilevel_jax`` registry entry's ``cut()`` runs it
    inside ``GraphEdgeController.jit_step_fn()``.
    """
    n = adj.shape[0]
    k = int(num_parts)
    active = mask > 0
    adjw = (jnp.asarray(adj, jnp.float32) * active[:, None]
            * active[None, :])
    na = active.sum()
    rank = jnp.cumsum(active.astype(jnp.int32)) - 1
    assign = jnp.where(active, (rank * k) // jnp.maximum(na, 1),
                       -1).astype(jnp.int32)
    cap = jnp.ceil(na.astype(jnp.float32) / k * imbalance)
    onehot = (jax.nn.one_hot(jnp.clip(assign, 0, k - 1), k)
              * active[:, None].astype(jnp.float32))
    conn = adjw @ onehot                       # [N, k] part connectivity
    load = onehot.sum(0)
    rows = jnp.arange(n)

    def body(_, carry):
        assign, conn, load = carry
        own = jnp.clip(assign, 0, k - 1)
        cur = conn[rows, own]
        ext = conn.at[rows, own].set(-jnp.inf)
        best = jnp.argmax(ext, axis=1).astype(jnp.int32)
        gain = ext[rows, best] - cur
        eligible = active & (load[best] + 1.0 <= cap)
        gain = jnp.where(eligible, gain, -jnp.inf)
        v = jnp.argmax(gain)
        do = gain[v] > 0
        a, b = own[v], best[v]
        dof = do.astype(jnp.float32)
        assign = assign.at[v].set(jnp.where(do, b, assign[v]))
        col = adjw[v] * dof
        conn = conn.at[:, a].add(-col).at[:, b].add(col)
        load = load.at[a].add(-dof).at[b].add(dof)
        return assign, conn, load

    assign, _, _ = jax.lax.fori_loop(0, moves, body, (assign, conn, load))
    return assign
