"""MADDPG (Lowe et al. [46]) in pure JAX — the learner behind DRLGO (§5.3).

One actor per edge server (local observation → 2-dim action in [0,1]²,
Eq. 22) and one centralized critic per agent (global state + all agents'
actions → Q). Target networks with soft updates (Eqs. 31–32), replay buffer,
deterministic policy gradient (Eq. 28), TD target (Eq. 30).

Networks follow the paper's training settings: 3 layers × 64 neurons,
actor-critic lr 3e-4, γ = 0.99, τ = 0.01, buffer 1e5, batch 256,
exploration noise 0.1.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nnlib.core import mlp_init, mlp_apply, tree_polyak
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class MADDPGConfig:
    n_agents: int
    obs_dim: int
    act_dim: int = 2
    hidden: int = 64          # paper: 3 layers × 64 neurons
    layers: int = 3
    lr: float = 3e-4          # paper Table 2
    gamma: float = 0.99
    tau: float = 0.01
    buffer_size: int = 100_000
    batch_size: int = 256
    explore_noise: float = 0.1

    @property
    def state_dim(self) -> int:
        return self.n_agents * self.obs_dim


class MADDPGState(NamedTuple):
    actor: list                # per-agent actor params
    critic: list               # per-agent critic params
    actor_t: list              # target actors
    critic_t: list             # target critics
    opt_actor: list
    opt_critic: list


def _net_sizes(cfg: MADDPGConfig, in_dim: int, out_dim: int) -> list[int]:
    return [in_dim] + [cfg.hidden] * (cfg.layers - 1) + [out_dim]


def init_maddpg(cfg: MADDPGConfig, key) -> MADDPGState:
    keys = jax.random.split(key, 2 * cfg.n_agents)
    actors, critics = [], []
    for m in range(cfg.n_agents):
        actors.append(mlp_init(keys[2 * m],
                               _net_sizes(cfg, cfg.obs_dim, cfg.act_dim)))
        critics.append(mlp_init(
            keys[2 * m + 1],
            _net_sizes(cfg, cfg.state_dim + cfg.n_agents * cfg.act_dim, 1)))
    copy = lambda t: jax.tree_util.tree_map(jnp.copy, t)
    return MADDPGState(
        actor=actors, critic=critics,
        actor_t=copy(actors), critic_t=copy(critics),
        opt_actor=[adamw_init(a) for a in actors],
        opt_critic=[adamw_init(c) for c in critics])


def actor_forward(params, obs: jnp.ndarray) -> jnp.ndarray:
    """π_m(O_m) ∈ [0,1]^act_dim (Eq. 22)."""
    return mlp_apply(params, obs, final_activation=jax.nn.sigmoid)


def critic_forward(params, state: jnp.ndarray, acts: jnp.ndarray
                   ) -> jnp.ndarray:
    """Q_m(S, A) — centralized critic."""
    x = jnp.concatenate([state, acts.reshape(*acts.shape[:-2], -1)], -1)
    return mlp_apply(params, x)[..., 0]


class ReplayBuffer:
    """(S, A, R, S', done) experience replay (paper §5.3)."""

    def __init__(self, cfg: MADDPGConfig, seed: int = 0):
        self.cfg = cfg
        n, o, a = cfg.n_agents, cfg.obs_dim, cfg.act_dim
        size = cfg.buffer_size
        self.obs = np.zeros((size, n, o), np.float32)
        self.state = np.zeros((size, n * o), np.float32)
        self.acts = np.zeros((size, n, a), np.float32)
        self.rew = np.zeros((size, n), np.float32)
        self.obs2 = np.zeros((size, n, o), np.float32)
        self.state2 = np.zeros((size, n * o), np.float32)
        self.done = np.zeros((size,), np.float32)
        self.ptr = 0
        self.full = False
        self.rng = np.random.default_rng(seed)

    def add(self, obs, state, acts, rew, obs2, state2, done):
        i = self.ptr
        self.obs[i], self.state[i], self.acts[i] = obs, state, acts
        self.rew[i], self.obs2[i], self.state2[i] = rew, obs2, state2
        self.done[i] = float(done)
        self.ptr = (self.ptr + 1) % self.cfg.buffer_size
        self.full = self.full or self.ptr == 0

    def add_batch(self, obs, state, acts, rew, obs2, state2, done):
        """Vectorized :meth:`add` of K transitions (e.g. one batched-env
        round's valid steps), with ring-buffer wraparound."""
        k = obs.shape[0]
        size = self.cfg.buffer_size
        idx = (self.ptr + np.arange(k)) % size
        self.obs[idx], self.state[idx], self.acts[idx] = obs, state, acts
        self.rew[idx], self.obs2[idx], self.state2[idx] = rew, obs2, state2
        self.done[idx] = np.asarray(done, np.float32)
        self.full = self.full or self.ptr + k >= size
        self.ptr = int((self.ptr + k) % size)

    def __len__(self):
        return self.cfg.buffer_size if self.full else self.ptr

    def sample(self):
        idx = self.rng.integers(0, len(self), self.cfg.batch_size)
        return (self.obs[idx], self.state[idx], self.acts[idx],
                self.rew[idx], self.obs2[idx], self.state2[idx],
                self.done[idx])


@partial(jax.jit, static_argnames=("cfg",))
def maddpg_update(cfg: MADDPGConfig, st: MADDPGState, batch) -> tuple:
    """One gradient step for every agent (Algorithm 2, lines 15–20)."""
    obs, state, acts, rew, obs2, state2, done = batch
    opt = AdamWConfig(lr=cfg.lr)

    # target actions A' = {π'_m(O'_m)}
    a2 = jnp.stack([actor_forward(st.actor_t[m], obs2[:, m])
                    for m in range(cfg.n_agents)], axis=1)

    new_actor, new_critic = list(st.actor), list(st.critic)
    new_oa, new_oc = list(st.opt_actor), list(st.opt_critic)
    losses = {}
    for m in range(cfg.n_agents):
        # critic: minimize (Q_m(S,A) − Y)², Y per Eq. (30)
        y = rew[:, m] + (1.0 - done) * cfg.gamma * \
            critic_forward(st.critic_t[m], state2, a2)
        y = jax.lax.stop_gradient(y)

        def critic_loss(p):
            q = critic_forward(p, state, acts)
            return jnp.mean((q - y) ** 2)

        cl, gc = jax.value_and_grad(critic_loss)(st.critic[m])
        new_critic[m], new_oc[m] = adamw_update(opt, gc, st.opt_critic[m],
                                                st.critic[m])

        # actor: deterministic policy gradient (Eq. 28)
        def actor_loss(p):
            am = actor_forward(p, obs[:, m])
            afull = acts.at[:, m].set(am)
            return -jnp.mean(critic_forward(new_critic[m], state, afull))

        al, ga = jax.value_and_grad(actor_loss)(st.actor[m])
        new_actor[m], new_oa[m] = adamw_update(opt, ga, st.opt_actor[m],
                                               st.actor[m])
        losses[f"critic_{m}"] = cl
        losses[f"actor_{m}"] = al

    # soft target updates (Eqs. 31–32)
    actor_t = [tree_polyak(a, at, cfg.tau)
               for a, at in zip(new_actor, st.actor_t)]
    critic_t = [tree_polyak(c, ct, cfg.tau)
                for c, ct in zip(new_critic, st.critic_t)]
    return MADDPGState(new_actor, new_critic, actor_t, critic_t,
                       new_oa, new_oc), losses


@partial(jax.jit, static_argnames=("cfg", "explore"))
def select_actions(cfg: MADDPGConfig, st: MADDPGState, obs: jnp.ndarray,
                   key, explore: bool = True) -> jnp.ndarray:
    """A_m = π_m(O_m) (+ exploration noise), clipped to [0,1] (Eq. 22)."""
    acts = jnp.stack([actor_forward(st.actor[m], obs[m])
                      for m in range(cfg.n_agents)])
    if explore:
        noise = cfg.explore_noise * jax.random.normal(key, acts.shape)
        acts = acts + noise
    return jnp.clip(acts, 0.0, 1.0)
