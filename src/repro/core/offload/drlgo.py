"""DRLGO training (paper Algorithm 2) and the GraphEdge controller loop.

Each episode: dynamically perturb the scenario (20% change rate by default,
§6.4), rebuild the dynamic graph layout, run HiCut (Algorithm 1) to get
G_sub, then roll the MAMDP: every step all agents act, one user is placed,
transitions go to the replay buffer, and every agent takes a gradient step.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs
from repro.core.api import get_partitioner, state_edges
from repro.core.dynamic_graph import GraphState, random_scenario, \
    perturb_scenario
from repro.core.hicut import hicut_ref
from repro.core.offload.env import ACT_DIM, OBS_DIM, OffloadEnv
from repro.core.offload.maddpg import (MADDPGConfig, ReplayBuffer,
                                       init_maddpg, maddpg_update,
                                       select_actions)


def hicut_partition(state: GraphState) -> np.ndarray:
    """Run HiCut (ref impl) on a GraphState → [N] subgraph ids.

    Kept as a convenience wrapper; the registry equivalent is
    ``get_partitioner("hicut_ref")(state).subgraph``."""
    mask = np.asarray(state.mask) > 0
    return hicut_ref(state.capacity, state_edges(state), active=mask)


@dataclass
class DRLGOTrainerConfig:
    capacity: int = 64            # graph-state capacity (max users)
    n_users: int = 50
    n_assoc: int = 150
    n_servers: int = 4
    episodes: int = 200
    change_rate: float = 0.2      # §6.4 dynamic change rate
    zeta_sp: float = 0.1          # ζ (Eq. 25) — balances R_sp vs ΔC in reward
    use_hicut: bool = True        # False → the DRL-only ablation (Fig. 12)
    partitioner: str | None = None  # registry name; None → use_hicut default
    cost_scale: float = 20.0      # reward normalizer
    updates_per_step: int = 1
    warmup_steps: int = 512
    seed: int = 0
    initial_scenario: GraphState | None = None   # e.g. dataset-derived

    @property
    def partitioner_name(self) -> str:
        """Registry name of the training-time partitioner. ``use_hicut``
        keeps its historical meaning (False → the DRL-only ablation)."""
        if self.partitioner is not None:
            return self.partitioner
        return "hicut_ref" if self.use_hicut else "none"


@dataclass
class DRLGOTrainer:
    cfg: DRLGOTrainerConfig

    def __post_init__(self):
        self.rng = np.random.default_rng(self.cfg.seed)
        self.key = jax.random.PRNGKey(self.cfg.seed)
        self.mcfg = MADDPGConfig(n_agents=self.cfg.n_servers,
                                 obs_dim=OBS_DIM, act_dim=ACT_DIM)
        self.key, k = jax.random.split(self.key)
        self.state = init_maddpg(self.mcfg, k)
        self.buffer = ReplayBuffer(self.mcfg, seed=self.cfg.seed)
        self.scenario = (self.cfg.initial_scenario
                         if self.cfg.initial_scenario is not None else
                         random_scenario(self.rng, self.cfg.capacity,
                                         self.cfg.n_users,
                                         self.cfg.n_assoc))
        self.net = costs.default_network(self.rng, self.cfg.capacity,
                                         self.cfg.n_servers)
        self.partitioner = get_partitioner(self.cfg.partitioner_name)
        self.history: list[dict] = []

    def make_env(self, scenario: GraphState) -> OffloadEnv:
        sub = self.partitioner(scenario)
        return OffloadEnv(self.net, scenario, sub,
                          zeta_sp=self.cfg.zeta_sp,
                          use_subgraph_reward=self.partitioner.name != "none",
                          cost_scale=self.cfg.cost_scale)

    def as_policy(self):
        """This trainer's (current) actors as a registry-compatible policy."""
        from repro.core.api import get_offload_policy
        return get_offload_policy("drlgo", trainer=self)

    def run_episode(self, env: OffloadEnv, explore: bool = True,
                    learn: bool = True) -> dict:
        obs, state = env.reset()
        ep_reward = 0.0
        losses = {}
        while env.t < env.num_steps:
            self.key, k = jax.random.split(self.key)
            acts = np.asarray(select_actions(self.mcfg, self.state,
                                             jnp.asarray(obs), k,
                                             explore=explore))
            obs2, state2, rew, done, _ = env.step(acts)
            ep_reward += float(rew.sum())          # Eq. (23)
            if learn:
                self.buffer.add(obs, state, acts, rew, obs2, state2, done)
                if len(self.buffer) >= max(self.mcfg.batch_size,
                                           self.cfg.warmup_steps):
                    for _ in range(self.cfg.updates_per_step):
                        batch = tuple(jnp.asarray(x)
                                      for x in self.buffer.sample())
                        self.state, losses = maddpg_update(
                            self.mcfg, self.state, batch)
            obs, state = obs2, state2
        final = env.final_cost()
        return {"reward": ep_reward, "system_cost": float(final.c),
                "t_all": float(final.t_all), "i_all": float(final.i_all),
                "cross_bits": float(final.cross_bits.sum()),
                **{k: float(v) for k, v in losses.items()}}

    def train(self, episodes: int | None = None, log_every: int = 0,
              ) -> list[dict]:
        episodes = episodes or self.cfg.episodes
        for e in range(episodes):
            # Algorithm 2 line 8: dynamically change env, rebuild G via
            # the dynamic graph model, run Algorithm 1 for G_sub
            self.scenario = perturb_scenario(self.rng, self.scenario,
                                             self.cfg.change_rate)
            env = self.make_env(self.scenario)
            stats = self.run_episode(env)
            stats["episode"] = e
            self.history.append(stats)
            if log_every and (e + 1) % log_every == 0:
                print(f"ep {e+1:4d} reward {stats['reward']:10.2f} "
                      f"cost {stats['system_cost']:10.2f}")
        return self.history

    def evaluate(self, scenario: GraphState, repeats: int = 1) -> dict:
        outs = [self.run_episode(self.make_env(scenario), explore=False,
                                 learn=False) for _ in range(repeats)]
        return {k: float(np.mean([o[k] for o in outs])) for k in outs[0]}
