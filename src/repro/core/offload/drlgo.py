"""DRLGO training (paper Algorithm 2) and the GraphEdge controller loop.

Each episode: dynamically perturb the scenario (20% change rate by default,
§6.4), rebuild the dynamic graph layout, run HiCut (Algorithm 1) to get
G_sub, then roll the MAMDP: every step all agents act, one user is placed,
transitions go to the replay buffer, and every agent takes a gradient step.

With ``DRLGOTrainerConfig.batch_envs = B > 1`` the trainer instead rolls B
independently-perturbed scenarios per update round through the vmapped
:class:`~repro.core.offload.batched_env.BatchedOffloadEnv` — the whole
collection loop runs in one ``lax.scan`` under jit (:func:`collect_batch`),
padded transitions are dropped, and the round then takes the same number of
gradient steps Algorithm 2 takes for *one* episode (one per env step), so
wall-clock per episode drops ≈ B× (see ``benchmarks/bench_convergence.py
--batch``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs
from repro.core.api import get_partitioner, state_edges
from repro.core.dynamic_graph import GraphState, random_scenario, \
    perturb_scenario
from repro.core.hicut import hicut_ref
from repro.core.offload.batched_env import (BatchedOffloadEnv, env_obs,
                                            env_reset, env_step)
from repro.core.offload.env import ACT_DIM, OBS_DIM, OffloadEnv
from repro.core.offload.maddpg import (MADDPGConfig, ReplayBuffer,
                                       init_maddpg, maddpg_update,
                                       select_actions)


def hicut_partition(state: GraphState) -> np.ndarray:
    """Run HiCut (ref impl) on a GraphState → [N] subgraph ids.

    Kept as a convenience wrapper; the registry equivalent is
    ``get_partitioner("hicut_ref")(state).subgraph``."""
    mask = np.asarray(state.mask) > 0
    return hicut_ref(state.capacity, state_edges(state), active=mask)


@partial(jax.jit, static_argnames=("mcfg", "explore"))
def collect_batch(mcfg: MADDPGConfig, st, scene, key, explore: bool = True):
    """Roll B episodes to completion in one jitted ``lax.scan``.

    Every scan step all B×M actors act (current MADDPG params ``st``) and
    every episode places one user. Scans the full capacity-N step range;
    steps past an episode's ``num_steps`` are masked no-ops (``valid``).

    Returns ``(EnvState, traj)`` with ``traj = (obs, acts, rew, obs2, done,
    valid)``, each leaf ``[N, B, ...]`` (time-major).
    """
    b, n = scene.mask.shape
    es0 = jax.vmap(env_reset)(scene)
    obs0 = jax.vmap(env_obs)(scene, es0)

    def one_step(carry, _):
        es, obs, key = carry
        key, k = jax.random.split(key)
        keys = jax.random.split(k, b)
        acts = jax.vmap(
            lambda o, kk: select_actions(mcfg, st, o, kk, explore=explore)
        )(obs, keys)
        valid = es.t < scene.num_steps
        es, obs2, rew, done, _ = jax.vmap(env_step)(scene, es, acts)
        return (es, obs2, key), (obs, acts, rew, obs2, done, valid)

    (es, _, _), traj = jax.lax.scan(one_step, (es0, obs0, key), None,
                                    length=n)
    return es, traj


@dataclass
class DRLGOTrainerConfig:
    capacity: int = 64            # graph-state capacity (max users)
    n_users: int = 50
    n_assoc: int = 150
    n_servers: int = 4
    episodes: int = 200
    change_rate: float = 0.2      # §6.4 dynamic change rate
    zeta_sp: float = 0.1          # ζ (Eq. 25) — balances R_sp vs ΔC in reward
    use_hicut: bool = True        # False → the DRL-only ablation (Fig. 12)
    partitioner: str | None = None  # registry name; None → use_hicut default
    cost_scale: float = 20.0      # reward normalizer
    updates_per_step: int = 1
    warmup_steps: int = 512
    batch_envs: int = 1           # B vmapped episodes per update round
    seed: int = 0
    initial_scenario: GraphState | None = None   # e.g. dataset-derived

    @property
    def partitioner_name(self) -> str:
        """Registry name of the training-time partitioner. ``use_hicut``
        keeps its historical meaning (False → the DRL-only ablation)."""
        if self.partitioner is not None:
            return self.partitioner
        return "hicut_ref" if self.use_hicut else "none"


@dataclass
class DRLGOTrainer:
    cfg: DRLGOTrainerConfig

    def __post_init__(self):
        self.rng = np.random.default_rng(self.cfg.seed)
        self.key = jax.random.PRNGKey(self.cfg.seed)
        self.mcfg = MADDPGConfig(n_agents=self.cfg.n_servers,
                                 obs_dim=OBS_DIM, act_dim=ACT_DIM)
        self.key, k = jax.random.split(self.key)
        self.state = init_maddpg(self.mcfg, k)
        self.buffer = ReplayBuffer(self.mcfg, seed=self.cfg.seed)
        self.scenario = (self.cfg.initial_scenario
                         if self.cfg.initial_scenario is not None else
                         random_scenario(self.rng, self.cfg.capacity,
                                         self.cfg.n_users,
                                         self.cfg.n_assoc))
        self.net = costs.default_network(self.rng, self.cfg.capacity,
                                         self.cfg.n_servers)
        self.partitioner = get_partitioner(self.cfg.partitioner_name)
        # B scenario streams, perturbed independently each round; stream 0
        # is the legacy self.scenario (kept in sync for evaluate()).
        self.scenarios: list[GraphState] = \
            [self.scenario] * max(1, self.cfg.batch_envs)
        self.history: list[dict] = []

    def make_env(self, scenario: GraphState) -> OffloadEnv:
        sub = self.partitioner(scenario)
        return OffloadEnv(self.net, scenario, sub,
                          zeta_sp=self.cfg.zeta_sp,
                          use_subgraph_reward=self.partitioner.name != "none",
                          cost_scale=self.cfg.cost_scale)

    def make_batched_env(self, scenarios: list[GraphState]
                         ) -> BatchedOffloadEnv:
        """Partition each scenario and stack into a vmappable batched env."""
        parts = [self.partitioner(s) for s in scenarios]
        return BatchedOffloadEnv.from_scenarios(
            self.net, scenarios, parts, zeta_sp=self.cfg.zeta_sp,
            use_subgraph_reward=self.partitioner.name != "none",
            cost_scale=self.cfg.cost_scale)

    def warm_update_jit(self) -> None:
        """Compile ``maddpg_update`` for this trainer's shapes without
        touching params or buffer (benchmarks call this so the one-time
        jit cost stays out of their timed region)."""
        m = self.mcfg
        z = lambda *s: jnp.zeros(s, jnp.float32)
        dummy = (z(m.batch_size, m.n_agents, m.obs_dim),
                 z(m.batch_size, m.n_agents * m.obs_dim),
                 z(m.batch_size, m.n_agents, m.act_dim),
                 z(m.batch_size, m.n_agents),
                 z(m.batch_size, m.n_agents, m.obs_dim),
                 z(m.batch_size, m.n_agents * m.obs_dim),
                 z(m.batch_size))
        maddpg_update(self.mcfg, self.state, dummy)    # result discarded

    def as_policy(self):
        """This trainer's (current) actors as a registry-compatible policy."""
        from repro.core.api import get_offload_policy
        return get_offload_policy("drlgo", trainer=self)

    def run_episode(self, env: OffloadEnv, explore: bool = True,
                    learn: bool = True) -> dict:
        obs, state = env.reset()
        ep_reward = 0.0
        losses = {}
        while env.t < env.num_steps:
            self.key, k = jax.random.split(self.key)
            acts = np.asarray(select_actions(self.mcfg, self.state,
                                             jnp.asarray(obs), k,
                                             explore=explore))
            obs2, state2, rew, done, _ = env.step(acts)
            ep_reward += float(rew.sum())          # Eq. (23)
            if learn:
                self.buffer.add(obs, state, acts, rew, obs2, state2, done)
                if len(self.buffer) >= max(self.mcfg.batch_size,
                                           self.cfg.warmup_steps):
                    for _ in range(self.cfg.updates_per_step):
                        batch = tuple(jnp.asarray(x)
                                      for x in self.buffer.sample())
                        self.state, losses = maddpg_update(
                            self.mcfg, self.state, batch)
            obs, state = obs2, state2
        final = env.final_cost()
        return {"reward": ep_reward, "system_cost": float(final.c),
                "t_all": float(final.t_all), "i_all": float(final.i_all),
                "cross_bits": float(final.cross_bits.sum()),
                **{k: float(v) for k, v in losses.items()}}

    def run_batch(self, benv: BatchedOffloadEnv, explore: bool = True,
                  learn: bool = True) -> list[dict]:
        """Collect B vmapped episodes in one scan, replay only the valid
        (non-padded) transitions, and take Algorithm 2's per-step gradient
        updates once per *round* (shared across the B episodes)."""
        self.key, k = jax.random.split(self.key)
        es, traj = collect_batch(self.mcfg, self.state, benv.scene, k,
                                 explore=explore)
        obs, acts, rew, obs2, done, valid = (np.asarray(x) for x in traj)
        t, b = valid.shape
        ep_reward = rew.sum(axis=(0, 2))               # [B], Eq. (23)
        losses = {}
        if learn:
            sel = valid.reshape(-1)
            flat = lambda x: x.reshape(t * b, *x.shape[2:])[sel]
            fobs, fobs2 = flat(obs), flat(obs2)
            self.buffer.add_batch(fobs, fobs.reshape(len(fobs), -1),
                                  flat(acts), flat(rew), fobs2,
                                  fobs2.reshape(len(fobs2), -1),
                                  flat(done.astype(np.float32)))
            if len(self.buffer) >= max(self.mcfg.batch_size,
                                       self.cfg.warmup_steps):
                n_upd = self.cfg.updates_per_step * int(valid.sum(0).max())
                for _ in range(n_upd):
                    batch = tuple(jnp.asarray(x) for x in self.buffer.sample())
                    self.state, losses = maddpg_update(self.mcfg, self.state,
                                                       batch)
        final = benv.final_costs(es)
        loss_f = {k_: float(v) for k_, v in losses.items()}
        return [{"reward": float(ep_reward[i]),
                 "system_cost": float(final.c[i]),
                 "t_all": float(final.t_all[i]),
                 "i_all": float(final.i_all[i]),
                 "cross_bits": float(np.asarray(final.cross_bits[i]).sum()),
                 **loss_f}
                for i in range(b)]

    def train(self, episodes: int | None = None, log_every: int = 0,
              ) -> list[dict]:
        episodes = episodes or self.cfg.episodes
        if self.cfg.batch_envs > 1:
            return self._train_batched(episodes, log_every)
        for _ in range(episodes):
            # Algorithm 2 line 8: dynamically change env, rebuild G via
            # the dynamic graph model, run Algorithm 1 for G_sub
            self.scenario = perturb_scenario(self.rng, self.scenario,
                                             self.cfg.change_rate)
            self.scenarios[0] = self.scenario
            env = self.make_env(self.scenario)
            stats = self.run_episode(env)
            stats["episode"] = len(self.history)
            e = stats["episode"]
            self.history.append(stats)
            if log_every and (e + 1) % log_every == 0:
                print(f"ep {e+1:4d} reward {stats['reward']:10.2f} "
                      f"cost {stats['system_cost']:10.2f}")
        return self.history

    def _train_batched(self, episodes: int, log_every: int = 0) -> list[dict]:
        """Vectorized training: ⌈episodes/B⌉ rounds of B episodes each."""
        b = self.cfg.batch_envs
        target = len(self.history) + episodes
        while len(self.history) < target:
            self.scenarios = [perturb_scenario(self.rng, s,
                                               self.cfg.change_rate)
                              for s in self.scenarios]
            self.scenario = self.scenarios[0]
            benv = self.make_batched_env(self.scenarios)
            for stats in self.run_batch(benv):
                stats["episode"] = len(self.history)
                self.history.append(stats)
            e = len(self.history)
            if log_every and (e // b) % max(1, log_every // b) == 0:
                last = self.history[-1]
                print(f"ep {e:4d} reward {last['reward']:10.2f} "
                      f"cost {last['system_cost']:10.2f}")
        return self.history

    def evaluate(self, scenario: GraphState, repeats: int = 1) -> dict:
        outs = [self.run_episode(self.make_env(scenario), explore=False,
                                 learn=False) for _ in range(repeats)]
        return {k: float(np.mean([o[k] for o in outs])) for k in outs[0]}
