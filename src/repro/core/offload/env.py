"""MAMDP environment for graph offloading (paper §5.1–5.2).

Users are iterated one by one; at each step every agent (one per edge
server) emits a two-dimensional action in [0,1]² (Eq. 22) whose first
component is read as "offload the current user to my server"; the user goes
to the eligible (non-full) server whose agent scored highest, which realizes
constraint C1 (exactly one server per user) by construction.

Rewards follow Eqs. (23)–(25): the serving agent receives
``−(C_m + R_sp)`` where ``C_m`` is the *marginal* system cost
(Eqs. 4,5,7,8,9 deltas + the user's share of the GNN energy, Eqs. 10–11)
of hosting the user, and ``R_sp = ζ·N_s/N_c`` penalizes spreading one
HiCut subgraph over many servers. The global reward is the sum.

Observations are a fixed-size featurization of Eq. (20): the current user's
(position, |N_i|, X_i, uplink bandwidth/distance to the agent's server), the
server's remaining service capacity and f_k, and subgraph-placement context.
The paper's raw O_m is variable-length (all users in scope); a fixed
featurization is the standard practical choice — the per-dimension layout is
documented in DESIGN.md ("Observation featurization").

All incremental cost arithmetic reuses the constants and formulas of
``repro.core.costs`` (checked against the batch ``system_cost`` in tests).

This class is the B=1 numpy-in/numpy-out reference implementation — the
controller and the non-learning baselines drive it directly. For training
at paper scale, :mod:`repro.core.offload.batched_env` ports the same
arithmetic to fixed-shape ``jnp`` pure functions vmappable over B episodes
(:meth:`OffloadEnv.as_batched` bridges a single env across); the parity
tests in ``tests/test_batched_env.py`` pin the two trajectories together.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import costs
from repro.core.costs import KB, EdgeNetwork, GNNCostParams
from repro.core.dynamic_graph import GraphState

OBS_DIM = 12
ACT_DIM = 2   # Eq. (22)


@dataclass
class OffloadEnv:
    net: EdgeNetwork
    state: GraphState
    subgraph: np.ndarray            # [N] int  — subgraph id (−1 masked); also
                                    # accepts a repro.core.api.Partition
    gnn: GNNCostParams = field(default_factory=GNNCostParams)
    zeta_sp: float = 1.0            # ζ in Eq. (25)
    use_subgraph_reward: bool = True  # False → the DRL-only ablation
    cost_scale: float = 1.0         # reward normalizer (does not change argmin)

    def __post_init__(self):
        if hasattr(self.subgraph, "subgraph"):    # api.Partition
            self.subgraph = self.subgraph.subgraph
        self.subgraph = np.asarray(self.subgraph, np.int64)
        self.m = int(self.net.server_pos.shape[0])
        self.n = int(self.state.capacity)
        self.mask = np.asarray(self.state.mask) > 0
        self.pos = np.asarray(self.state.pos)
        self.adj = np.asarray(self.state.adj)
        self.kb = np.asarray(self.state.task_kb)
        self.deg = self.adj.sum(1) * self.mask
        self.rate_up = np.asarray(costs.uplink_rate(self.net, self.state))
        self.rate_sv = np.asarray(costs.server_rate(self.net))
        self.f_k = np.asarray(self.net.f_k)
        self.caps = np.asarray(self.net.capacity)
        self.zeta_im = np.broadcast_to(
            np.asarray(self.net.zeta_im, np.float32), (self.m,))
        self.zeta_kl = np.broadcast_to(
            np.asarray(self.net.zeta_kl, np.float32), (self.m, self.m))
        self.d_im = np.linalg.norm(
            self.pos[:, None, :] - np.asarray(self.net.server_pos)[None], axis=-1)
        # visit users subgraph-by-subgraph (the controller knows G_sub)
        order = np.nonzero(self.mask)[0]
        self.order = order[np.argsort(self.subgraph[order], kind="stable")]

    # -- episode control ----------------------------------------------------
    def reset(self) -> tuple[np.ndarray, np.ndarray]:
        self.t = 0
        self.assign = -np.ones(self.n, np.int64)
        self.load = np.zeros(self.m)
        # zero-capacity servers (down/degraded) are ineligible from step 0
        self.done_m = self.load >= self.caps
        return self._obs(), self._global_state()

    @property
    def num_steps(self) -> int:
        return len(self.order)

    def current_user(self) -> int:
        return int(self.order[self.t])

    def _user_gnn_energy(self, i: int) -> float:
        """User i's share of Eqs. (10)–(11) summed over layers."""
        sizes = [s * KB for s in self.gnn.layer_sizes_kb]
        tot = 0.0
        for k in range(1, len(sizes)):
            tot += self.gnn.mu * self.deg[i] * sizes[k - 1]
            tot += self.gnn.theta * sizes[k - 1] * sizes[k] / \
                self.gnn.update_norm_bits + self.gnn.phi * sizes[k]
        return tot

    def marginal_cost(self, i: int, k: int) -> float:
        """ΔC of hosting user i on server k given the partial assignment."""
        bits = self.kb[i] * KB
        t_up = bits / max(self.rate_up[i, k], 1.0)
        i_up = bits * self.zeta_im[k]
        t_com = bits / self.f_k[k]
        t_tran = i_com = 0.0
        for j in np.nonzero(self.adj[i])[0]:
            l = self.assign[j]
            if l >= 0 and l != k:
                jbits = self.kb[j] * KB
                t_tran += (bits + jbits) / max(self.rate_sv[k, l], 1.0)
                i_com += self.zeta_kl[k, l] * (bits + jbits)
        return t_up + i_up + t_com + t_tran + i_com + self._user_gnn_energy(i)

    def _r_sp(self, i: int, k: int) -> float:
        """Eq. (25) for user i's subgraph after placing it on server k."""
        c = self.subgraph[i]
        members = (self.subgraph == c) & (self.assign >= 0)
        servers = set(self.assign[members].tolist()) | {k}
        n_c = members.sum() + 1
        return self.zeta_sp * len(servers) / n_c

    # -- observations --------------------------------------------------------
    def _obs(self) -> np.ndarray:
        """[M, OBS_DIM] local observations O_m (Eq. 20, fixed featurization)."""
        i = self.current_user() if self.t < self.num_steps else self.order[-1]
        obs = np.zeros((self.m, OBS_DIM), np.float32)
        c = self.subgraph[i]
        members = (self.subgraph == c) & (self.assign >= 0)
        n_c = max(members.sum(), 1)
        for m in range(self.m):
            frac_here = (self.assign[members] == m).sum() / n_c
            obs[m] = [
                self.pos[i, 0] / 2000.0, self.pos[i, 1] / 2000.0,
                self.deg[i] / 16.0,
                self.kb[i] / 1500.0,
                self.d_im[i, m] / 2000.0,
                self.rate_up[i, m] / 1e9,
                (self.caps[m] - self.load[m]) / max(self.caps[m], 1.0),
                self.f_k[m] / 10e9,
                frac_here,
                len(set(self.assign[members].tolist())) / self.m,
                self.load[m] / max(self.caps[m], 1.0),
                self.t / max(self.num_steps, 1),
            ]
        return obs

    def _global_state(self) -> np.ndarray:
        """S(t) = concat of local observations (Eq. 19)."""
        return self._obs().reshape(-1)

    # -- step ------------------------------------------------------------
    def step(self, actions: np.ndarray):
        """actions: [M, 2] in [0,1] (Eq. 22). Returns MADDPG transition."""
        i = self.current_user()
        score = actions[:, 0] - actions[:, 1]
        eligible = ~self.done_m
        if not eligible.any():          # all servers full: least-loaded hosts
            # ...but never a zero-capacity (down) server while any server
            # can still host at all
            hosting = self.caps > 0.0
            if hosting.any():
                load_h = np.where(hosting, self.load, np.inf)
                eligible = load_h == load_h.min()
            else:
                eligible = self.load == self.load.min()
        k = int(np.argmax(np.where(eligible, score, -np.inf)))
        dc = self.marginal_cost(i, k)
        r_sp = self._r_sp(i, k) if self.use_subgraph_reward else 0.0
        rewards = np.zeros(self.m, np.float32)
        rewards[k] = -(dc / self.cost_scale + r_sp)          # Eq. (24)
        self.assign[i] = k
        self.load[k] += 1
        self.done_m = self.load >= self.caps
        self.t += 1
        done = self.t >= self.num_steps
        if done:
            self.done_m[:] = True
        return self._obs(), self._global_state(), rewards, done, k

    # -- batched bridge ------------------------------------------------------
    def as_batched(self):
        """This env's scenario as a B=1 :class:`BatchedOffloadEnv` (same
        net, subgraph, reward constants — trajectories match to f32)."""
        from repro.core.offload.batched_env import BatchedOffloadEnv
        return BatchedOffloadEnv.from_scenarios(
            self.net, [self.state], [self.subgraph], gnn=self.gnn,
            zeta_sp=self.zeta_sp,
            use_subgraph_reward=self.use_subgraph_reward,
            cost_scale=self.cost_scale)

    # -- final accounting ----------------------------------------------------
    def final_cost(self) -> costs.SystemCost:
        """Batch-check the episode with the exact Eqs. (12)–(14) model."""
        import jax.numpy as jnp
        w = costs.assignment_onehot(jnp.asarray(self.assign), self.m)
        return costs.system_cost(self.net, self.state, w, self.gnn)
