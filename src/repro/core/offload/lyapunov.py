"""Lyapunov drift-plus-penalty offloading scheduler (queue-aware baseline).

System-aware co-inference schedulers (ACE-GNN, arXiv:2511.11586) place GNN
tasks by balancing instantaneous cost against server load/queue state; the
classic formalization is Lyapunov optimization. This module adds that
scheduler as an ``OffloadPolicy`` registry backend (``lyapunov``):

* every edge server ``k`` keeps a **virtual queue** ``Q_k`` measuring how
  far its arrivals have run ahead of its fair service share. One user
  arrives per scheduler step, so the per-step service vector is
  ``μ_k = cap_k / Σ cap`` (each server drains in proportion to its
  capacity) and the update is the standard
  ``Q_k ← max(Q_k + 1{k chosen} − μ_k, 0)``;
* the per-user decision minimizes the **drift-plus-penalty** score
  ``Q_k + V · ΔC(i, k) / cost_scale`` over the eligible (non-full)
  servers, where ``ΔC`` is the exact marginal system cost the MAMDP env
  charges (Eqs. 4–11 deltas via
  :func:`repro.core.offload.batched_env.marginal_cost`). ``V`` trades
  queue stability (small V → balance load by capacity share) against
  greedy cost minimization (large V → cost only).

The decision rule is a pure-jnp ``lax.scan`` over the batched-env
primitives (``env_reset`` / ``env_step`` — identical arithmetic to the
numpy walk), so the registry entry satisfies the
:class:`repro.core.api.JitPolicy` protocol: ``GraphEdgeController.step()``
runs the whole episode as one jitted XLA call and ``jit_step_fn()`` traces
it inside ``lax.scan`` rollouts with zero numpy round-trips.

``run_lyapunov`` is the numpy oracle: it drives the reference
:class:`~repro.core.offload.env.OffloadEnv` step by step, choosing servers
from the same float32 scene arrays, and is pinned step-for-step against
the scan by ``tests/test_lyapunov.py`` and the backends CI lane.

The registered policy runs at ``DEFAULT_V`` — the ``JitPolicy`` contract
requires ``decide`` to be a module-level (hashable-stable) function, so
the V knob lives on the functional APIs (``lyapunov_rollout_jit(scene,
v_weight)`` / ``run_lyapunov(env, v_weight)``) rather than the registry
instance; see DESIGN.md §6.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import KB
from repro.core.offload.baselines import (_episode_stats, _force_server,
                                          _force_server_jnp)
from repro.core.offload.batched_env import (EnvScene, _current_user,
                                            env_reset, env_step,
                                            make_scene, marginal_cost)
from repro.core.offload.env import OffloadEnv

DEFAULT_V = 1.0   # drift-plus-penalty trade-off of the registered policy


def virtual_queue_update(q, arrival, service, xp=jnp):
    """The Lyapunov virtual-queue recursion ``Q ← max(Q + a − μ, 0)``.

    The one update rule shared by every drift-plus-penalty consumer in the
    repo: the per-server scheduler below (jnp scan + numpy oracle) and the
    per-tenant admission controller of the streaming serving front-end
    (:class:`repro.serve.frontend.LyapunovAdmission`). ``xp`` selects the
    array module (``jnp`` for traced code, ``np`` for host-side walks)."""
    return xp.maximum(q + arrival - service, 0.0)


def _marginal_cost_all(scene: EnvScene, es, i) -> jnp.ndarray:
    """[M] marginal cost of hosting the current user on every server."""
    m = scene.f_k.shape[0]
    return jax.vmap(lambda k: marginal_cost(scene, es, i, k))(jnp.arange(m))


def _lyapunov_choice(scene: EnvScene, es, q: jnp.ndarray,
                     v_weight) -> jnp.ndarray:
    """argmin_k Q_k + V·ΔC(i,k)/cost_scale over eligible servers (the
    env's least-loaded fallback applies when every server is full)."""
    i = _current_user(scene, es)
    dc = _marginal_cost_all(scene, es, i)
    score = q + v_weight * dc / scene.cost_scale
    eligible = ~es.done_m
    hosting = scene.caps > 0.0          # never a down server while any hosts
    load_h = jnp.where(hosting, es.load, jnp.inf)
    fallback = jnp.where(hosting.any(), load_h == load_h.min(),
                         es.load == es.load.min())
    eligible = jnp.where(eligible.any(), eligible, fallback)
    return jnp.argmin(jnp.where(eligible, score, jnp.inf)).astype(jnp.int32)


def lyapunov_scan(scene: EnvScene, v_weight=DEFAULT_V):
    """Full episode as one ``lax.scan``; padded steps are no-ops.

    Returns ``(assign [N] i32, Σreward, q_final [M], q_max [])`` — the
    final virtual queues and the largest queue backlog seen anywhere in
    the episode (the boundedness certificate the tests assert on)."""
    m = scene.f_k.shape[0]
    mu = scene.caps / jnp.maximum(scene.caps.sum(), 1.0)

    def body(carry, _):
        es, q = carry
        k = _lyapunov_choice(scene, es, q, v_weight)
        valid = (es.t < scene.num_steps).astype(jnp.float32)
        es, _, rew, _, _ = env_step(scene, es, _force_server_jnp(m, k))
        arrival = jnp.zeros((m,), jnp.float32).at[k].set(valid)
        q = virtual_queue_update(q, arrival, mu * valid)
        return (es, q), (rew.sum(), q.max())

    init = (env_reset(scene), jnp.zeros((m,), jnp.float32))
    (es, q), (rewards, qmax) = jax.lax.scan(body, init, None,
                                            length=scene.mask.shape[0])
    return es.assign, rewards.sum(), q, jnp.maximum(qmax.max(), 0.0)


def lyapunov_rollout_jit(scene: EnvScene, v_weight=DEFAULT_V):
    """``JitPolicy.decide`` surface: ``scene → (assign, Σreward)``."""
    assign, reward, _, _ = lyapunov_scan(scene, v_weight)
    return assign, reward


# ---------------------------------------------------------------------------
# numpy oracle (drives the reference OffloadEnv step by step)
# ---------------------------------------------------------------------------

def _scene_numpy(env: OffloadEnv) -> dict:
    """The env's scenario as the float32 scene arrays the scan consumes."""
    scene = make_scene(env.net, env.state, env.subgraph,
                       zeta_sp=env.zeta_sp,
                       use_subgraph_reward=env.use_subgraph_reward,
                       cost_scale=env.cost_scale, gnn=env.gnn)
    return {f: np.asarray(getattr(scene, f)) for f in scene._fields}


def _marginal_cost_all_np(sc: dict, assign: np.ndarray, i: int
                          ) -> np.ndarray:
    """float32 numpy mirror of :func:`_marginal_cost_all` (same formulas,
    same f32 arrays, so the argmin matches the scan's step for step)."""
    m = sc["f_k"].shape[0]
    kb32 = np.float32(KB)
    bits = sc["kb"][i] * kb32
    t_up = bits / np.maximum(sc["rate_up"][i], np.float32(1.0))
    i_up = bits * sc["zeta_im"]
    t_com = bits / sc["f_k"]
    ks = np.arange(m)
    placed = (assign[None, :] >= 0) & (assign[None, :] != ks[:, None])
    w = sc["adj"][i][None, :] * placed                       # [M, N]
    pair = bits + sc["kb"] * kb32
    peer = np.clip(assign, 0, m - 1)
    rate = sc["rate_sv"][:, peer]                            # [M, N]
    t_tran = np.sum(w * pair[None, :] / np.maximum(rate, np.float32(1.0)),
                    axis=1, dtype=np.float32)
    i_com = np.sum(w * sc["zeta_kl"][:, peer] * pair[None, :], axis=1,
                   dtype=np.float32)
    return t_up + i_up + t_com + t_tran + i_com + sc["gnn_vec"][i]


def run_lyapunov(env: OffloadEnv, v_weight: float = DEFAULT_V) -> dict:
    """Numpy reference episode; stats gain ``queue_final``/``queue_max``."""
    sc = _scene_numpy(env)
    m = env.m
    caps = sc["caps"]
    mu = caps / max(float(caps.sum()), 1.0)
    q = np.zeros(m, np.float32)
    q_max = 0.0
    env.reset()
    total_r = 0.0
    while env.t < env.num_steps:
        i = env.current_user()
        dc = _marginal_cost_all_np(sc, env.assign, i)
        score = q + np.float32(v_weight) * dc / sc["cost_scale"]
        eligible = ~env.done_m
        if not eligible.any():
            hosting = env.caps > 0.0    # never a down server while any hosts
            if hosting.any():
                load_h = np.where(hosting, env.load, np.inf)
                eligible = load_h == load_h.min()
            else:
                eligible = env.load == env.load.min()
        k = int(np.argmin(np.where(eligible, score, np.inf)))
        _, _, rew, _, _ = env.step(_force_server(env, k))
        total_r += float(rew.sum())
        arrival = np.zeros(m, np.float32)
        arrival[k] = 1.0
        q = virtual_queue_update(q, arrival, mu, xp=np)
        q_max = max(q_max, float(q.max()))
    stats = _episode_stats(env, total_r)
    stats["queue_final"] = q
    stats["queue_max"] = q_max
    return stats
