"""Batched, vmappable MAMDP offloading environment (paper §5.1–5.2).

The legacy :class:`~repro.core.offload.env.OffloadEnv` walks users with
per-step numpy; reproducing the paper's Fig. 7–9 sweeps (hundreds of users,
many dynamic scenarios) makes that walk the training wall-clock bottleneck.
This module ports the marginal-cost arithmetic (Eqs. 4–11, 22–25) to
fixed-shape ``jnp`` pure functions over two pytrees so ``B`` independent
episodes/scenarios step together under ``jax.vmap`` (and whole rollouts run
under one ``lax.scan``/``jit``):

* :class:`EnvScene` — everything that is constant within one episode: the
  masked graph layout, per-user/server rates and distances, the HiCut
  subgraph ids, the fixed visit order, and the reward constants. Built for
  all B scenarios in one jitted vmapped pass by
  :meth:`BatchedOffloadEnv.from_scenarios`.
* :class:`EnvState` — the per-step mutable state: step counter, the partial
  user→server assignment, server loads, and the full-server flags.

Padding/masking convention (documented in DESIGN.md "Batched environment"):
every episode is rolled for exactly ``N = capacity`` steps. Steps with
``t >= num_steps`` (the scenario's active-user count) are no-ops — the
assignment, loads and flags freeze and the reward is zero — so shapes stay
static under ``jit``/``vmap`` while scenarios with different user counts
share one batch. Trainers drop the padded transitions via the per-step
``valid`` flag before replay.

Numerical parity with the numpy env is pinned by
``tests/test_batched_env.py``: with ``B = 1``, the same action sequence
produces the same server choices/assignment (exactly) and the same rewards
and observations (to float32 tolerance).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs
from repro.core.costs import KB, EdgeNetwork, GNNCostParams
from repro.core.dynamic_graph import GraphState
from repro.core.offload.env import OBS_DIM


class EnvScene(NamedTuple):
    """Per-episode constants (all ``jnp``; batchable with a leading B axis)."""
    mask: jnp.ndarray       # [N] f32 {0,1} — active users
    pos: jnp.ndarray        # [N, 2] f32
    adj: jnp.ndarray        # [N, N] f32 {0,1}
    kb: jnp.ndarray         # [N] f32 — task size X_i (kilobit)
    deg: jnp.ndarray        # [N] f32 — active degree |N_i|
    subgraph: jnp.ndarray   # [N] i32 — HiCut subgraph id (−1 inactive)
    order: jnp.ndarray      # [N] i32 — visit order, actives first by subgraph
    num_steps: jnp.ndarray  # [] i32 — #active users = #real steps
    rate_up: jnp.ndarray    # [N, M] f32 — uplink rate R_{i,m} (Eq. 3)
    rate_sv: jnp.ndarray    # [M, M] f32 — server rate R_{k,l} (Eq. 6)
    f_k: jnp.ndarray        # [M] f32
    caps: jnp.ndarray       # [M] f32
    d_im: jnp.ndarray       # [N, M] f32
    gnn_vec: jnp.ndarray    # [N] f32 — user share of Eqs. (10)–(11)
    zeta_im: jnp.ndarray    # [M] f32 — per-server ς_{i,m} (scalars broadcast)
    zeta_kl: jnp.ndarray    # [M, M] f32 — per-pair ς_{k,l} (scalars broadcast)
    zeta_sp: jnp.ndarray    # [] f32 — ζ in Eq. (25)
    sub_w: jnp.ndarray      # [] f32 — 1.0 ⇒ R_sp on, 0.0 ⇒ DRL-only ablation
    cost_scale: jnp.ndarray  # [] f32 — reward normalizer


class EnvState(NamedTuple):
    """Per-step episode state (the pytree carried through ``lax.scan``)."""
    t: jnp.ndarray          # [] i32 — step counter (runs to N, not num_steps)
    assign: jnp.ndarray     # [N] i32 — user → server (−1 unplaced)
    load: jnp.ndarray       # [M] f32 — users hosted per server
    done_m: jnp.ndarray     # [M] bool — server full


def _scene_core(net: EdgeNetwork, state: GraphState, subgraph: jnp.ndarray,
                zeta_sp, sub_w, cost_scale,
                gnn: GNNCostParams) -> EnvScene:
    """Pure scene construction (vmappable over (state, subgraph))."""
    mask = jnp.asarray(state.mask, jnp.float32)
    adj = jnp.asarray(state.adj, jnp.float32)
    deg = (adj.sum(1) * mask).astype(jnp.float32)
    active = mask > 0
    # actives first, stable by subgraph id — matches the numpy env's
    # nonzero(mask) + stable argsort over subgraph[order]
    big = jnp.int32(2 ** 30)
    order = jnp.argsort(jnp.where(active, subgraph, big),
                        stable=True).astype(jnp.int32)
    sizes = [s * KB for s in gnn.layer_sizes_kb]
    gnn_a = gnn.mu * sum(sizes[:-1])
    gnn_b = sum(gnn.theta * sizes[k - 1] * sizes[k] / gnn.update_norm_bits
                + gnn.phi * sizes[k] for k in range(1, len(sizes)))
    d_im = jnp.linalg.norm(
        jnp.asarray(state.pos)[:, None, :] - net.server_pos[None], axis=-1)
    return EnvScene(
        mask=mask, pos=jnp.asarray(state.pos, jnp.float32), adj=adj,
        kb=jnp.asarray(state.task_kb, jnp.float32), deg=deg,
        subgraph=subgraph, order=order,
        num_steps=active.sum().astype(jnp.int32),
        rate_up=costs.uplink_rate(net, state).astype(jnp.float32),
        rate_sv=costs.server_rate(net).astype(jnp.float32),
        f_k=jnp.asarray(net.f_k, jnp.float32),
        caps=jnp.asarray(net.capacity, jnp.float32),
        d_im=d_im.astype(jnp.float32),
        gnn_vec=(gnn_a * deg + gnn_b).astype(jnp.float32),
        zeta_im=jnp.broadcast_to(
            jnp.asarray(net.zeta_im, jnp.float32), net.f_k.shape),
        zeta_kl=jnp.broadcast_to(
            jnp.asarray(net.zeta_kl, jnp.float32),
            (net.f_k.shape[0], net.f_k.shape[0])),
        zeta_sp=jnp.asarray(zeta_sp, jnp.float32),
        sub_w=jnp.asarray(sub_w, jnp.float32),
        cost_scale=jnp.asarray(cost_scale, jnp.float32))


def _raw_subgraph(subgraph) -> np.ndarray:
    """``api.Partition`` or array → [N] int32 subgraph ids."""
    if hasattr(subgraph, "subgraph"):
        subgraph = subgraph.subgraph
    return np.asarray(subgraph, np.int32)


def make_scene(net: EdgeNetwork, state: GraphState, subgraph,
               zeta_sp: float = 1.0, use_subgraph_reward: bool = True,
               cost_scale: float = 1.0,
               gnn: GNNCostParams = GNNCostParams()) -> EnvScene:
    """One unbatched :class:`EnvScene` from a scenario + subgraph ids.

    Pure and traceable — callable from inside ``jit``/``scan`` (the
    controller's jitted decision path builds its scene here every step).
    Eager callers get the same arrays the batched constructors produce."""
    sub = (jnp.asarray(_raw_subgraph(subgraph))
           if not isinstance(subgraph, jnp.ndarray)
           else subgraph.astype(jnp.int32))
    return _scene_core(net, state, sub, zeta_sp,
                       1.0 if use_subgraph_reward else 0.0, cost_scale, gnn)


@partial(jax.jit, static_argnames=("gnn",))
def _make_scenes(net: EdgeNetwork, states: GraphState, subgraphs, zeta_sp,
                 sub_w, cost_scale, gnn: GNNCostParams) -> EnvScene:
    """All B scenes in one jitted vmapped pass (scalars broadcast)."""
    return jax.vmap(
        lambda st, sg: _scene_core(net, st, sg, zeta_sp, sub_w, cost_scale,
                                   gnn))(states, subgraphs)


def stack_states(states: Sequence[GraphState]) -> GraphState:
    """[B] GraphStates (same capacity) → batched GraphState pytree.

    Sits on the streaming control plane's hot path
    (``GraphEdgeController.step_batch`` stacks every scheduling cycle's
    layouts before the one vmapped decide), so leaves are stacked on the
    host — one ``device_put`` per leaf instead of B eager ``jnp.stack``
    dispatches. Tracer leaves (stacking inside a trace) keep the pure
    ``jnp`` road."""
    def _stack(*xs):
        if any(isinstance(x, jax.core.Tracer) for x in xs):
            return jnp.stack(xs)
        return jnp.asarray(np.stack([np.asarray(x) for x in xs]))
    return jax.tree_util.tree_map(_stack, *states)


# ---------------------------------------------------------------------------
# pure single-episode functions (vmap across a stacked EnvScene for batches)
# ---------------------------------------------------------------------------

def env_reset(scene: EnvScene) -> EnvState:
    n = scene.mask.shape[0]
    m = scene.f_k.shape[0]
    return EnvState(t=jnp.int32(0),
                    assign=jnp.full((n,), -1, jnp.int32),
                    load=jnp.zeros((m,), jnp.float32),
                    # a zero-capacity server (down / fully degraded) must be
                    # ineligible from the first placement, not just after it
                    # fills — mirror of OffloadEnv.reset
                    done_m=scene.caps <= 0.0)


def _current_user(scene: EnvScene, es: EnvState) -> jnp.ndarray:
    idx = jnp.clip(es.t, 0, jnp.maximum(scene.num_steps - 1, 0))
    return scene.order[idx]


def marginal_cost(scene: EnvScene, es: EnvState, i, k) -> jnp.ndarray:
    """ΔC of hosting user i on server k given the partial assignment
    (Eqs. 4, 5, 7, 8, 9 deltas + the user's GNN-energy share, Eqs. 10–11)."""
    m = scene.f_k.shape[0]
    bits = scene.kb[i] * KB
    t_up = bits / jnp.maximum(scene.rate_up[i, k], 1.0)
    i_up = bits * scene.zeta_im[k]
    t_com = bits / scene.f_k[k]
    placed = (es.assign >= 0) & (es.assign != k)
    w = scene.adj[i] * placed
    pair = bits + scene.kb * KB
    peer = jnp.clip(es.assign, 0, m - 1)
    rate = scene.rate_sv[k, peer]
    t_tran = jnp.sum(w * pair / jnp.maximum(rate, 1.0))
    i_com = jnp.sum(w * scene.zeta_kl[k, peer] * pair)
    return t_up + i_up + t_com + t_tran + i_com + scene.gnn_vec[i]


def _subgraph_onehot(scene: EnvScene, es: EnvState, i):
    """[N, M] bool: already-placed members of i's subgraph, by server."""
    m = scene.f_k.shape[0]
    members = (scene.subgraph == scene.subgraph[i]) & (es.assign >= 0)
    onehot = (es.assign[:, None] == jnp.arange(m)[None, :]) & members[:, None]
    return members, onehot


def r_sp(scene: EnvScene, es: EnvState, i, k) -> jnp.ndarray:
    """Eq. (25): ζ·N_s/N_c for user i's subgraph after placing it on k."""
    members, onehot = _subgraph_onehot(scene, es, i)
    used = jnp.any(onehot, axis=0).at[k].set(True)
    return scene.zeta_sp * used.sum() / (members.sum() + 1)


def env_obs(scene: EnvScene, es: EnvState) -> jnp.ndarray:
    """[M, OBS_DIM] local observations O_m (Eq. 20, fixed featurization —
    the per-dimension layout is identical to ``OffloadEnv._obs``)."""
    m = scene.f_k.shape[0]
    i = _current_user(scene, es)
    members, onehot = _subgraph_onehot(scene, es, i)
    n_c = jnp.maximum(members.sum(), 1)
    ones = jnp.ones((m,), jnp.float32)
    caps = jnp.maximum(scene.caps, 1.0)
    cols = [
        ones * scene.pos[i, 0] / 2000.0,
        ones * scene.pos[i, 1] / 2000.0,
        ones * scene.deg[i] / 16.0,
        ones * scene.kb[i] / 1500.0,
        scene.d_im[i] / 2000.0,
        scene.rate_up[i] / 1e9,
        (scene.caps - es.load) / caps,
        scene.f_k / 10e9,
        onehot.sum(0) / n_c,
        ones * jnp.any(onehot, axis=0).sum() / m,
        es.load / caps,
        ones * es.t / jnp.maximum(scene.num_steps, 1),
    ]
    return jnp.stack(cols, axis=-1).astype(jnp.float32)


def env_step(scene: EnvScene, es: EnvState, actions: jnp.ndarray):
    """One MAMDP step (Eqs. 22–25). ``actions``: [M, 2] in [0,1].

    Returns ``(EnvState, obs [M, OBS_DIM], rewards [M], done [], k [])``.
    Steps past ``num_steps`` are masked no-ops (see module docstring)."""
    m = scene.f_k.shape[0]
    i = _current_user(scene, es)
    score = actions[:, 0] - actions[:, 1]
    eligible = ~es.done_m
    # all full: least-loaded hosts the overflow — but never a zero-capacity
    # (down) server while any server can still host at all
    hosting = scene.caps > 0.0
    load_h = jnp.where(hosting, es.load, jnp.inf)
    fallback = jnp.where(hosting.any(), load_h == load_h.min(),
                         es.load == es.load.min())
    eligible = jnp.where(eligible.any(), eligible, fallback)
    k = jnp.argmax(jnp.where(eligible, score, -jnp.inf)).astype(jnp.int32)
    dc = marginal_cost(scene, es, i, k)
    valid = es.t < scene.num_steps
    reward_k = -(dc / scene.cost_scale + scene.sub_w * r_sp(scene, es, i, k))
    rewards = jnp.zeros((m,), jnp.float32).at[k].set(
        reward_k * valid.astype(jnp.float32))        # Eq. (24)
    assign = jnp.where(valid, es.assign.at[i].set(k), es.assign)
    load = jnp.where(valid, es.load.at[k].add(1.0), es.load)
    done_m = jnp.where(valid, load >= scene.caps, es.done_m)
    t = es.t + 1
    done = t >= scene.num_steps
    done_m = done_m | done
    es = EnvState(t, assign, load, done_m)
    return es, env_obs(scene, es), rewards, done, k


# ---------------------------------------------------------------------------
# batched wrappers
# ---------------------------------------------------------------------------

@jax.jit
def _reset_batch(scene: EnvScene):
    es = jax.vmap(env_reset)(scene)
    return es, jax.vmap(env_obs)(scene, es)


@jax.jit
def _step_batch(scene: EnvScene, es: EnvState, actions: jnp.ndarray):
    return jax.vmap(env_step)(scene, es, actions)


@partial(jax.jit, static_argnames=("gnn",))
def _final_batch(net: EdgeNetwork, states: GraphState, assign: jnp.ndarray,
                 gnn: GNNCostParams):
    m = net.server_pos.shape[0]

    def one(state, a):
        w = costs.assignment_onehot(a, m)
        return costs.system_cost(net, state, w, gnn)

    return jax.vmap(one)(states, assign)


@dataclass
class BatchedOffloadEnv:
    """B independent offloading episodes stepping together under vmap/jit.

    Functional counterpart of the numpy :class:`OffloadEnv` — state lives in
    the :class:`EnvState` pytree returned by :meth:`reset`, not on the
    object, so whole rollouts can run inside ``lax.scan`` (see
    ``repro.core.offload.drlgo.collect_batch``). Build with
    :meth:`from_scenarios`, or from a single legacy env with
    ``OffloadEnv.as_batched()``.
    """
    net: EdgeNetwork
    states: GraphState            # stacked [B, ...] scenario pytree
    scene: EnvScene               # stacked [B, ...] episode constants
    gnn: GNNCostParams = field(default_factory=GNNCostParams)

    @classmethod
    def from_scenarios(cls, net: EdgeNetwork,
                       scenarios: Sequence[GraphState], subgraphs: Sequence,
                       gnn: GNNCostParams = GNNCostParams(),
                       zeta_sp: float = 1.0,
                       use_subgraph_reward: bool = True,
                       cost_scale: float = 1.0) -> "BatchedOffloadEnv":
        """Build from B (scenario, subgraph/Partition) pairs sharing one
        :class:`EdgeNetwork` and capacity."""
        states = stack_states(list(scenarios))
        subs = jnp.asarray(np.stack([_raw_subgraph(g) for g in subgraphs]))
        scene = _make_scenes(net, states, subs, zeta_sp,
                             1.0 if use_subgraph_reward else 0.0,
                             cost_scale, gnn)
        return cls(net, states, scene, gnn=gnn)

    @property
    def batch_size(self) -> int:
        return int(self.scene.mask.shape[0])

    @property
    def m(self) -> int:
        return int(self.scene.f_k.shape[-1])

    @property
    def capacity(self) -> int:
        return int(self.scene.mask.shape[-1])

    @property
    def num_steps(self) -> np.ndarray:
        """[B] active-user count per episode (#real, non-padded steps)."""
        return np.asarray(self.scene.num_steps)

    def reset(self):
        """→ ``(EnvState, obs [B, M, OBS_DIM], global_state [B, M·OBS_DIM])``."""
        es, obs = _reset_batch(self.scene)
        return es, obs, obs.reshape(self.batch_size, -1)

    def step(self, es: EnvState, actions):
        """actions ``[B, M, 2]`` → ``(EnvState, obs, global_state,
        rewards [B, M], done [B], k [B])``."""
        es, obs, rew, done, k = _step_batch(self.scene, es,
                                            jnp.asarray(actions))
        return es, obs, obs.reshape(self.batch_size, -1), rew, done, k

    def final_costs(self, es: EnvState) -> costs.SystemCost:
        """Exact Eqs. (12)–(14) accounting per episode (leaves are [B, ...])."""
        return _final_batch(self.net, self.states, es.assign, self.gnn)
