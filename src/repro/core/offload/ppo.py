"""PTOM baseline (§6.1): PPO task offloading with the global state.

Single agent, categorical policy over the M servers for the current user,
clipped-surrogate PPO with GAE. Same network budget as DRLGO (3×64) and no
HiCut / subgraph constraint, exactly as the paper describes the baseline.

:meth:`PTOMAgent.run_batch` rolls B vmapped episodes per update through a
:class:`~repro.core.offload.batched_env.BatchedOffloadEnv` (one jitted
``lax.scan``), computes GAE per episode over the valid (non-padded) steps,
and updates on the pooled trajectory — the batched counterpart of
:meth:`PTOMAgent.run_episode`.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nnlib.core import mlp_init, mlp_apply
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.core.offload.batched_env import (BatchedOffloadEnv, env_obs,
                                            env_reset, env_step)
from repro.core.offload.env import OffloadEnv


@dataclass(frozen=True)
class PPOConfig:
    state_dim: int
    n_actions: int
    hidden: int = 64
    layers: int = 3
    lr: float = 3e-4
    gamma: float = 0.99
    lam: float = 0.95
    clip: float = 0.2
    epochs: int = 4
    minibatch: int = 256
    entropy_coef: float = 0.01


class PPOState(NamedTuple):
    policy: list
    value: list
    opt_p: object
    opt_v: object


def init_ppo(cfg: PPOConfig, key) -> PPOState:
    kp, kv = jax.random.split(key)
    sizes_p = [cfg.state_dim] + [cfg.hidden] * (cfg.layers - 1) + [cfg.n_actions]
    sizes_v = [cfg.state_dim] + [cfg.hidden] * (cfg.layers - 1) + [1]
    p, v = mlp_init(kp, sizes_p), mlp_init(kv, sizes_v)
    return PPOState(p, v, adamw_init(p), adamw_init(v))


def policy_logits(params, s):
    return mlp_apply(params, s)


@partial(jax.jit, static_argnames=("cfg",))
def ppo_update(cfg: PPOConfig, st: PPOState, batch):
    """One clipped-surrogate epoch. ``batch`` is ``(s, a, logp, adv, ret)``
    or ``(s, a, logp, adv, ret, w)`` with per-sample weights ``w`` — the
    batched rollout pads to a fixed size with ``w = 0`` so jit compiles
    once instead of retracing on every pooled-trajectory length."""
    if len(batch) == 6:
        s, a, logp_old, adv, ret, w = batch
    else:
        s, a, logp_old, adv, ret = batch
        w = jnp.ones_like(adv)
    opt = AdamWConfig(lr=cfg.lr)
    wsum = jnp.maximum(w.sum(), 1.0)
    wmean = lambda x: (x * w).sum() / wsum

    def ploss(p):
        logits = policy_logits(p, s)
        logp = jax.nn.log_softmax(logits)[jnp.arange(a.shape[0]), a]
        ratio = jnp.exp(logp - logp_old)
        clipped = jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip)
        ent = wmean(-jnp.sum(jax.nn.softmax(logits) *
                             jax.nn.log_softmax(logits), -1))
        return -wmean(jnp.minimum(ratio * adv, clipped * adv)) \
            - cfg.entropy_coef * ent

    def vloss(p):
        v = mlp_apply(p, s)[:, 0]
        return wmean((v - ret) ** 2)

    pl, gp = jax.value_and_grad(ploss)(st.policy)
    vl, gv = jax.value_and_grad(vloss)(st.value)
    newp, op = adamw_update(opt, gp, st.opt_p, st.policy)
    newv, ov = adamw_update(opt, gv, st.opt_v, st.value)
    return PPOState(newp, newv, op, ov), {"policy_loss": pl, "value_loss": vl}


@partial(jax.jit, static_argnames=("explore",))
def ptom_collect(st: PPOState, scene, key, explore: bool = True):
    """B PTOM episodes in one jitted scan over the batched env.

    Returns ``(EnvState, (s, a, logp, r, v, valid))``, leaves ``[N, B, ...]``
    time-major; ``r`` is the summed per-step reward (Eq. 23 terms)."""
    b, n = scene.mask.shape
    m = scene.f_k.shape[-1]
    es0 = jax.vmap(env_reset)(scene)
    s0 = jax.vmap(env_obs)(scene, es0).reshape(b, -1)

    def one_step(carry, _):
        es, s, key = carry
        logits = policy_logits(st.policy, s)                     # [B, M]
        v = mlp_apply(st.value, s)[:, 0]
        key, k = jax.random.split(key)
        if explore:
            a = jax.random.categorical(k, logits)
        else:
            a = jnp.argmax(logits, axis=-1)
        logp = jax.nn.log_softmax(logits)[jnp.arange(b), a]
        # PTOM picks the server directly: one-hot "yes" to server a
        acts = jnp.zeros((b, m, 2), jnp.float32).at[:, :, 1].set(1.0)
        acts = acts.at[jnp.arange(b), a, 0].set(2.0)
        valid = es.t < scene.num_steps
        es, obs2, rew, _, _ = jax.vmap(env_step)(scene, es, acts)
        out = (s, a, logp, rew.sum(-1), v, valid)
        return (es, obs2.reshape(b, -1), key), out

    (es, _, _), traj = jax.lax.scan(one_step, (es0, s0, key), None, length=n)
    return es, traj


@dataclass
class PTOMAgent:
    """Rollout + update driver for the PPO baseline."""
    cfg: PPOConfig
    seed: int = 0

    def __post_init__(self):
        self.key = jax.random.PRNGKey(self.seed)
        self.key, k = jax.random.split(self.key)
        self.state = init_ppo(self.cfg, k)

    def run_episode(self, env: OffloadEnv, learn: bool = True,
                    explore: bool = True) -> dict:
        obs, s = env.reset()
        traj = {k: [] for k in ("s", "a", "logp", "r", "v")}
        total_r = 0.0
        while env.t < env.num_steps:
            logits = policy_logits(self.state.policy, jnp.asarray(s))
            v = mlp_apply(self.state.value, jnp.asarray(s))[0]
            self.key, k = jax.random.split(self.key)
            if explore:
                a = int(jax.random.categorical(k, logits))
            else:
                a = int(jnp.argmax(logits))
            logp = jax.nn.log_softmax(logits)[a]
            # PTOM picks the server directly: one-hot "yes" to server a
            acts = np.zeros((env.m, 2), np.float32)
            acts[:, 1] = 1.0
            acts[a, 0] = 2.0
            obs, s2, rew, done, _ = env.step(acts)
            r = float(rew.sum())
            total_r += r
            for key_, val in zip(("s", "a", "logp", "r", "v"),
                                 (s, a, float(logp), r, float(v))):
                traj[key_].append(val)
            s = s2
        if learn:
            self._update(traj)
        final = env.final_cost()
        return {"reward": total_r, "system_cost": float(final.c),
                "t_all": float(final.t_all), "i_all": float(final.i_all),
                "cross_bits": float(final.cross_bits.sum())}

    def run_batch(self, benv: BatchedOffloadEnv, learn: bool = True,
                  explore: bool = True) -> list[dict]:
        """Roll B vmapped episodes, GAE per episode over valid steps, one
        pooled PPO update. Returns one stats dict per episode."""
        self.key, k = jax.random.split(self.key)
        es, traj = ptom_collect(self.state, benv.scene, k, explore=explore)
        s, a, logp, r, v, valid = (np.asarray(x) for x in traj)
        n_steps = valid.sum(0)                          # [B] valid prefix
        if learn:
            ss, aa, lp, adv, ret = [], [], [], [], []
            for b in range(s.shape[1]):                 # GAE per episode
                n = int(n_steps[b])
                if n == 0:
                    continue
                adv_b, ret_b = self._gae(r[:n, b], v[:n, b])
                ss.append(s[:n, b]); aa.append(a[:n, b]); lp.append(logp[:n, b])
                adv.append(adv_b); ret.append(ret_b)
            if ss:
                adv = np.concatenate(adv)
                adv = (adv - adv.mean()) / (adv.std() + 1e-8)
                # pad the pooled batch to the fixed size T×B (weight 0) so
                # ppo_update compiles once, not per pooled length
                k, full = len(adv), s.shape[0] * s.shape[1]
                pad = lambda x, d: np.concatenate(
                    [x, np.zeros((full - k, *x.shape[1:]), d)]) \
                    if full > k else x[:full]
                w = pad(np.ones(k, np.float32), np.float32)
                batch = (jnp.asarray(pad(np.concatenate(ss).astype(
                             np.float32), np.float32)),
                         jnp.asarray(pad(np.concatenate(aa).astype(
                             np.int32), np.int32)),
                         jnp.asarray(pad(np.concatenate(lp).astype(
                             np.float32), np.float32)),
                         jnp.asarray(pad(adv.astype(np.float32),
                                         np.float32)),
                         jnp.asarray(pad(np.concatenate(ret).astype(
                             np.float32), np.float32)),
                         jnp.asarray(w))
                for _ in range(self.cfg.epochs):
                    self.state, _ = ppo_update(self.cfg, self.state, batch)
        final = benv.final_costs(es)
        return [{"reward": float(r[:, b].sum()),
                 "system_cost": float(final.c[b]),
                 "t_all": float(final.t_all[b]),
                 "i_all": float(final.i_all[b]),
                 "cross_bits": float(np.asarray(final.cross_bits[b]).sum())}
                for b in range(s.shape[1])]

    def _gae(self, r: np.ndarray, v: np.ndarray
             ) -> tuple[np.ndarray, np.ndarray]:
        """One episode's GAE advantages + returns (terminal bootstrap 0)."""
        r = np.asarray(r, np.float32)
        v = np.append(np.asarray(v, np.float32), 0.0)
        adv = np.zeros_like(r)
        gae = 0.0
        for t in reversed(range(len(r))):
            delta = r[t] + self.cfg.gamma * v[t + 1] - v[t]
            gae = delta + self.cfg.gamma * self.cfg.lam * gae
            adv[t] = gae
        return adv, adv + v[:-1]

    def _update(self, traj):
        adv, ret = self._gae(np.array(traj["r"], np.float32),
                             np.array(traj["v"], np.float32))
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)
        s = jnp.asarray(np.array(traj["s"], np.float32))
        a = jnp.asarray(np.array(traj["a"], np.int32))
        lp = jnp.asarray(np.array(traj["logp"], np.float32))
        batch = (s, a, lp, jnp.asarray(adv), jnp.asarray(ret))
        for _ in range(self.cfg.epochs):
            self.state, _ = ppo_update(self.cfg, self.state, batch)
