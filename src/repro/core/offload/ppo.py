"""PTOM baseline (§6.1): PPO task offloading with the global state.

Single agent, categorical policy over the M servers for the current user,
clipped-surrogate PPO with GAE. Same network budget as DRLGO (3×64) and no
HiCut / subgraph constraint, exactly as the paper describes the baseline.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nnlib.core import mlp_init, mlp_apply
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.core.offload.env import OffloadEnv


@dataclass(frozen=True)
class PPOConfig:
    state_dim: int
    n_actions: int
    hidden: int = 64
    layers: int = 3
    lr: float = 3e-4
    gamma: float = 0.99
    lam: float = 0.95
    clip: float = 0.2
    epochs: int = 4
    minibatch: int = 256
    entropy_coef: float = 0.01


class PPOState(NamedTuple):
    policy: list
    value: list
    opt_p: object
    opt_v: object


def init_ppo(cfg: PPOConfig, key) -> PPOState:
    kp, kv = jax.random.split(key)
    sizes_p = [cfg.state_dim] + [cfg.hidden] * (cfg.layers - 1) + [cfg.n_actions]
    sizes_v = [cfg.state_dim] + [cfg.hidden] * (cfg.layers - 1) + [1]
    p, v = mlp_init(kp, sizes_p), mlp_init(kv, sizes_v)
    return PPOState(p, v, adamw_init(p), adamw_init(v))


def policy_logits(params, s):
    return mlp_apply(params, s)


@partial(jax.jit, static_argnames=("cfg",))
def ppo_update(cfg: PPOConfig, st: PPOState, batch):
    s, a, logp_old, adv, ret = batch
    opt = AdamWConfig(lr=cfg.lr)

    def ploss(p):
        logits = policy_logits(p, s)
        logp = jax.nn.log_softmax(logits)[jnp.arange(a.shape[0]), a]
        ratio = jnp.exp(logp - logp_old)
        clipped = jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip)
        ent = -jnp.mean(jnp.sum(jax.nn.softmax(logits) *
                                jax.nn.log_softmax(logits), -1))
        return -jnp.mean(jnp.minimum(ratio * adv, clipped * adv)) \
            - cfg.entropy_coef * ent

    def vloss(p):
        v = mlp_apply(p, s)[:, 0]
        return jnp.mean((v - ret) ** 2)

    pl, gp = jax.value_and_grad(ploss)(st.policy)
    vl, gv = jax.value_and_grad(vloss)(st.value)
    newp, op = adamw_update(opt, gp, st.opt_p, st.policy)
    newv, ov = adamw_update(opt, gv, st.opt_v, st.value)
    return PPOState(newp, newv, op, ov), {"policy_loss": pl, "value_loss": vl}


@dataclass
class PTOMAgent:
    """Rollout + update driver for the PPO baseline."""
    cfg: PPOConfig
    seed: int = 0

    def __post_init__(self):
        self.key = jax.random.PRNGKey(self.seed)
        self.key, k = jax.random.split(self.key)
        self.state = init_ppo(self.cfg, k)

    def run_episode(self, env: OffloadEnv, learn: bool = True,
                    explore: bool = True) -> dict:
        obs, s = env.reset()
        traj = {k: [] for k in ("s", "a", "logp", "r", "v")}
        total_r = 0.0
        while env.t < env.num_steps:
            logits = policy_logits(self.state.policy, jnp.asarray(s))
            v = mlp_apply(self.state.value, jnp.asarray(s))[0]
            self.key, k = jax.random.split(self.key)
            if explore:
                a = int(jax.random.categorical(k, logits))
            else:
                a = int(jnp.argmax(logits))
            logp = jax.nn.log_softmax(logits)[a]
            # PTOM picks the server directly: one-hot "yes" to server a
            acts = np.zeros((env.m, 2), np.float32)
            acts[:, 1] = 1.0
            acts[a, 0] = 2.0
            obs, s2, rew, done, _ = env.step(acts)
            r = float(rew.sum())
            total_r += r
            for key_, val in zip(("s", "a", "logp", "r", "v"),
                                 (s, a, float(logp), r, float(v))):
                traj[key_].append(val)
            s = s2
        if learn:
            self._update(traj)
        final = env.final_cost()
        return {"reward": total_r, "system_cost": float(final.c),
                "t_all": float(final.t_all), "i_all": float(final.i_all),
                "cross_bits": float(final.cross_bits.sum())}

    def _update(self, traj):
        r = np.array(traj["r"], np.float32)
        v = np.array(traj["v"] + [0.0], np.float32)
        adv = np.zeros_like(r)
        gae = 0.0
        for t in reversed(range(len(r))):
            delta = r[t] + self.cfg.gamma * v[t + 1] - v[t]
            gae = delta + self.cfg.gamma * self.cfg.lam * gae
            adv[t] = gae
        ret = adv + v[:-1]
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)
        s = jnp.asarray(np.array(traj["s"], np.float32))
        a = jnp.asarray(np.array(traj["a"], np.int32))
        lp = jnp.asarray(np.array(traj["logp"], np.float32))
        batch = (s, a, lp, jnp.asarray(adv), jnp.asarray(ret))
        for _ in range(self.cfg.epochs):
            self.state, _ = ppo_update(self.cfg, self.state, batch)
