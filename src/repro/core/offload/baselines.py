"""Non-learning offloading baselines (paper §6.1): GM and RM."""
from __future__ import annotations

import numpy as np

from repro.core.offload.env import OffloadEnv


def run_greedy(env: OffloadEnv) -> dict:
    """GM: offload each user to the nearest (non-full) edge server."""
    env.reset()
    total_r = 0.0
    while env.t < env.num_steps:
        i = env.current_user()
        d = env.d_im[i].copy()
        d[env.done_m] = np.inf
        if not np.isfinite(d).any():
            d = env.d_im[i]
        k = int(np.argmin(d))
        acts = np.zeros((env.m, 2), np.float32)
        acts[:, 1] = 1.0
        acts[k, 0] = 2.0
        _, _, rew, _, _ = env.step(acts)
        total_r += float(rew.sum())
    final = env.final_cost()
    return {"reward": total_r, "system_cost": float(final.c),
            "t_all": float(final.t_all), "i_all": float(final.i_all),
            "cross_bits": float(final.cross_bits.sum())}


def run_random(env: OffloadEnv, seed: int = 0) -> dict:
    """RM: offload each user to a uniformly random server."""
    rng = np.random.default_rng(seed)
    env.reset()
    total_r = 0.0
    while env.t < env.num_steps:
        k = int(rng.integers(env.m))
        acts = np.zeros((env.m, 2), np.float32)
        acts[:, 1] = 1.0
        acts[k, 0] = 2.0
        _, _, rew, _, _ = env.step(acts)
        total_r += float(rew.sum())
    final = env.final_cost()
    return {"reward": total_r, "system_cost": float(final.c),
            "t_all": float(final.t_all), "i_all": float(final.i_all),
            "cross_bits": float(final.cross_bits.sum())}
