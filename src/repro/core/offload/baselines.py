"""Non-learning offloading baselines: GM and RM (paper §6.1), plus LM.

Each baseline drives an :class:`OffloadEnv` episode to completion and
returns the standard stats dict; the registry adapters in
``repro.core.api`` expose them as ``greedy`` / ``random`` / ``local``
offload policies.

The same GM/LM decision rules also exist as pure-jnp episode rollouts
(:func:`greedy_rollout_jit` / :func:`local_rollout_jit`) over the
batched-env primitives (``env_reset``/``env_step`` — the identical
marginal-cost arithmetic, Eqs. 4–11/22–25), so the whole episode runs as
one ``lax.scan`` with no per-user Python. These are the decision functions
behind the ``greedy_jit`` / ``local_jit`` registry entries and the
controller's fully-jitted ``partition → offload → cost`` step; parity with
the numpy walks is pinned by ``tests/test_jit_policies.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.offload.batched_env import (EnvScene, _current_user,
                                            env_reset, env_step)
from repro.core.offload.env import OffloadEnv


def _force_server(env: OffloadEnv, k: int) -> np.ndarray:
    """Action block that deterministically routes the current user to k."""
    acts = np.zeros((env.m, 2), np.float32)
    acts[:, 1] = 1.0
    acts[k, 0] = 2.0
    return acts


def _episode_stats(env: OffloadEnv, total_r: float) -> dict:
    final = env.final_cost()
    return {"reward": total_r, "system_cost": float(final.c),
            "t_all": float(final.t_all), "i_all": float(final.i_all),
            "cross_bits": float(final.cross_bits.sum())}


def run_greedy(env: OffloadEnv) -> dict:
    """GM: offload each user to the nearest (non-full) edge server."""
    env.reset()
    total_r = 0.0
    while env.t < env.num_steps:
        i = env.current_user()
        d = env.d_im[i].copy()
        d[env.done_m] = np.inf
        if not np.isfinite(d).any():
            d = env.d_im[i]
        k = int(np.argmin(d))
        _, _, rew, _, _ = env.step(_force_server(env, k))
        total_r += float(rew.sum())
    return _episode_stats(env, total_r)


def run_random(env: OffloadEnv, seed: int = 0) -> dict:
    """RM: offload each user to a uniformly random server."""
    rng = np.random.default_rng(seed)
    env.reset()
    total_r = 0.0
    while env.t < env.num_steps:
        k = int(rng.integers(env.m))
        _, _, rew, _, _ = env.step(_force_server(env, k))
        total_r += float(rew.sum())
    return _episode_stats(env, total_r)


# ---------------------------------------------------------------------------
# pure-jnp episode rollouts (lax.scan over the batched-env primitives)
# ---------------------------------------------------------------------------

def _force_server_jnp(m: int, k) -> jnp.ndarray:
    """jnp twin of :func:`_force_server` ([M, 2] action block)."""
    return jnp.zeros((m, 2), jnp.float32).at[:, 1].set(1.0).at[k, 0].set(2.0)


def _rollout_scene(scene: EnvScene, choose_server):
    """Roll one full episode under ``lax.scan``: N fixed-shape steps, padded
    steps are no-ops (batched-env convention). ``choose_server(scene, es)``
    → server index for the current user. Returns (assign [N] i32, Σreward)."""
    m = scene.f_k.shape[0]

    def body(es, _):
        acts = _force_server_jnp(m, choose_server(scene, es))
        es, _, rew, _, _ = env_step(scene, es, acts)
        return es, rew.sum()

    es, rewards = jax.lax.scan(body, env_reset(scene), None,
                               length=scene.mask.shape[0])
    return es.assign, rewards.sum()


def _greedy_choice(scene: EnvScene, es) -> jnp.ndarray:
    """GM rule: nearest non-full server (nearest overall when all full —
    the env's least-loaded fallback then resolves the placement)."""
    d = scene.d_im[_current_user(scene, es)]
    d_open = jnp.where(es.done_m, jnp.inf, d)
    d_use = jnp.where(jnp.isfinite(d_open).any(), d_open, d)
    return jnp.argmin(d_use).astype(jnp.int32)


def _local_choice(scene: EnvScene, es) -> jnp.ndarray:
    """LM rule: nearest server, ignoring load."""
    return jnp.argmin(scene.d_im[_current_user(scene, es)]).astype(jnp.int32)


def greedy_rollout_jit(scene: EnvScene):
    """GM episode as one jit-able scan — same trajectory as :func:`run_greedy`
    (server choices exact, rewards to f32 tolerance)."""
    return _rollout_scene(scene, _greedy_choice)


def local_rollout_jit(scene: EnvScene):
    """LM episode as one jit-able scan — the pure twin of :func:`run_local`."""
    return _rollout_scene(scene, _local_choice)


def run_local(env: OffloadEnv) -> dict:
    """LM: offload each user to its nearest server, ignoring server load
    (pure locality — the env still enforces capacity via eligibility)."""
    env.reset()
    total_r = 0.0
    while env.t < env.num_steps:
        k = int(np.argmin(env.d_im[env.current_user()]))
        _, _, rew, _, _ = env.step(_force_server(env, k))
        total_r += float(rew.sum())
    return _episode_stats(env, total_r)
