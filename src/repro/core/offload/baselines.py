"""Non-learning offloading baselines: GM and RM (paper §6.1), plus LM.

Each baseline drives an :class:`OffloadEnv` episode to completion and
returns the standard stats dict; the registry adapters in
``repro.core.api`` expose them as ``greedy`` / ``random`` / ``local``
offload policies.
"""
from __future__ import annotations

import numpy as np

from repro.core.offload.env import OffloadEnv


def _force_server(env: OffloadEnv, k: int) -> np.ndarray:
    """Action block that deterministically routes the current user to k."""
    acts = np.zeros((env.m, 2), np.float32)
    acts[:, 1] = 1.0
    acts[k, 0] = 2.0
    return acts


def _episode_stats(env: OffloadEnv, total_r: float) -> dict:
    final = env.final_cost()
    return {"reward": total_r, "system_cost": float(final.c),
            "t_all": float(final.t_all), "i_all": float(final.i_all),
            "cross_bits": float(final.cross_bits.sum())}


def run_greedy(env: OffloadEnv) -> dict:
    """GM: offload each user to the nearest (non-full) edge server."""
    env.reset()
    total_r = 0.0
    while env.t < env.num_steps:
        i = env.current_user()
        d = env.d_im[i].copy()
        d[env.done_m] = np.inf
        if not np.isfinite(d).any():
            d = env.d_im[i]
        k = int(np.argmin(d))
        _, _, rew, _, _ = env.step(_force_server(env, k))
        total_r += float(rew.sum())
    return _episode_stats(env, total_r)


def run_random(env: OffloadEnv, seed: int = 0) -> dict:
    """RM: offload each user to a uniformly random server."""
    rng = np.random.default_rng(seed)
    env.reset()
    total_r = 0.0
    while env.t < env.num_steps:
        k = int(rng.integers(env.m))
        _, _, rew, _, _ = env.step(_force_server(env, k))
        total_r += float(rew.sum())
    return _episode_stats(env, total_r)


def run_local(env: OffloadEnv) -> dict:
    """LM: offload each user to its nearest server, ignoring server load
    (pure locality — the env still enforces capacity via eligibility)."""
    env.reset()
    total_r = 0.0
    while env.t < env.num_steps:
        k = int(np.argmin(env.d_im[env.current_user()]))
        _, _, rew, _, _ = env.step(_force_server(env, k))
        total_r += float(rew.sum())
    return _episode_stats(env, total_r)
