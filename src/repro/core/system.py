"""GraphEdge — the top-level architecture (paper Figs. 1–2).

Processing flow per time step:
  1. perceive the user topology → dynamic graph layout G(t) (§3.2),
  2. optimize the layout with HiCut → G_sub (§4, subproblem P1),
  3. run the (trained) DRLGO policy → graph offloading decision w (§5, P2),
  4. broadcast w; the offloaded tasks feed distributed GNN inference
     (``repro.gnn.distributed``), and the exact system cost (Eqs. 12–14)
     is accounted.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import costs
from repro.core.dynamic_graph import GraphState
from repro.core.offload.drlgo import DRLGOTrainer, hicut_partition
from repro.core.offload.env import OffloadEnv


@dataclass
class GraphEdge:
    """EC-controller facade: perceive → HiCut → offload → account."""
    trainer: DRLGOTrainer

    def offload(self, scenario: GraphState) -> dict:
        """One control step: returns assignment + full cost accounting."""
        sub = hicut_partition(scenario)
        env = OffloadEnv(self.trainer.net, scenario, sub,
                         zeta_sp=self.trainer.cfg.zeta_sp,
                         cost_scale=self.trainer.cfg.cost_scale)
        stats = self.trainer.run_episode(env, explore=False, learn=False)
        return {
            "assignment": env.assign.copy(),
            "subgraphs": sub,
            "num_subgraphs": int(len(np.unique(sub[sub >= 0]))),
            **stats,
        }
