"""Deprecated ``GraphEdge`` facade — use :mod:`repro.core.api` instead.

The top-level architecture (paper Figs. 1–2) now lives behind the pluggable
:class:`repro.core.api.GraphEdgeController`:

    controller = GraphEdgeController(net=trainer.net, policy="drlgo",
                                     policy_kwargs={"trainer": trainer},
                                     partitioner="hicut_jax")
    decision = controller.step(scenario)

This module keeps the old one-shot ``GraphEdge.offload`` entry point working
for one release; it delegates to a controller configured exactly like the
legacy wiring (``hicut_ref`` + the trainer's MADDPG actors) and returns the
same flat stats dict.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

from repro.core.api import GraphEdgeController
from repro.core.dynamic_graph import GraphState
from repro.core.offload.drlgo import DRLGOTrainer


@dataclass
class GraphEdge:
    """Deprecated EC-controller facade: perceive → HiCut → offload → account.

    .. deprecated:: PR 1
        Use :class:`repro.core.api.GraphEdgeController`.
    """
    trainer: DRLGOTrainer

    def __post_init__(self):
        warnings.warn(
            "GraphEdge is deprecated; use repro.core.api.GraphEdgeController"
            " (policy='drlgo', partitioner='hicut_ref') instead.",
            DeprecationWarning, stacklevel=2)
        self._controller = GraphEdgeController(
            net=self.trainer.net,
            policy="drlgo", policy_kwargs={"trainer": self.trainer},
            partitioner="hicut_ref",
            zeta_sp=self.trainer.cfg.zeta_sp,
            cost_scale=self.trainer.cfg.cost_scale)

    def offload(self, scenario: GraphState) -> dict:
        """One control step: returns assignment + full cost accounting."""
        return self._controller.step(scenario).summary()
