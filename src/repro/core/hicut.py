"""HiCut — hierarchical traversal graph cut (paper §4, Algorithm 1).

Two implementations with identical semantics:

* ``hicut_ref`` — numpy adjacency-list transcription of Algorithm 1,
  line-for-line. O(N² + NE) total (LayerCut is a BFS, invoked from every
  still-unassigned vertex). Used for large benchmark graphs (Fig. 6) and as
  the oracle for the JAX version.
* ``hicut_jax`` — fixed-shape jit-able version operating on a masked dense
  adjacency matrix (the :class:`~repro.core.dynamic_graph.GraphState`
  layout). BFS layers are frontier masks; the layer-boundary decision logic
  (lines 20–36) is branchless ``jnp.where``. One ``lax.while_loop`` per
  LayerCut, driven by a ``lax.fori_loop`` over seed vertices.

Semantics notes (faithful to the pseudocode, documented where it is loose):

* ``d_n`` counts, for every vertex of the current BFS layer, its incident
  edges toward vertices not yet in any subgraph (intra-layer edges therefore
  count twice — once per endpoint — exactly as the ref loop does).
* A layer where ``d_n < d_{n-1}`` becomes the cut candidate ``V_seg``; its
  vertices stay *uncommitted* until either associations strengthen again
  (``d_{n-1} ≤ d_n`` with non-empty ``V_seg`` and strict increase → commit
  ``V_seg`` and cut, line 28–29) or the frontier dies (``d_n == 0`` → commit
  ``V_seg`` ∪ current layer, line 22–23).
* On equality (``d_{n-1} == d_n``) with a pending ``V_seg`` the pseudocode
  commits only the current layer and leaves ``V_seg`` pending; we reproduce
  that verbatim.
* Vertices left pending when the queue empties are *not* committed; they
  seed later LayerCut calls (outer loop, lines 2–4), so every active vertex
  still ends in exactly one subgraph.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# reference implementation (Algorithm 1, numpy / adjacency lists)
# ---------------------------------------------------------------------------

def _adjacency_lists(n: int, edges: np.ndarray) -> list[np.ndarray]:
    nbrs: list[list[int]] = [[] for _ in range(n)]
    for i, j in edges:
        nbrs[i].append(j)
        nbrs[j].append(i)
    return [np.array(sorted(x), np.int64) for x in nbrs]


def hicut_ref(n: int, edges: np.ndarray,
              active: np.ndarray | None = None) -> np.ndarray:
    """Run Algorithm 1. Returns [n] int64 subgraph ids (−1 for inactive)."""
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    nbrs = _adjacency_lists(n, edges)
    active = np.ones(n, bool) if active is None else np.asarray(active, bool)
    assigned = np.full(n, -1, np.int64)   # membership in G_sub
    sub_id = 0

    def layer_cut(v_begin: int, sid: int) -> None:
        # line 8: initialize variables
        from collections import deque
        q = deque([v_begin])
        visited = np.zeros(n, bool)
        visited[v_begin] = True
        assigned[v_begin] = sid                     # line 9: V_begin → G_subc
        n_cur, l_cur = 1, 1
        v_cur: list[int] = []
        v_seg: list[int] = []
        d_prev = d_n = 0
        while q:                                    # line 11
            vc = q.popleft()                        # lines 12-14
            v_cur.append(vc)
            n_cur -= 1
            for vr in nbrs[vc]:                     # line 15
                if active[vr] and assigned[vr] < 0:  # line 16: not in G_sub
                    d_n += 1                        # line 17
                    if not visited[vr]:             # line 18
                        visited[vr] = True
                        q.append(vr)                # line 19
            if n_cur == 0:                          # line 20: layer boundary
                n_cur = len(q)                      # line 21
                if d_n == 0:                        # lines 22-23
                    for v in v_seg + v_cur:
                        assigned[v] = sid
                    return
                if l_cur == 1:                      # lines 24-25
                    d_prev = d_n
                else:
                    if d_prev <= d_n:               # line 27
                        if v_seg and d_prev < d_n:  # lines 28-29: cut here
                            for v in v_seg:
                                assigned[v] = sid
                            return
                        d_prev = d_n                # line 31
                        for v in v_cur:
                            assigned[v] = sid
                    else:                           # line 32: d_prev > d_n
                        for v in v_seg:             # lines 33-34
                            assigned[v] = sid
                        v_seg = list(v_cur)         # line 35
                        d_prev = d_n                # line 36
                l_cur += 1                          # line 37
                v_cur = []
                d_n = 0

    for v in range(n):                              # lines 2-4
        if active[v] and assigned[v] < 0:
            layer_cut(v, sub_id)
            sub_id += 1
    return assigned


# ---------------------------------------------------------------------------
# JAX implementation (fixed shape, jit-able)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=())
def hicut_jax(adj: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Fixed-shape HiCut. adj [N,N] {0,1} symmetric, mask [N] {0,1}.

    Returns [N] int32 subgraph ids (−1 for masked-out vertices). Matches
    ``hicut_ref`` exactly (tested property-wise and pointwise).
    """
    n = adj.shape[0]
    adjb = (adj > 0) & (mask[:, None] > 0) & (mask[None, :] > 0)

    def layer_cut(assigned, seed, sid):
        frontier = jnp.zeros(n, bool).at[seed].set(True)
        visited = frontier
        assigned = jnp.where(frontier, sid, assigned)      # line 9
        vseg = jnp.zeros(n, bool)
        # carry: (assigned, frontier, visited, vseg, d_prev, l_cur, done)
        def cond(c):
            _, frontier, _, _, _, l_cur, done = c
            return (~done) & jnp.any(frontier) & (l_cur <= n)

        def body(c):
            assigned, frontier, visited, vseg, d_prev, l_cur, done = c
            unassigned = (assigned < 0) & (mask > 0)
            # d_n: edges from current layer to not-in-G_sub vertices
            d_n = jnp.sum(jnp.where(frontier[:, None] & adjb
                                    & unassigned[None, :], 1, 0))
            nxt = (adjb.T @ frontier.astype(jnp.int32) > 0)
            nxt = nxt & unassigned & ~visited              # lines 16-19
            first = l_cur == 1
            zero = d_n == 0
            inc = (~first) & (d_prev <= d_n)
            cut_now = inc & jnp.any(vseg) & (d_prev < d_n)  # lines 28-29
            dec = (~first) & (d_prev > d_n)
            # lines 22-23: commit vseg ∪ current layer, exit
            commit_zero = jnp.where(zero, vseg | frontier, False)
            # lines 28-29: commit vseg, exit (only if not zero-case)
            commit_cut = jnp.where(cut_now & ~zero, vseg, False)
            # line 31: commit current layer, continue
            commit_inc = jnp.where(inc & ~cut_now & ~zero, frontier, False)
            # lines 33-34: commit pending vseg, continue (vseg := layer)
            commit_dec = jnp.where(dec & ~zero, vseg, False)
            commit = commit_zero | commit_cut | commit_inc | commit_dec
            assigned = jnp.where(commit, sid, assigned)
            exit_now = zero | (cut_now & ~zero)
            vseg = jnp.where(dec & ~zero & ~exit_now, frontier,
                             jnp.where(commit_cut.any() | zero,
                                       jnp.zeros(n, bool), vseg))
            d_prev = jnp.where(first | inc | dec, d_n, d_prev)
            visited = visited | nxt
            frontier = jnp.where(exit_now, jnp.zeros(n, bool), nxt)
            return (assigned, frontier, visited, vseg, d_prev, l_cur + 1,
                    done | exit_now)

        init = (assigned, frontier, visited, vseg, jnp.zeros((), jnp.int32),
                jnp.ones((), jnp.int32), jnp.zeros((), bool))
        out = jax.lax.while_loop(cond, body, init)
        return out[0]

    def outer(i, carry):
        assigned, sid = carry
        todo = (assigned[i] < 0) & (mask[i] > 0)
        assigned = jax.lax.cond(
            todo, lambda a: layer_cut(a, i, sid), lambda a: a, assigned)
        return assigned, sid + jnp.where(todo, 1, 0)

    assigned0 = jnp.full(n, -1, jnp.int32)
    assigned, _ = jax.lax.fori_loop(0, n, outer, (assigned0,
                                                  jnp.zeros((), jnp.int32)))
    return assigned


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def cut_metrics(n: int, edges: np.ndarray, assigned: np.ndarray) -> dict:
    """Partition quality: cross-subgraph edge count / fraction, #subgraphs."""
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    a = np.asarray(assigned)
    valid = (a[edges[:, 0]] >= 0) & (a[edges[:, 1]] >= 0)
    e = edges[valid]
    cross = int(np.sum(a[e[:, 0]] != a[e[:, 1]]))
    ids = np.unique(a[a >= 0])
    sizes = np.array([(a == s).sum() for s in ids])
    return {
        "num_subgraphs": int(len(ids)),
        "cross_edges": cross,
        "total_edges": int(len(e)),
        "cut_fraction": cross / max(len(e), 1),
        "mean_subgraph_size": float(sizes.mean()) if len(sizes) else 0.0,
        "max_subgraph_size": int(sizes.max()) if len(sizes) else 0,
    }
