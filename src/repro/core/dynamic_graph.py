"""Dynamic graph model (paper §3.2).

The EC controller perceives the user topology as a graph layout
``G(t) = (V(t), E(t))``. Users have three kinds of dynamics: position
changes, count changes (join/leave), association changes. Following the
paper, the layout has a fixed capacity ``N`` with a **mask module** (an
array of length N, 1 = active user) plus per-vertex **position attributes**;
leaving users zero their mask slot and drop their edges, joining users
re-activate zeroed slots.

Everything is fixed-shape jnp, so the whole perceive → HiCut → offload
pipeline can live under jit.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class GraphState(NamedTuple):
    """Graph layout G(t) with the paper's mask/position extensions."""
    mask: jnp.ndarray      # [N] f32 in {0,1}; the paper's mask module
    pos: jnp.ndarray       # [N, 2] f32; user coordinates (x_i(t), y_i(t))
    adj: jnp.ndarray       # [N, N] f32 in {0,1}; e_ij, symmetric, no self-loops
    task_kb: jnp.ndarray   # [N] f32; task data size X_i(t) in kilobits

    @property
    def capacity(self) -> int:
        return self.mask.shape[0]

    def num_active(self) -> jnp.ndarray:
        return jnp.sum(self.mask)

    def degrees(self) -> jnp.ndarray:
        """|N_i(t)|: number of active neighbors of each active user."""
        return (self.adj @ self.mask) * self.mask


def _symmetrize(adj: jnp.ndarray) -> jnp.ndarray:
    adj = jnp.maximum(adj, adj.T)
    n = adj.shape[0]
    return adj * (1.0 - jnp.eye(n, dtype=adj.dtype))


def _apply_mask(state: GraphState) -> GraphState:
    """Drop edges incident to inactive vertices (paper: 'their associations
    with other vertices will be removed')."""
    m = state.mask
    adj = state.adj * m[:, None] * m[None, :]
    return state._replace(adj=adj, task_kb=state.task_kb * m)


def make_graph_state(capacity: int, positions, edges, task_kb,
                     active: int | None = None) -> GraphState:
    """Build a GraphState from numpy inputs, padding to ``capacity``."""
    positions = np.asarray(positions, np.float32)
    n = positions.shape[0]
    active = n if active is None else active
    assert n <= capacity
    mask = np.zeros(capacity, np.float32)
    mask[:active] = 1.0
    pos = np.zeros((capacity, 2), np.float32)
    pos[:n] = positions
    adj = np.zeros((capacity, capacity), np.float32)
    for i, j in np.asarray(edges, np.int64).reshape(-1, 2):
        if i != j:
            adj[i, j] = adj[j, i] = 1.0
    kb = np.zeros(capacity, np.float32)
    kb[:n] = np.asarray(task_kb, np.float32)
    state = GraphState(jnp.asarray(mask), jnp.asarray(pos), jnp.asarray(adj),
                       jnp.asarray(kb))
    return _apply_mask(state)


# ---------------------------------------------------------------------------
# dynamic events (all jit-able, fixed shape)
# ---------------------------------------------------------------------------

def move_users(state: GraphState, new_pos: jnp.ndarray) -> GraphState:
    """Position change: sync vertex position attributes to user locations."""
    pos = jnp.where(state.mask[:, None] > 0, new_pos, state.pos)
    return state._replace(pos=pos)


def remove_users(state: GraphState, drop: jnp.ndarray) -> GraphState:
    """drop: [N] {0,1}. Mask slots go to 0 and their edges are removed."""
    mask = state.mask * (1.0 - drop)
    return _apply_mask(state._replace(mask=mask))


def add_users(state: GraphState, add: jnp.ndarray, pos: jnp.ndarray,
              task_kb: jnp.ndarray, adj_new: jnp.ndarray) -> GraphState:
    """add: [N] {0,1} marks previously-inactive slots to activate; new
    vertices take the given positions / task sizes / association rows."""
    add = add * (1.0 - state.mask)                 # only inactive slots
    mask = jnp.clip(state.mask + add, 0.0, 1.0)
    posn = jnp.where(add[:, None] > 0, pos, state.pos)
    kb = jnp.where(add > 0, task_kb, state.task_kb)
    touched = jnp.maximum(add[:, None], add[None, :])
    adj = jnp.where(touched > 0, _symmetrize(adj_new), state.adj)
    return _apply_mask(GraphState(mask, posn, adj, kb))


def rewire(state: GraphState, adj_new: jnp.ndarray) -> GraphState:
    """Association change: replace E with new edges (masked + symmetrized)."""
    return _apply_mask(state._replace(adj=_symmetrize(adj_new)))


# ---------------------------------------------------------------------------
# random scenario / event sampling (numpy; drives training + benchmarks)
# ---------------------------------------------------------------------------

def random_scenario(rng: np.random.Generator, capacity: int, n_users: int,
                    n_assoc: int, plane: float = 2000.0,
                    task_kb_range=(500.0, 1500.0)) -> GraphState:
    """Random EC scenario on a plane×plane area (paper §6.1: 2000m×2000m)."""
    pos = rng.uniform(0, plane, size=(n_users, 2))
    max_e = n_users * (n_users - 1) // 2
    n_assoc = min(n_assoc, max_e)
    have: set[tuple[int, int]] = set()
    while len(have) < n_assoc:
        i, j = rng.integers(n_users), rng.integers(n_users)
        if i != j:
            have.add((min(i, j), max(i, j)))
    edges = np.array(sorted(have), np.int64) if have else np.zeros((0, 2),
                                                                   np.int64)
    kb = rng.uniform(*task_kb_range, size=n_users)
    return make_graph_state(capacity, pos, edges, kb, active=n_users)


def _attach_new_users(rng: np.random.Generator, state: GraphState,
                      grow: np.ndarray, plane: float = 2000.0,
                      friends: int = 3,
                      task_kb_range=(500.0, 1500.0)) -> GraphState:
    """Activate the slots marked in ``grow`` [N] {0,1}: uniform positions,
    task sizes from ``task_kb_range``, and ≤``friends`` random associations
    to already-active (or co-arriving) users. Shared by
    :func:`perturb_scenario` and :func:`arrival_wave`."""
    n = state.capacity
    pos = rng.uniform(0, plane, (n, 2)).astype(np.float32)
    kb = rng.uniform(*task_kb_range, n).astype(np.float32)
    adj = np.asarray(state.adj).copy()
    active = np.asarray(state.mask) + grow
    for i in np.nonzero(grow)[0]:
        cand = np.nonzero(active)[0]
        cand = cand[cand != i]
        if len(cand):
            pick = rng.choice(cand, size=min(friends, len(cand)),
                              replace=False)
            adj[i, pick] = adj[pick, i] = 1.0
    return add_users(state, jnp.asarray(grow), jnp.asarray(pos),
                     jnp.asarray(kb), jnp.asarray(adj))


def perturb_scenario(rng: np.random.Generator, state: GraphState,
                     change_rate: float = 0.2,
                     plane: float = 2000.0) -> GraphState:
    """Paper Fig. 4/§6.4: each episode randomly changes user count,
    associations and positions (default 20% change rate)."""
    n = state.capacity
    mask = np.asarray(state.mask)
    # positions: all users drift
    new_pos = np.asarray(state.pos) + rng.normal(0, 0.05 * plane, (n, 2))
    state = move_users(state, jnp.asarray(
        np.clip(new_pos, 0, plane).astype(np.float32)))
    # membership: flip ~change_rate of slots
    flips = rng.random(n) < change_rate * 0.5
    drop = (flips & (mask > 0)).astype(np.float32)
    state = remove_users(state, jnp.asarray(drop))
    grow = (flips & (mask == 0)).astype(np.float32)
    if grow.any():
        state = _attach_new_users(rng, state, grow, plane=plane)
    # associations: rewire ~change_rate of edges among active users
    adj = np.asarray(state.adj).copy()
    mask = np.asarray(state.mask)
    act = np.nonzero(mask)[0]
    if len(act) >= 2:
        e_idx = np.transpose(np.nonzero(np.triu(adj)))
        for i, j in e_idx:
            if rng.random() < change_rate:
                adj[i, j] = adj[j, i] = 0.0
                a, b = rng.choice(act, 2, replace=False)
                adj[a, b] = adj[b, a] = 1.0
    return rewire(state, jnp.asarray(adj.astype(np.float32)))


# ---------------------------------------------------------------------------
# event stream (user churn waves + server health; drives fault injection)
# ---------------------------------------------------------------------------

EVENT_ARRIVE = "arrive"
EVENT_DEPART = "depart"
EVENT_SERVER_DOWN = "server_down"
EVENT_SERVER_UP = "server_up"
EVENT_DEGRADE = "degrade"
USER_EVENTS = (EVENT_ARRIVE, EVENT_DEPART)
SERVER_EVENTS = (EVENT_SERVER_DOWN, EVENT_SERVER_UP, EVENT_DEGRADE)
EVENT_KINDS = USER_EVENTS + SERVER_EVENTS


class GraphEvent(NamedTuple):
    """One timed event in a fault/churn schedule (DESIGN.md §9).

    ``cycle`` is a logical clock tick (a frontend pump cycle or an engine
    request index). User events carry ``count`` (wave size); server events
    carry ``server`` (id) and, for ``degrade``, ``scale`` — the factor
    applied to the server's capacity/compute (energy is scaled by 1/scale,
    see ``repro.serve.faults``)."""
    cycle: int
    kind: str
    count: int = 0
    server: int = -1
    scale: float = 1.0


def arrival_wave(rng: np.random.Generator, state: GraphState, count: int,
                 plane: float = 2000.0, friends: int = 3,
                 task_kb_range=(500.0, 1500.0)) -> GraphState:
    """Activate up to ``count`` inactive slots as newly-arrived users
    (uniform positions, ≤``friends`` random associations each)."""
    mask = np.asarray(state.mask)
    free = np.nonzero(mask == 0)[0]
    if len(free) == 0 or count <= 0:
        return state
    pick = rng.choice(free, size=min(count, len(free)), replace=False)
    grow = np.zeros(state.capacity, np.float32)
    grow[pick] = 1.0
    return _attach_new_users(rng, state, grow, plane=plane, friends=friends,
                             task_kb_range=task_kb_range)


def departure_wave(rng: np.random.Generator, state: GraphState,
                   count: int) -> GraphState:
    """Deactivate up to ``count`` random active users (edges dropped)."""
    act = np.nonzero(np.asarray(state.mask) > 0)[0]
    if len(act) == 0 or count <= 0:
        return state
    pick = rng.choice(act, size=min(count, len(act)), replace=False)
    drop = np.zeros(state.capacity, np.float32)
    drop[pick] = 1.0
    return remove_users(state, jnp.asarray(drop))


def apply_user_event(rng: np.random.Generator, state: GraphState,
                     event: GraphEvent) -> GraphState:
    """Apply one user-churn event; server events pass through unchanged."""
    if event.kind == EVENT_ARRIVE:
        return arrival_wave(rng, state, event.count)
    if event.kind == EVENT_DEPART:
        return departure_wave(rng, state, event.count)
    return state
