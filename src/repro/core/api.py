"""GraphEdge control-plane API: pluggable perceive → partition → offload → serve.

The paper's architecture (Figs. 1–2) is a single control loop — perceive the
user topology, cut it with HiCut (§4), offload with DRLGO (§5), serve the
distributed GNN inference and account the exact system cost (Eqs. 12–14).
This module exposes that loop behind three swappable pieces:

* :class:`Partitioner` — ``partition(state) -> Partition``; implementations
  are registered by name (``hicut_jax`` [default, jit-able], ``hicut_ref``,
  ``mincut``, ``multilevel``, ``multilevel_jax``, ``none``) and selected
  with :func:`get_partitioner`. Partitioners whose cut is a pure jnp
  function additionally satisfy :class:`JitPartitioner`
  (``cut(state) -> [N] i32``) and power the end-to-end jitted step.
* :class:`OffloadPolicy` — ``policy(env) -> Assignment``; registered names
  are ``drlgo``, ``ppo``, ``greedy``, ``random``, ``local``, plus the
  pure-jnp ``greedy_jit`` / ``local_jit`` / ``lyapunov``
  (:func:`get_offload_policy`).
* :class:`JitPolicy` — the protocol extension for policies whose decision
  rule is a pure jnp function over an
  :class:`~repro.core.offload.batched_env.EnvScene`
  (``decide(scene) -> (assign, reward)``). For these the controller skips
  the per-user numpy env entirely: ``step()`` runs one jitted
  ``scene → offload → exact cost`` call, and :meth:`GraphEdgeController.
  jit_step_fn` closes the loop end to end (HiCut partition included) as a
  pure function usable inside ``jax.jit`` / ``lax.scan``.
* :class:`GraphEdgeController` — composes the two. ``step(state)`` runs one
  control step and returns a :class:`Decision` carrying the assignment, the
  partition and the full :class:`~repro.core.costs.SystemCost`; ``rollout``
  drives multiple steps through the dynamic-graph event model (§3.2).
  Partitions are cached across steps whose topology (mask + adjacency) is
  unchanged — a bounded LRU keyed by :func:`topology_key`, so pure mobility
  steps never re-run the cut and long dynamic rollouts cannot grow the
  cache without limit (``cache_info()`` reports hits/misses/size).

For training-scale workloads, :meth:`GraphEdgeController.make_batched_env`
stacks B scenarios into one vmapped
:class:`~repro.core.offload.batched_env.BatchedOffloadEnv` with the
controller's partitioner and reward constants (DESIGN.md §3).

A :class:`Decision` bridges directly into serving:
``decision.to_partition_plan(P)`` feeds
:func:`repro.gnn.distributed.make_partition_plan` →
:func:`~repro.gnn.distributed.distributed_gcn_forward`
(see ``repro.launch.serve_gnn`` and DESIGN.md for the full data path).

Registries are plain dicts of factories; third-party strategies plug in with
:func:`register_partitioner` / :func:`register_offload_policy`.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs
from repro.core.dynamic_graph import GraphState, perturb_scenario
from repro.core.hicut import cut_metrics, hicut_jax, hicut_ref
from repro.core.offload.batched_env import (BatchedOffloadEnv, EnvScene,
                                            _scene_core, stack_states)
from repro.core.offload.env import OffloadEnv


def state_edges(state: GraphState) -> np.ndarray:
    """Upper-triangular edge list [(i, j)] of the (masked) layout G(t).

    One pass over the dense layout (GraphState stores adj dense), but no
    N×N temporary — the old ``np.triu`` copy doubled peak memory."""
    i, j = np.nonzero(np.asarray(state.adj))
    keep = i < j
    return np.stack([i[keep], j[keep]], axis=1)


def topology_key(state: GraphState) -> str:
    """Topology fingerprint: hash of (capacity, mask, sorted edge list).

    Keyed off the edge list rather than the dense adjacency bytes: the
    hashed payload scales with E, not N² (the scan over GraphState's dense
    adj is unavoidable, but allocates only O(E)), and sparse- and dense-
    derived layouts of the same graph share cache entries. ``state_edges``
    emits edges in sorted (row-major upper-triangular) order, making the
    key canonical."""
    edges = np.ascontiguousarray(state_edges(state), np.int64)
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(state.capacity).tobytes())
    h.update(np.asarray(state.mask, np.float32).tobytes())
    h.update(edges.tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# partitioning (subproblem P1)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Partition:
    """Result of graph-layout optimization: vertex → subgraph ids."""
    subgraph: np.ndarray          # [N] int64 subgraph id (−1 = inactive)
    method: str                   # registry name that produced it
    cut_metrics: dict = field(default_factory=dict)

    @property
    def num_subgraphs(self) -> int:
        ids = self.subgraph[self.subgraph >= 0]
        return int(len(np.unique(ids)))

    def to_device_assignment(self, num_devices: int) -> np.ndarray:
        """Subgraph ids → device/server ids (id mod P; −1 preserved)."""
        out = np.asarray(self.subgraph, np.int64).copy()
        out[out >= 0] %= num_devices
        return out


@runtime_checkable
class Partitioner(Protocol):
    """Graph-layout optimizer: ``G(t) → G_sub`` (paper §4, P1)."""
    name: str

    def __call__(self, state: GraphState) -> Partition: ...


@runtime_checkable
class JitPartitioner(Protocol):
    """Partitioner whose cut is a *pure jnp* function of the layout.

    ``cut(state) -> [N] int32`` must be traceable (no numpy, no host
    round-trips) so :meth:`GraphEdgeController.jit_step_fn` can close it
    into the end-to-end jitted ``partition → offload → cost`` step.
    Implementations keep the plain ``__call__(state) -> Partition``
    surface for every eager caller. The mirror of :class:`JitPolicy` on
    the partition side: ``hicut_jax``, ``none`` and ``multilevel_jax``
    satisfy it (DESIGN.md §6 walks through adding another).
    """
    name: str

    def cut(self, state: GraphState) -> jnp.ndarray: ...


_PARTITIONERS: dict[str, Callable[..., Partitioner]] = {}


def register_partitioner(name: str):
    """Register a partitioner factory under ``name`` (decorator)."""
    def deco(factory: Callable[..., Partitioner]):
        _PARTITIONERS[name] = factory
        return factory
    return deco


def available_partitioners() -> tuple[str, ...]:
    return tuple(sorted(_PARTITIONERS))


def get_partitioner(name: str, **kwargs: Any) -> Partitioner:
    """Instantiate a registered partitioner by name."""
    try:
        factory = _PARTITIONERS[name]
    except KeyError:
        raise ValueError(f"unknown partitioner {name!r}; available: "
                         f"{available_partitioners()}") from None
    return factory(**kwargs)


def _finish(state: GraphState, assigned: np.ndarray, method: str) -> Partition:
    assigned = np.asarray(assigned, np.int64)
    metrics = cut_metrics(state.capacity, state_edges(state), assigned)
    return Partition(assigned, method, metrics)


@register_partitioner("hicut_jax")
class _HiCutJax:
    """Fixed-shape jit-able HiCut (Algorithm 1) on the masked dense layout."""
    name = "hicut_jax"

    def __call__(self, state: GraphState) -> Partition:
        assigned = np.asarray(self.cut(state))
        return _finish(state, assigned, self.name)

    def cut(self, state: GraphState) -> jnp.ndarray:
        return hicut_jax(state.adj, state.mask)


@register_partitioner("hicut_ref")
class _HiCutRef:
    """Numpy adjacency-list transcription of Algorithm 1 (the oracle)."""
    name = "hicut_ref"

    def __call__(self, state: GraphState) -> Partition:
        active = np.asarray(state.mask) > 0
        assigned = hicut_ref(state.capacity, state_edges(state), active=active)
        return _finish(state, assigned, self.name)


@register_partitioner("mincut")
class _MinCut:
    """Iterated pairwise max-flow min-cut baseline (Zeng et al. [36])."""
    name = "mincut"

    def __init__(self, num_parts: int = 4, seed: int = 0,
                 weight_range: tuple[int, int] = (1, 100)):
        self.num_parts = num_parts
        self.seed = seed
        self.weight_range = weight_range

    def __call__(self, state: GraphState) -> Partition:
        from repro.core.mincut_baseline import mincut_partition_state
        assigned = mincut_partition_state(state, self.num_parts,
                                          seed=self.seed,
                                          weight_range=self.weight_range)
        return _finish(state, assigned, self.name)


@register_partitioner("none")
class _NoPartition:
    """Every active vertex its own subgraph — the DRL-only ablation (Fig 12)."""
    name = "none"

    def __call__(self, state: GraphState) -> Partition:
        assigned = np.arange(state.capacity, dtype=np.int64)
        assigned[np.asarray(state.mask) <= 0] = -1
        return _finish(state, assigned, self.name)

    def cut(self, state: GraphState) -> jnp.ndarray:
        return jnp.where(state.mask > 0,
                         jnp.arange(state.mask.shape[0], dtype=jnp.int32),
                         -1)


@register_partitioner("multilevel")
class _Multilevel:
    """METIS-style multilevel k-way cut: heavy-edge-matching coarsening,
    greedy balanced initial partition, boundary KL refinement
    (repro.core.multilevel; the Zeng et al. arXiv:2210.17281 family)."""
    name = "multilevel"

    def __init__(self, num_parts: int = 4, coarsen_to: int | None = None,
                 sweeps: int = 4, imbalance: float = 1.1):
        self.num_parts = num_parts
        self.coarsen_to = coarsen_to
        self.sweeps = sweeps
        self.imbalance = imbalance

    def __call__(self, state: GraphState) -> Partition:
        from repro.core.multilevel import multilevel_partition_state
        assigned = multilevel_partition_state(
            state, self.num_parts, coarsen_to=self.coarsen_to,
            sweeps=self.sweeps, imbalance=self.imbalance)
        return _finish(state, assigned, self.name)


@register_partitioner("multilevel_jax")
class _MultilevelJax:
    """Fixed-shape jnp refinement stage of the multilevel pipeline — a
    :class:`JitPartitioner`, so it also runs inside ``jit_step_fn()``."""
    name = "multilevel_jax"

    def __init__(self, num_parts: int = 4, moves: int | None = None,
                 imbalance: float = 1.1):
        self.num_parts = num_parts
        self.moves = moves                 # None → 2·capacity at call time
        self.imbalance = imbalance

    def _moves(self, state: GraphState) -> int:
        return 2 * state.capacity if self.moves is None else int(self.moves)

    def cut(self, state: GraphState) -> jnp.ndarray:
        from repro.core.multilevel import multilevel_jax
        return multilevel_jax(state.adj, state.mask, self.num_parts,
                              self._moves(state), self.imbalance)

    def __call__(self, state: GraphState) -> Partition:
        return _finish(state, np.asarray(self.cut(state)), self.name)


# ---------------------------------------------------------------------------
# offloading (subproblem P2)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Assignment:
    """Graph offloading decision w: user → edge server (C1 holds)."""
    servers: np.ndarray           # [N] int64 server id (−1 = inactive)
    reward: float = 0.0           # Σ per-step rewards (Eq. 23)
    stats: dict = field(default_factory=dict)

    def onehot(self, m: int) -> jnp.ndarray:
        return costs.assignment_onehot(jnp.asarray(self.servers), m)


@runtime_checkable
class OffloadPolicy(Protocol):
    """Task scheduler: rolls an :class:`OffloadEnv` episode → Assignment."""
    name: str

    def __call__(self, env: OffloadEnv) -> Assignment: ...


_POLICIES: dict[str, Callable[..., OffloadPolicy]] = {}


def register_offload_policy(name: str):
    def deco(factory: Callable[..., OffloadPolicy]):
        _POLICIES[name] = factory
        return factory
    return deco


def available_offload_policies() -> tuple[str, ...]:
    return tuple(sorted(_POLICIES))


def get_offload_policy(name: str, **kwargs: Any) -> OffloadPolicy:
    """Instantiate a registered offloading policy by name."""
    try:
        factory = _POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown offload policy {name!r}; available: "
                         f"{available_offload_policies()}") from None
    return factory(**kwargs)


def _episode_assignment(env: OffloadEnv, stats: dict, name: str) -> Assignment:
    return Assignment(env.assign.copy(), float(stats.get("reward", 0.0)),
                      dict(stats))


@register_offload_policy("greedy")
class _Greedy:
    """GM: each user to the nearest non-full edge server (§6.1)."""
    name = "greedy"

    def __call__(self, env: OffloadEnv) -> Assignment:
        from repro.core.offload.baselines import run_greedy
        return _episode_assignment(env, run_greedy(env), self.name)


@register_offload_policy("random")
class _Random:
    """RM: each user to a uniformly random server (§6.1)."""
    name = "random"

    def __init__(self, seed: int = 0):
        self.seed = seed

    def __call__(self, env: OffloadEnv) -> Assignment:
        from repro.core.offload.baselines import run_random
        return _episode_assignment(env, run_random(env, seed=self.seed),
                                   self.name)


@register_offload_policy("local")
class _Local:
    """LM: each user to its geographically nearest server, ignoring load."""
    name = "local"

    def __call__(self, env: OffloadEnv) -> Assignment:
        from repro.core.offload.baselines import run_local
        return _episode_assignment(env, run_local(env), self.name)


@register_offload_policy("drlgo")
class _DRLGO:
    """The paper's MADDPG policy; wraps a (trained) DRLGOTrainer's actors."""
    name = "drlgo"

    def __init__(self, trainer):
        self.trainer = trainer

    def __call__(self, env: OffloadEnv) -> Assignment:
        stats = self.trainer.run_episode(env, explore=False, learn=False)
        return _episode_assignment(env, stats, self.name)


@runtime_checkable
class JitPolicy(Protocol):
    """Offload policy whose decision rule is a *pure jnp* episode rollout.

    ``decide`` must be traceable (an :class:`EnvScene` in, the final
    ``(assign [N] i32, Σreward)`` out) and hashable-stable (a module-level
    function, not a per-instance closure) so the controller can close it
    into one jitted ``scene → offload → cost`` step. Implementations also
    keep the plain ``OffloadPolicy`` ``__call__(env)`` surface so every
    existing env-driven caller (benchmarks, trainers) works unchanged.
    """
    name: str

    def decide(self, scene: EnvScene) -> tuple[jnp.ndarray, jnp.ndarray]: ...


@partial(jax.jit, static_argnames=("decide", "gnn", "m"))
def _jit_offload_and_cost(net: costs.EdgeNetwork, state: GraphState,
                          subgraph: jnp.ndarray, zeta_sp, sub_w, cost_scale,
                          gnn: costs.GNNCostParams, decide, m: int):
    """The controller's jitted decision hot path: build the scene from the
    (already-partitioned) layout, roll the policy's scan, and account the
    exact Eqs. (12)–(14) cost — one XLA computation, zero numpy."""
    scene = _scene_core(net, state, subgraph, zeta_sp, sub_w, cost_scale,
                        gnn)
    assign, reward = decide(scene)
    w = costs.assignment_onehot(assign, m)
    return assign, reward, costs.system_cost(net, state, w, gnn)


@partial(jax.jit, static_argnames=("decide", "gnn", "m"))
def _jit_offload_and_cost_batch(net: costs.EdgeNetwork, states: GraphState,
                                subgraphs: jnp.ndarray, zeta_sp, sub_w,
                                cost_scale, gnn: costs.GNNCostParams, decide,
                                m: int):
    """Batched twin of :func:`_jit_offload_and_cost`: ``states`` is a
    stacked [B, ...] GraphState pytree (``batched_env.stack_states``) and
    ``subgraphs`` [B, N] i32. One vmapped XLA call builds all B
    :class:`EnvScene` pytrees, rolls the policy's decision scan per scene
    and accounts the exact Eqs. (12)–(14) cost — the whole scheduling
    cycle's control work in a single dispatch, no per-request host
    round-trips."""
    def one(state, subgraph):
        scene = _scene_core(net, state, subgraph, zeta_sp, sub_w,
                            cost_scale, gnn)
        assign, reward = decide(scene)
        w = costs.assignment_onehot(assign, m)
        return assign, reward, costs.system_cost(net, state, w, gnn)

    return jax.vmap(one)(states, subgraphs)


def _jit_decide(decide, net: costs.EdgeNetwork, state: GraphState, subgraph,
                zeta_sp, sub_w, cost_scale, gnn: costs.GNNCostParams,
                m: int) -> tuple[Assignment, costs.SystemCost]:
    """Run the jitted hot path and package the standard episode stats —
    the one place the (assignment, stats, cost) post-processing lives for
    both the ``__call__(env)`` surface and ``GraphEdgeController.step``."""
    assign, reward, sc = _jit_offload_and_cost(
        net, state, jnp.asarray(subgraph, jnp.int32), zeta_sp, sub_w,
        cost_scale, gnn, decide, m)
    stats = {"reward": float(reward), "system_cost": float(sc.c),
             "t_all": float(sc.t_all), "i_all": float(sc.i_all),
             "cross_bits": float(sc.cross_bits.sum())}
    return Assignment(np.asarray(assign, np.int64), float(reward), stats), sc


def _jit_policy_call(policy: JitPolicy, env: OffloadEnv) -> Assignment:
    """OffloadPolicy surface for jit policies: one jitted episode over the
    env's scenario (the env object is only read, never stepped)."""
    assignment, _ = _jit_decide(
        type(policy).decide, env.net, env.state, env.subgraph, env.zeta_sp,
        1.0 if env.use_subgraph_reward else 0.0, env.cost_scale, env.gnn,
        env.m)
    return assignment


@register_offload_policy("greedy_jit")
class _GreedyJit:
    """GM decision rule as a pure-jnp scan (zero numpy round-trips)."""
    name = "greedy_jit"

    @staticmethod
    def decide(scene: EnvScene):
        from repro.core.offload.baselines import greedy_rollout_jit
        return greedy_rollout_jit(scene)

    def __call__(self, env: OffloadEnv) -> Assignment:
        return _jit_policy_call(self, env)


@register_offload_policy("local_jit")
class _LocalJit:
    """LM decision rule as a pure-jnp scan (zero numpy round-trips)."""
    name = "local_jit"

    @staticmethod
    def decide(scene: EnvScene):
        from repro.core.offload.baselines import local_rollout_jit
        return local_rollout_jit(scene)

    def __call__(self, env: OffloadEnv) -> Assignment:
        return _jit_policy_call(self, env)


@register_offload_policy("lyapunov")
class _Lyapunov:
    """Queue-aware drift-plus-penalty scheduler (ACE-GNN-style system-aware
    scheduling): per-server virtual queues + marginal-cost penalty, rolled
    as one pure-jnp scan (repro.core.offload.lyapunov)."""
    name = "lyapunov"

    @staticmethod
    def decide(scene: EnvScene):
        from repro.core.offload.lyapunov import lyapunov_rollout_jit
        return lyapunov_rollout_jit(scene)

    def __call__(self, env: OffloadEnv) -> Assignment:
        return _jit_policy_call(self, env)


@register_offload_policy("ppo")
class _PPO:
    """PTOM baseline: single-agent PPO over the global state (§6.1)."""
    name = "ppo"

    def __init__(self, agent=None, seed: int = 0):
        self.agent = agent
        self.seed = seed

    def __call__(self, env: OffloadEnv) -> Assignment:
        if self.agent is None:        # lazily size the nets from the env
            from repro.core.offload.env import OBS_DIM
            from repro.core.offload.ppo import PPOConfig, PTOMAgent
            self.agent = PTOMAgent(PPOConfig(state_dim=env.m * OBS_DIM,
                                             n_actions=env.m), seed=self.seed)
        stats = self.agent.run_episode(env, learn=False, explore=False)
        return _episode_assignment(env, stats, self.name)


# ---------------------------------------------------------------------------
# the controller
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Decision:
    """One control step's output: who runs where, and what it costs."""
    state: GraphState
    partition: Partition
    assignment: Assignment
    cost: costs.SystemCost
    topo_key: str | None = None   # topology fingerprint (when cached)

    @property
    def servers(self) -> np.ndarray:
        return self.assignment.servers

    def to_partition_plan(self, num_devices: int | None = None,
                          exchange: str = "gather"):
        """Bridge into serving: decision → halo-exchange PartitionPlan.

        The offload assignment (user → server) becomes the vertex → device
        placement (server ids folded mod P when P differs from M), ready for
        :func:`repro.gnn.distributed.distributed_gcn_forward`. Plans are
        built through the sparse O(E) edge-list path — no N×N work — so
        serving stays viable at PubMed-scale layouts; the forward picks the
        gather aggregation automatically for such plans. ``exchange``
        selects the halo layout: ``"gather"`` (all_gather of each device's
        boundary union — the single-host default) or ``"pair"`` (all_to_all
        over exactly the cut edges — the multi-host wire format, see
        ``repro.gnn.multihost``)."""
        from repro.gnn.distributed import make_partition_plan_sparse
        m = int(np.asarray(self.cost.t_tran).shape[0])
        p = m if num_devices is None else num_devices
        assign = np.asarray(self.servers, np.int64).copy()
        assign[assign >= 0] %= p
        return make_partition_plan_sparse(state_edges(self.state), assign,
                                          p, n=self.state.capacity,
                                          exchange=exchange)

    def summary(self) -> dict:
        """Flat dict in the legacy ``GraphEdge.offload`` result format."""
        return {
            "assignment": self.servers.copy(),
            "subgraphs": self.partition.subgraph.copy(),
            "num_subgraphs": self.partition.num_subgraphs,
            "reward": self.assignment.reward,
            "system_cost": float(self.cost.c),
            "t_all": float(self.cost.t_all),
            "i_all": float(self.cost.i_all),
            "cross_bits": float(self.cost.cross_bits.sum()),
            **{k: v for k, v in self.assignment.stats.items()
               if k not in ("reward", "system_cost", "t_all", "i_all",
                            "cross_bits")},
        }


class CacheInfo(NamedTuple):
    """``functools.lru_cache``-style counters (partition + plan caches)."""
    hits: int
    misses: int
    maxsize: int
    currsize: int


class LruCache:
    """Tiny bounded LRU with hit/miss counters — shared by the controller's
    topology-keyed partition cache and the serving engine's plan cache."""

    def __init__(self, maxsize: int):
        self.maxsize = int(maxsize)
        self._data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        """Cached value (refreshing recency) or None; counts the lookup."""
        val = self._data.get(key)
        if val is None:
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return val

    def put(self, key, value) -> None:
        self._data[key] = value
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)          # evict LRU entry

    def __len__(self) -> int:
        return len(self._data)

    def clear(self) -> None:
        """Drop every entry (counters survive — they describe lookups,
        not contents). Used when cached values are invalidated wholesale,
        e.g. a fault event changes the effective server set."""
        self._data.clear()

    def info(self) -> CacheInfo:
        return CacheInfo(self.hits, self.misses, self.maxsize,
                         len(self._data))


class JitStepResult(NamedTuple):
    """All-jnp control-step output (the ``jit_step_fn`` return pytree)."""
    subgraph: jnp.ndarray         # [N] i32 — partition ids (−1 inactive)
    servers: jnp.ndarray          # [N] i32 — offload assignment (−1 inactive)
    reward: jnp.ndarray           # []  f32 — Σ per-step rewards (Eq. 23)
    cost: costs.SystemCost


@dataclass
class GraphEdgeController:
    """EC controller: perceive → partition → offload → account, pluggable.

    ``partitioner`` / ``policy`` accept either registry names or instances;
    kwargs for name-based construction go in ``partitioner_kwargs`` /
    ``policy_kwargs`` (e.g. ``policy="drlgo",
    policy_kwargs={"trainer": trainer}``).

    With a :class:`JitPolicy` (``greedy_jit`` / ``local_jit``), ``step()``
    runs the offload + cost accounting as a single jitted XLA call instead
    of walking the numpy env user by user; learned / numpy policies keep
    the env-stepping path. ``jit_step_fn()`` returns the fully-pure
    ``state → JitStepResult`` closure (partition included) for callers that
    put whole rollouts under ``jax.jit`` / ``lax.scan``.
    """
    net: costs.EdgeNetwork
    policy: OffloadPolicy | str = "greedy"
    partitioner: Partitioner | str = "hicut_jax"
    policy_kwargs: dict = field(default_factory=dict)
    partitioner_kwargs: dict = field(default_factory=dict)
    gnn: costs.GNNCostParams = field(default_factory=costs.GNNCostParams)
    zeta_sp: float = 0.1          # ζ (Eq. 25)
    cost_scale: float = 1.0       # reward normalizer
    use_subgraph_reward: bool | None = None   # None → auto (off for "none")
    cache_partitions: bool = True
    cache_size: int = 64          # LRU bound on distinct cached topologies

    def __post_init__(self):
        if isinstance(self.partitioner, str):
            self.partitioner = get_partitioner(self.partitioner,
                                               **self.partitioner_kwargs)
        if isinstance(self.policy, str):
            self.policy = get_offload_policy(self.policy,
                                             **self.policy_kwargs)
        if self.use_subgraph_reward is None:
            self.use_subgraph_reward = self.partitioner.name != "none"
        self._partition_cache = LruCache(self.cache_size)

    # -- perceive + partition (cached on topology) --------------------------
    def _partition_cached(self, state: GraphState
                          ) -> tuple[Partition, str | None]:
        """(partition, topology key) — key is None when caching is off."""
        if not self.cache_partitions:
            return self.partitioner(state), None
        key = topology_key(state)
        part = self._partition_cache.get(key)
        if part is None:
            part = self.partitioner(state)
            self._partition_cache.put(key, part)
        return part, key

    def partition(self, state: GraphState) -> Partition:
        """Run (or reuse) the partitioner. The cut depends only on the
        topology (mask + adjacency), so pure-mobility steps hit the cache —
        a bounded LRU (``cache_size`` entries) keyed by ``topology_key``."""
        return self._partition_cached(state)[0]

    def cache_info(self) -> CacheInfo:
        """Partition-cache counters (``functools.lru_cache`` convention)."""
        return self._partition_cache.info()

    def invalidate_partitions(self) -> None:
        """Flush the topology-keyed partition cache. Call when cached cuts
        stop being the ones you want for their topology — e.g. a fault
        event changed the live server count so re-cuts should target a
        different number of parts (DESIGN.md §9)."""
        self._partition_cache.clear()

    def recut_warm(self, state: GraphState, previous: np.ndarray,
                   num_parts: int | None = None, sweeps: int = 4,
                   imbalance: float = 1.1) -> Partition:
        """Warm-started multilevel re-cut seeded from ``previous`` (the
        last decision's subgraph ids for this topology) — the migration
        path after a fault event (DESIGN.md §9). Skips coarsening and the
        initial cut entirely: the previous assignment is projected onto
        ``num_parts`` parts (default: the number of distinct previous
        parts) and boundary-refined, so the re-cut costs one
        :func:`~repro.core.multilevel.refine` pass instead of a full
        pipeline. The result is installed in the partition cache under the
        state's topology key, so subsequent :meth:`step` calls on the same
        topology reuse it."""
        from repro.core.multilevel import multilevel_partition
        prev = np.asarray(previous, np.int64)
        if num_parts is None:
            live = np.unique(prev[prev >= 0])
            num_parts = max(1, len(live))
        assigned = multilevel_partition(
            state.capacity, state_edges(state), int(num_parts),
            active=np.asarray(state.mask) > 0, sweeps=sweeps,
            imbalance=imbalance, initial=prev)
        part = _finish(state, assigned, "multilevel_warm")
        if self.cache_partitions:
            self._partition_cache.put(topology_key(state), part)
        return part

    @property
    def cache_hits(self) -> int:
        return self._partition_cache.hits

    @property
    def cache_misses(self) -> int:
        return self._partition_cache.misses

    def make_env(self, state: GraphState,
                 partition: Partition | None = None) -> OffloadEnv:
        part = self.partition(state) if partition is None else partition
        return OffloadEnv(self.net, state, part, gnn=self.gnn,
                          zeta_sp=self.zeta_sp,
                          use_subgraph_reward=bool(self.use_subgraph_reward),
                          cost_scale=self.cost_scale)

    def make_batched_env(self, states: list[GraphState],
                         partitions: list[Partition] | None = None
                         ) -> BatchedOffloadEnv:
        """B scenarios (same capacity) → one vmapped
        :class:`~repro.core.offload.batched_env.BatchedOffloadEnv` with this
        controller's partitioner and reward constants. Used by the batched
        DRLGO/PTOM trainers; see DESIGN.md "Batched environment"."""
        if partitions is None:
            partitions = [self.partition(s) for s in states]
        return BatchedOffloadEnv.from_scenarios(
            self.net, states, partitions, gnn=self.gnn, zeta_sp=self.zeta_sp,
            use_subgraph_reward=bool(self.use_subgraph_reward),
            cost_scale=self.cost_scale)

    # -- one control step ----------------------------------------------------
    def step(self, state: GraphState) -> Decision:
        """Perceive → HiCut (or plug-in) → offload → exact cost accounting.

        :class:`JitPolicy` instances dispatch to one jitted
        ``scene → offload → cost`` XLA call (the partition still goes
        through the LRU cache); everything else steps the numpy env."""
        part, key = self._partition_cached(state)
        if isinstance(self.policy, JitPolicy):
            assignment, sc = _jit_decide(
                type(self.policy).decide, self.net, state, part.subgraph,
                self.zeta_sp, 1.0 if self.use_subgraph_reward else 0.0,
                self.cost_scale, self.gnn,
                int(self.net.server_pos.shape[0]))
            return Decision(state, part, assignment, sc, topo_key=key)
        env = self.make_env(state, part)
        assignment = self.policy(env)
        w = assignment.onehot(int(self.net.server_pos.shape[0]))
        sc = costs.system_cost(self.net, state, w, self.gnn)
        return Decision(state, part, assignment, sc, topo_key=key)

    def step_batch(self, states: list[GraphState]) -> list[Decision]:
        """Batched control step: B same-capacity layouts → B Decisions.

        The serving-tier hot path (ISSUE 8 / ROADMAP "batch the controller
        step too"): partitions are looked up per layout through the
        topology-keyed LRU exactly as in :meth:`step`, then the offload
        decision + exact cost for *all* B layouts runs as **one** vmapped
        jitted XLA call (:func:`_jit_offload_and_cost_batch`) instead of B
        sequential dispatches — the per-request decide cost is amortized
        across the whole scheduling cycle. Requires a :class:`JitPolicy`;
        other policies (and B = 1) fall back to per-state :meth:`step`.
        Results are positionally aligned with ``states``."""
        if not states:
            return []
        cap = states[0].capacity
        if len(states) == 1 or not isinstance(self.policy, JitPolicy) \
                or any(s.capacity != cap for s in states):
            return [self.step(s) for s in states]
        looked_up = [self._partition_cached(s) for s in states]
        parts = [p for p, _ in looked_up]
        subs = jnp.asarray(np.stack([np.asarray(p.subgraph, np.int32)
                                     for p in parts]))
        assign_b, reward_b, sc_b = _jit_offload_and_cost_batch(
            self.net, stack_states(list(states)), subs, self.zeta_sp,
            1.0 if self.use_subgraph_reward else 0.0, self.cost_scale,
            self.gnn, type(self.policy).decide,
            int(self.net.server_pos.shape[0]))
        # one host fetch for the whole batch, then pure numpy unpacking
        assign_np = np.asarray(assign_b, np.int64)
        reward_np = np.asarray(reward_b, np.float64)
        sc_np = jax.tree_util.tree_map(np.asarray, sc_b)
        decisions = []
        for b, (state, (part, key)) in enumerate(zip(states, looked_up)):
            sc = jax.tree_util.tree_map(lambda leaf: leaf[b], sc_np)
            stats = {"reward": float(reward_np[b]),
                     "system_cost": float(sc.c), "t_all": float(sc.t_all),
                     "i_all": float(sc.i_all),
                     "cross_bits": float(sc.cross_bits.sum())}
            assignment = Assignment(assign_np[b], float(reward_np[b]), stats)
            decisions.append(Decision(state, part, assignment, sc,
                                      topo_key=key))
        return decisions

    def jit_step_batch_fn(self) -> Callable[[GraphState], JitStepResult]:
        """Batched twin of :meth:`jit_step_fn`: a pure traceable closure
        over a **stacked** [B, ...] GraphState pytree
        (``batched_env.stack_states``) returning a stacked
        :class:`JitStepResult` — partition (re-cut inside the trace, like
        ``jit_step_fn``), offload scan and exact cost, vmapped so a whole
        scheduling cycle is one XLA computation. Same :class:`JitPolicy` /
        :class:`JitPartitioner` requirements as :meth:`jit_step_fn`."""
        return jax.vmap(self.jit_step_fn())

    def jit_step_fn(self) -> Callable[[GraphState], JitStepResult]:
        """Pure ``state → JitStepResult`` closure over this controller's
        network/constants: partition (a :class:`JitPartitioner`:
        ``hicut_jax``, ``none`` or ``multilevel_jax``) → jit-policy scan →
        exact Eqs. (12)–(14) cost. The
        returned function is traceable — wrap it in ``jax.jit`` or drive a
        whole rollout through ``lax.scan`` with zero host round-trips.
        (No partition caching: inside a trace every step re-cuts.)"""
        if not isinstance(self.policy, JitPolicy):
            raise TypeError(
                f"policy {self.policy.name!r} has no pure decide(); "
                f"jit_step_fn needs a JitPolicy "
                f"(e.g. greedy_jit/local_jit/lyapunov)")
        if not isinstance(self.partitioner, JitPartitioner):
            raise ValueError(
                f"partitioner {self.partitioner.name!r} is not jnp-pure; "
                f"jit_step_fn needs a JitPartitioner with a traceable "
                f"cut() (e.g. hicut_jax, none, multilevel_jax)")
        part_fn = self.partitioner.cut
        net, gnn = self.net, self.gnn
        zeta_sp, cost_scale = self.zeta_sp, self.cost_scale
        sub_w = 1.0 if self.use_subgraph_reward else 0.0
        decide = type(self.policy).decide
        m = int(net.server_pos.shape[0])

        def step_fn(state: GraphState) -> JitStepResult:
            subgraph = part_fn(state).astype(jnp.int32)
            scene = _scene_core(net, state, subgraph, zeta_sp, sub_w,
                                cost_scale, gnn)
            assign, reward = decide(scene)
            w = costs.assignment_onehot(assign, m)
            return JitStepResult(subgraph, assign, reward,
                                 costs.system_cost(net, state, w, gnn))
        return step_fn

    # -- multi-step control --------------------------------------------------
    def rollout(self, state: GraphState, steps: int,
                rng: np.random.Generator | None = None,
                change_rate: float = 0.2) -> list[Decision]:
        """Drive ``steps`` control steps through the dynamic-graph event
        model (§3.2 / §6.4): each step perturbs user count, positions and
        associations at ``change_rate``, then runs :meth:`step`."""
        rng = np.random.default_rng(0) if rng is None else rng
        decisions = []
        for _ in range(steps):
            state = perturb_scenario(rng, state, change_rate)
            decisions.append(self.step(state))
        return decisions
