"""GraphEdge system cost model (paper §3.3–3.5, Eqs. 3–14).

Single source of truth for every cost the paper defines; the DRLGO reward,
the benchmarks and the examples all call into here. All functions are pure
jnp over fixed shapes and jit-able.

Units (paper Table 2): distances m, bandwidth Hz, power W, task size kilobit,
energy J, time s. The paper's objective adds time and energy directly
(C = T_all + I_all); we keep optional weights (default 1,1) for ablations.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.dynamic_graph import GraphState

KB = 1e3  # bits per kilobit (paper: 'each dimension ... user data size 1 kb')


class EdgeNetwork(NamedTuple):
    """Static EC network ω: APs + co-located edge servers (paper §3.1)."""
    server_pos: jnp.ndarray   # [M, 2] m
    f_k: jnp.ndarray          # [M] Hz      — CPU cycles/s per unit data (Eq. 9)
    capacity: jnp.ndarray     # [M]         — max #users a server can host
    B_im: jnp.ndarray         # [N, M] Hz   — user↔AP bandwidth
    B_kl: jnp.ndarray         # [M, M] Hz   — server↔server bandwidth
    P_i: jnp.ndarray          # [N] W       — user transmit power
    P_k: jnp.ndarray          # [M] W       — server transmit power
    eta_kl: jnp.ndarray       # [M, M] {0,1} — server communication state η_kl
    sigma2: float             # W           — noise power σ²
    rho0: float               # channel gain at d0 = 1 m
    h0: float                 # server↔server channel gain
    zeta_im: float            # J/bit — unit upload energy ς_{i,m} (scalar or [M])
    zeta_kl: float            # J/bit — unit server-transfer energy ς_{k,l} (scalar or [M, M])


class GNNCostParams(NamedTuple):
    """GNN inference energy constants (paper Eqs. 10–11, Table 2).

    Note: Eq. (11)'s quadratic term ϑ·S_{κ-1}·S_κ is dimensionally
    inconsistent as printed (pJ/bit × bit²); we normalize the product by
    ``update_norm_bits`` (1 kb) so the update energy is ϑ·S_{κ-1}·S_κ/1kb —
    the only reading under which Table 2's constants give the
    method-separable cost curves the paper reports (Figs. 7–10)."""
    mu: float = 20e-12        # J/bit  — unit aggregation cost μ
    theta: float = 100e-12    # J/bit  — unit update cost ϑ
    phi: float = 50e-12       # J/bit  — unit activation-multiply cost φ
    layer_sizes_kb: tuple = (1500.0, 64.0, 8.0)  # S_0..S_F per-vertex feature kb
    update_norm_bits: float = 1e3


def default_network(rng: np.random.Generator, capacity_n: int, m: int = 4,
                    plane: float = 2000.0, mean_users: float | None = None,
                    ) -> EdgeNetwork:
    """Sample an EC network per paper §6.1 / Table 2.

    Service scope 500m×500m per server → M=4 on the 2000m plane by default;
    server capacities drawn from {5/4·Mean, Mean, 3/4·Mean}.
    """
    side = int(np.ceil(np.sqrt(m)))
    cells = plane / side
    pos = np.array([[(i % side + 0.5) * cells, (i // side + 0.5) * cells]
                    for i in range(m)], np.float32)
    mean = (capacity_n / m) if mean_users is None else mean_users
    levels = np.array([1.25 * mean, 1.0 * mean, 0.75 * mean], np.float32)
    caps = levels[rng.integers(0, 3, m)]
    return EdgeNetwork(
        server_pos=jnp.asarray(pos),
        f_k=jnp.asarray(rng.uniform(2e9, 10e9, m).astype(np.float32)),
        capacity=jnp.asarray(caps),
        B_im=jnp.asarray(rng.uniform(20e6, 50e6,
                                     (capacity_n, m)).astype(np.float32)),
        B_kl=jnp.asarray(np.full((m, m), 100e6, np.float32)),
        P_i=jnp.asarray(rng.uniform(2e-3, 5e-3,
                                    capacity_n).astype(np.float32)),
        P_k=jnp.asarray(rng.uniform(10e-3, 15e-3, m).astype(np.float32)),
        eta_kl=jnp.asarray((np.ones((m, m)) - np.eye(m)).astype(np.float32)),
        sigma2=10 ** (-110 / 10) * 1e-3,   # -110 dBm → W
        rho0=1e-3,                          # -30 dB reference gain
        h0=1e-7,
        zeta_im=3e-3 / 1e6,                 # 3 mJ/Mb → J/bit
        zeta_kl=5e-3 / 1e6,                 # 5 mJ/Mb → J/bit
    )


# ---------------------------------------------------------------------------
# channel / rates
# ---------------------------------------------------------------------------

def channel_gain(net: EdgeNetwork, state: GraphState) -> jnp.ndarray:
    """h_{i,m}(t) = ρ0 · d_{i,m}(t)^{-2} (free-space path loss)."""
    d = jnp.linalg.norm(state.pos[:, None, :] - net.server_pos[None, :, :],
                        axis=-1)
    return net.rho0 / jnp.maximum(d, 1.0) ** 2


def uplink_rate(net: EdgeNetwork, state: GraphState) -> jnp.ndarray:
    """Eq. (3): R_{i,m} = B_{i,m} log2(1 + P_i h_{i,m} / σ²)   [bit/s]."""
    h = channel_gain(net, state)
    snr = net.P_i[:, None] * h / net.sigma2
    return net.B_im * jnp.log2(1.0 + snr)


def server_rate(net: EdgeNetwork) -> jnp.ndarray:
    """Eq. (6): R_{k,l} = B_{k,l} log2(1 + P_k h0 / σ²)   [bit/s]."""
    snr = net.P_k[:, None] * net.h0 / net.sigma2
    r = net.B_kl * jnp.log2(1.0 + snr)
    m = r.shape[0]
    return r * (1.0 - jnp.eye(m, dtype=r.dtype))


# ---------------------------------------------------------------------------
# cost terms
# ---------------------------------------------------------------------------

def upload_costs(net: EdgeNetwork, state: GraphState, w: jnp.ndarray
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Eqs. (4)–(5). w: [N, M] one-hot offloading decision w_{im}.

    Returns (T_up [N], I_up [N]) per user."""
    bits = state.task_kb * KB * state.mask
    rate = uplink_rate(net, state)
    t_up = jnp.sum(bits[:, None] / jnp.maximum(rate, 1.0) * w, axis=1)
    i_up = jnp.sum(bits[:, None] * net.zeta_im * w, axis=1)
    return t_up, i_up


def cross_server_bits(state: GraphState, w: jnp.ndarray) -> jnp.ndarray:
    """x_{k→l}(t) = Σ_i Σ_j X_i · w_ik · e_ij · w_jl  (bits, [M, M]).

    Per Eq. (8) this counts per *edge*: SV_k sends user i's data to SV_l
    once for every associated user j hosted on l (each message-passing
    aggregation pulls it)."""
    bits = state.task_kb * KB * state.mask
    x = jnp.einsum("i,ik,ij,jl->kl", bits, w, state.adj, w)
    m = w.shape[1]
    return x * (1.0 - jnp.eye(m, dtype=x.dtype))


def transfer_costs(net: EdgeNetwork, state: GraphState, w: jnp.ndarray
                   ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Eqs. (7)–(8). Returns (T_tran [M,M], I_com [M,M], x̃_kl [M,M])."""
    x_dir = cross_server_bits(state, w)
    x_sym = x_dir + x_dir.T                       # x̃_kl
    rate = server_rate(net)
    t_tran = x_sym / jnp.maximum(rate, 1.0) * net.eta_kl
    i_com = net.zeta_kl * x_dir * net.eta_kl      # Eq. (8) per directed pair
    return t_tran, i_com, x_sym


def compute_time(net: EdgeNetwork, state: GraphState, w: jnp.ndarray
                 ) -> jnp.ndarray:
    """Eq. (9): T^{com}_{i,f_k} = X_i w_ik / f_k   [N]."""
    bits = state.task_kb * KB * state.mask
    return jnp.sum(bits[:, None] / net.f_k[None, :] * w, axis=1)


def gnn_energy(state: GraphState, p: GNNCostParams) -> jnp.ndarray:
    """Eqs. (10)–(11) summed over layers κ = 1..F (scalar J).

    I_agg_κ = Σ_i μ |N_i| S_{κ-1};  I_upd_κ = ϑ S_{κ-1} S_κ + φ S_κ."""
    deg = state.degrees()
    n_active = state.num_active()
    total = jnp.zeros(())
    sizes = [s * KB for s in p.layer_sizes_kb]
    for k in range(1, len(sizes)):
        s_prev, s_cur = sizes[k - 1], sizes[k]
        total = total + p.mu * jnp.sum(deg) * s_prev
        total = total + (p.theta * s_prev * s_cur / p.update_norm_bits
                         + p.phi * s_cur) * n_active
    return total


class SystemCost(NamedTuple):
    c: jnp.ndarray            # scalar — C = λt·T_all + λe·I_all (Eq. 14 objective)
    t_all: jnp.ndarray        # Eq. (12)
    i_all: jnp.ndarray        # Eq. (13)
    t_up: jnp.ndarray         # [N]
    t_tran: jnp.ndarray       # [M, M]
    t_com: jnp.ndarray        # [N]
    i_up: jnp.ndarray         # [N]
    i_com: jnp.ndarray        # [M, M]
    i_gnn: jnp.ndarray        # scalar
    cross_bits: jnp.ndarray   # x̃_kl [M, M] — cross-server communication volume


def system_cost(net: EdgeNetwork, state: GraphState, w: jnp.ndarray,
                gnn: GNNCostParams = GNNCostParams(),
                lambda_t: float = 1.0, lambda_e: float = 1.0) -> SystemCost:
    """Full objective C = T_all + I_all (Eqs. 12–14) for assignment w."""
    w = w * state.mask[:, None]
    t_up, i_up = upload_costs(net, state, w)
    t_tran, i_com, x_sym = transfer_costs(net, state, w)
    t_com = compute_time(net, state, w)
    i_gnn = gnn_energy(state, gnn)
    t_all = jnp.sum(t_up) + jnp.sum(t_tran) + jnp.sum(t_com)
    i_all = jnp.sum(i_up) + jnp.sum(i_com) + i_gnn
    c = lambda_t * t_all + lambda_e * i_all
    return SystemCost(c, t_all, i_all, t_up, t_tran, t_com, i_up, i_com,
                      i_gnn, x_sym)


# ---------------------------------------------------------------------------
# heterogeneous per-server profiles (fault injection / degradation)
# ---------------------------------------------------------------------------

class ServerProfile(NamedTuple):
    """Per-server health and heterogeneity scales (DESIGN.md §9).

    A degraded server *reprices* rather than vanishing: its capacity and
    compute shrink and its energy cost grows, so the offload policies route
    around it through the ordinary cost terms. A down server (``up == 0``)
    is unreachable: capacity 0, no uplink bandwidth, η row/col zeroed."""
    up: jnp.ndarray              # [M] {0,1} — server reachable
    compute_scale: jnp.ndarray   # [M] — multiplies f_k
    capacity_scale: jnp.ndarray  # [M] — multiplies capacity
    energy_scale: jnp.ndarray    # [M] — multiplies ς_{i,m} / ς_{k,l} (sender side)

    @classmethod
    def healthy(cls, m: int) -> "ServerProfile":
        one = jnp.ones((m,), jnp.float32)
        return cls(up=one, compute_scale=one, capacity_scale=one,
                   energy_scale=one)


def degrade_network(net: EdgeNetwork, profile: ServerProfile) -> EdgeNetwork:
    """Reprice ``net`` under ``profile`` (pure; the base net is untouched).

    capacity → capacity·capacity_scale·up (a down server hosts no one),
    f_k → f_k·compute_scale (floored at 1 Hz so Eq. 9 stays finite),
    B_im → B_im·up (no uplink to a down server), η_kl → η_kl·up_k·up_l,
    ς_{i,m} → [M] per-server array scaled by energy_scale, and
    ς_{k,l} → [M, M] sender-scaled by energy_scale."""
    m = int(net.f_k.shape[0])
    up = jnp.asarray(profile.up, jnp.float32)
    zeta_im = (jnp.broadcast_to(jnp.asarray(net.zeta_im, jnp.float32), (m,))
               * profile.energy_scale)
    zeta_kl = (jnp.broadcast_to(jnp.asarray(net.zeta_kl, jnp.float32), (m, m))
               * profile.energy_scale[:, None])
    return net._replace(
        f_k=jnp.maximum(net.f_k * profile.compute_scale, 1.0),
        capacity=net.capacity * profile.capacity_scale * up,
        B_im=net.B_im * up[None, :],
        eta_kl=net.eta_kl * up[:, None] * up[None, :],
        zeta_im=zeta_im,
        zeta_kl=zeta_kl,
    )


def assignment_onehot(assign: jnp.ndarray, m: int) -> jnp.ndarray:
    """[N] int server ids (−1 = unassigned) → [N, M] one-hot w."""
    oh = jnp.zeros((assign.shape[0], m), jnp.float32)
    valid = assign >= 0
    oh = oh.at[jnp.arange(assign.shape[0]),
               jnp.clip(assign, 0, m - 1)].set(1.0)
    return oh * valid[:, None].astype(jnp.float32)
