"""Comparison baseline for Fig. 6: iterated max-flow min-cut (Zeng et al. [36]).

The paper describes the baseline as: iterate over pairs of edge servers,
take the pair as (source, sink), run max-flow/min-cut on the vertices and
edges spanning the two servers' current partitions, and re-partition by the
resulting cut. Edge weights are random integers in [1, 100]; the number of
iterations scales with the number of server pairs. Overall O(V²E).

We implement Dinic's algorithm (adjacency-list residual graph) and the
pairwise re-partition loop. The benchmark (``benchmarks/bench_hicut.py``)
compares wall time and cut quality against HiCut on the paper's sparse /
non-sparse random graphs.
"""
from __future__ import annotations

from collections import deque

import numpy as np


class Dinic:
    def __init__(self, n: int):
        self.n = n
        self.head: list[list[int]] = [[] for _ in range(n)]
        self.to: list[int] = []
        self.cap: list[int] = []

    def add_edge(self, u: int, v: int, c: int) -> None:
        self.head[u].append(len(self.to)); self.to.append(v); self.cap.append(c)
        self.head[v].append(len(self.to)); self.to.append(u); self.cap.append(c)

    def _bfs(self, s: int, t: int) -> bool:
        self.level = [-1] * self.n
        self.level[s] = 0
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 0 and self.level[v] < 0:
                    self.level[v] = self.level[u] + 1
                    q.append(v)
        return self.level[t] >= 0

    def _dfs(self, u: int, t: int, f: int) -> int:
        if u == t:
            return f
        while self.it[u] < len(self.head[u]):
            eid = self.head[u][self.it[u]]
            v = self.to[eid]
            if self.cap[eid] > 0 and self.level[v] == self.level[u] + 1:
                d = self._dfs(v, t, min(f, self.cap[eid]))
                if d > 0:
                    self.cap[eid] -= d
                    self.cap[eid ^ 1] += d
                    return d
            self.it[u] += 1
        return 0

    def max_flow(self, s: int, t: int) -> int:
        flow = 0
        while self._bfs(s, t):
            self.it = [0] * self.n
            while True:
                f = self._dfs(s, t, 1 << 60)
                if f == 0:
                    break
                flow += f
        return flow

    def min_cut_side(self, s: int) -> np.ndarray:
        """Vertices reachable from s in the residual graph (source side)."""
        side = np.zeros(self.n, bool)
        side[s] = True
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 0 and not side[v]:
                    side[v] = True
                    q.append(v)
        return side


def pairwise_mincut_partition(n: int, edges: np.ndarray, weights: np.ndarray,
                              num_servers: int, seed: int = 0) -> np.ndarray:
    """The [36]-style baseline: pairwise max-flow min-cut re-partitioning."""
    rng = np.random.default_rng(seed)
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    assign = rng.integers(0, num_servers, n)
    import sys
    old_limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old_limit, n + 1024))
    try:
        for k in range(num_servers):
            for l in range(k + 1, num_servers):
                members = np.nonzero((assign == k) | (assign == l))[0]
                if len(members) < 2:
                    continue
                local = -np.ones(n, np.int64)
                local[members] = np.arange(len(members))
                emask = (local[edges[:, 0]] >= 0) & (local[edges[:, 1]] >= 0)
                sub_e = edges[emask]
                sub_w = weights[emask]
                if len(sub_e) == 0:
                    continue
                g = Dinic(len(members))
                for (u, v), c in zip(sub_e, sub_w):
                    g.add_edge(int(local[u]), int(local[v]), int(c))
                # anchor terminals: highest-degree member of each side
                deg = np.zeros(len(members), np.int64)
                np.add.at(deg, local[sub_e[:, 0]], 1)
                np.add.at(deg, local[sub_e[:, 1]], 1)
                side_k = assign[members] == k
                if not side_k.any() or side_k.all():
                    continue
                s = int(np.argmax(np.where(side_k, deg, -1)))
                t = int(np.argmax(np.where(~side_k, deg, -1)))
                g.max_flow(s, t)
                src_side = g.min_cut_side(s)
                assign[members[src_side]] = k
                assign[members[~src_side]] = l
    finally:
        sys.setrecursionlimit(old_limit)
    return assign


def mincut_partition_state(state, num_parts: int, seed: int = 0,
                           weight_range: tuple[int, int] = (1, 100)
                           ) -> np.ndarray:
    """Run the baseline on a ``GraphState`` layout → [N] part ids (−1 for
    inactive vertices). Edge weights are random integers in ``weight_range``
    as the paper describes; this is the ``mincut`` entry of the
    ``repro.core.api`` partitioner registry."""
    from repro.core.api import state_edges   # function-level: keep this
    edges = state_edges(state)               # module numpy-only otherwise
    rng = np.random.default_rng(seed)
    weights = rng.integers(weight_range[0], weight_range[1] + 1,
                           len(edges))
    assign = pairwise_mincut_partition(state.capacity, edges, weights,
                                       num_parts, seed=seed)
    assign[np.asarray(state.mask) <= 0] = -1
    return assign
