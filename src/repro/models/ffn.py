"""FFN blocks: gated dense MLP, routed MoE, RWKV channel-mix.

MoE uses scatter-based token dispatch (sort-free): top-k routing →
position-within-expert via cumsum → scatter into [E, capacity, d] →
batched expert matmuls → gather+combine. This avoids the O(T·E·cap)
one-hot dispatch tensor (prohibitive at 65k tokens/device) while staying
pure XLA so GSPMD can shard the expert dim (EP) or the expert hidden dim
(TP) per the sharding rules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nnlib.core import normal_init


# ---------------------------------------------------------------------------
# dense gated MLP (llama/qwen-style SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int) -> dict:
    ks = jax.random.split(key, 3)
    return {"w_gate": normal_init(ks[0], (d_model, d_ff), std=d_model ** -0.5),
            "w_up": normal_init(ks[1], (d_model, d_ff), std=d_model ** -0.5),
            "w_down": normal_init(ks[2], (d_ff, d_model), std=d_ff ** -0.5)}


def mlp_apply(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# routed MoE (mixtral / deepseek-v2-lite)
# ---------------------------------------------------------------------------

def moe_init(key, cfg) -> dict:
    d, f = cfg.d_model, cfg.moe_d_ff
    e = cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": normal_init(ks[0], (d, e), std=d ** -0.5),
        "we_gate": normal_init(ks[1], (e, d, f), std=d ** -0.5),
        "we_up": normal_init(ks[2], (e, d, f), std=d ** -0.5),
        "we_down": normal_init(ks[3], (e, f, d), std=f ** -0.5),
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, f * cfg.num_shared_experts)
    return p


def _constrain(x, shard_ctx, name):
    if shard_ctx and name in shard_ctx:
        return jax.lax.with_sharding_constraint(x, shard_ctx[name])
    return x


MOE_GROUP = 128      # routing-group size in slots (GShard-style).
# §Perf: 256→128 confirmed −5.6% train compute term (dispatch-einsum FLOPs
# scale with the group size) at equal capacity-drop behavior.

# §Perf toggle: pin ye (down-proj output) to the replicated-d layout.
# True = baseline; False lets the f-contraction's partial sums propagate to
# the sequence-sharded residual so GSPMD can reduce-scatter instead of
# all-reduce (see EXPERIMENTS.md §Perf-1).
YE_CONSTRAINT = True

# §Perf toggle: accumulate the down-proj/combine einsums in bf16 so the TP
# partial-sum all-reduce crosses ICI in bf16 instead of f32 (standard TPU
# practice for TP reductions; MXU still accumulates f32 internally on HW).
BF16_REDUCE = False


def moe_apply(cfg, p, x, shard_ctx=None):
    """x [B,S,d] → [B,S,d]; top-k routing, GShard-style einsum dispatch.

    Token slots are split into routing groups of MOE_GROUP slots; within a
    group the position-in-expert cumsum is local and dispatch/combine are
    dense one-hot matmuls — everything shards cleanly under GSPMD (no
    scatter, whose distributed lowering replicates operands). Capacity is
    per group (C = cf·group/E); small groups (decode / smoke tests) run
    dropless. The dispatch einsums cost O(T·k·d·cf·group) extra FLOPs —
    visible in the roofline compute term and a deliberate trade (see
    EXPERIMENTS.md §Perf for the sort-based alternative).
    Returns (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    logits = x @ p["router"]                         # [B, S, E]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gates, idx = jax.lax.top_k(probs, k)             # [B, S, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # drop any sequence sharding BEFORE the (B,S·k)→(G,group) reshape —
    # GSPMD cannot split a dim sharded on one axis across a dim merged with
    # another, and falls back to full all-gathers of the dispatch tensors
    gates = _constrain(gates, shard_ctx, "moe_route")
    idx = _constrain(idx, shard_ctx, "moe_route")
    x = _constrain(x, shard_ctx, "moe_route")

    slots = s * k                                    # slot order: (s, k)
    group = MOE_GROUP if slots % MOE_GROUP == 0 and slots > MOE_GROUP \
        else slots
    gpr = slots // group                             # groups per row
    ng = b * gpr
    if group < MOE_GROUP:                            # small inputs (decode /
        cap = group                                  # smoke): dropless
    else:
        cap = max(1, int(cfg.capacity_factor * group / e))

    flat_e = idx.reshape(ng, group)                  # [G, gs]
    gate_g = gates.reshape(ng, group)
    onehot_e = jax.nn.one_hot(flat_e, e, dtype=jnp.float32)   # [G, gs, E]
    pos = jnp.cumsum(onehot_e, axis=1) * onehot_e    # 1-based, per group
    pos_sel = pos.max(-1) - 1.0                      # [G, gs]
    keep = (pos_sel < cap) & (pos_sel >= 0)
    onehot_c = jax.nn.one_hot(pos_sel.astype(jnp.int32), cap,
                              dtype=jnp.float32) * keep[..., None]
    disp = jnp.einsum("gse,gsc->gsec", onehot_e,
                      onehot_c).astype(x.dtype)      # [G, gs, E, C]
    comb = disp * gate_g[..., None, None].astype(x.dtype)

    acc = x.dtype if BF16_REDUCE else None
    xg = jnp.repeat(x, k, axis=1).reshape(ng, group, d)
    xg = _constrain(xg, shard_ctx, "moe_tok")
    xe = jnp.einsum("gsec,gsd->gecd", disp, xg,      # [G, E, C, d]
                    preferred_element_type=acc)
    xe = _constrain(xe, shard_ctx, "moe_xe")

    h = jnp.einsum("gecd,edf->gecf", xe, p["we_gate"],
                   preferred_element_type=acc)
    h = _constrain(h, shard_ctx, "moe_he")
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, p["we_up"],
                                    preferred_element_type=acc)
    h = _constrain(h, shard_ctx, "moe_he")
    ye = jnp.einsum("gecf,efd->gecd", h, p["we_down"],
                    preferred_element_type=acc)
    if YE_CONSTRAINT:
        ye = _constrain(ye, shard_ctx, "moe_xe")

    yg = jnp.einsum("gsec,gecd->gsd", comb, ye,      # [G, gs, d]
                    preferred_element_type=acc)
    yg = _constrain(yg, shard_ctx, "moe_tok")
    y = yg.reshape(b, s, k, d).sum(2)
    # re-shard to the residual layout HERE — letting the partitioner resolve
    # the (batch-sharded) → (seq-sharded) mismatch at the `h + fx` add makes
    # it re-partition the whole dispatch chain with full all-gathers
    y = _constrain(y, shard_ctx, "residual")

    if "shared" in p:
        y = y + mlp_apply(p["shared"], x)

    # load-balance aux loss (Switch-style)
    me = probs.mean((0, 1))
    ce = jnp.mean(onehot_e, (0, 1))
    aux = e * jnp.sum(me * ce)
    return y, aux


# ---------------------------------------------------------------------------
# RWKV channel-mix
# ---------------------------------------------------------------------------

def rwkv_cm_init(key, d_model: int, d_ff: int) -> dict:
    ks = jax.random.split(key, 3)
    return {"mu_k": jnp.zeros((d_model,)) + 0.5,
            "mu_r": jnp.zeros((d_model,)) + 0.5,
            "w_k": normal_init(ks[0], (d_model, d_ff), std=d_model ** -0.5),
            "w_v": normal_init(ks[1], (d_ff, d_model), std=d_ff ** -0.5),
            "w_r": normal_init(ks[2], (d_model, d_model),
                               std=d_model ** -0.5)}


def rwkv_cm_apply(p, x, x_prev):
    """x [B,S,d]; x_prev [B,1,d] = last token of the previous segment."""
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    xk = x + (shifted - x) * p["mu_k"]
    xr = x + (shifted - x) * p["mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (kk @ p["w_v"]), x[:, -1:]
