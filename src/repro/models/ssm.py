"""Mamba2 (SSD) mixer — chunked matmul form (zamba2 backbone).

State-space recurrence with scalar-per-head decay:
    h_t = a_t · h_{t−1} + (Δ_t x_t) ⊗ B_t,   y_t = h_t C_t + D x_t
with a_t = exp(Δ_t · A), A = −exp(A_log) < 0.

TPU-native chunked evaluation (the SSD algorithm): within a chunk of length
L everything is dense matmuls against the decay matrix
``exp(ca_i − ca_j)`` (MXU work); across chunks a ``lax.scan`` carries the
[H, P, N] state. All decay exponents are ≤ 0, so the chunked form is
numerically safe. Decode is the single-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nnlib.core import normal_init, rmsnorm_init, rmsnorm_apply


def mamba2_init(key, cfg) -> dict:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    heads = din // cfg.ssm_headdim
    ks = jax.random.split(key, 4)
    conv_dim = din + 2 * n
    return {
        "w_in": normal_init(ks[0], (d, 2 * din + 2 * n + heads),
                            std=d ** -0.5),
        "conv_w": normal_init(ks[1], (cfg.ssm_conv, conv_dim), std=0.5),
        "conv_b": jnp.zeros((conv_dim,)),
        "a_log": jnp.log(jnp.linspace(1.0, 8.0, heads)),
        "dt_bias": jnp.zeros((heads,)),
        "d_skip": jnp.ones((heads,)),
        "out_norm": rmsnorm_init(din),
        "w_out": normal_init(ks[3], (din, d), std=din ** -0.5),
    }


def _split_in(cfg, zxbcdt):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    heads = din // cfg.ssm_headdim
    z = zxbcdt[..., :din]
    xc = zxbcdt[..., din:2 * din]
    bc = zxbcdt[..., 2 * din:2 * din + 2 * n]
    dt = zxbcdt[..., 2 * din + 2 * n:]
    return z, xc, bc, dt, din, n, heads


def _conv_step(p, window):
    """window [B, K, C] — causal depthwise conv at one position."""
    return jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]


def mamba2_apply(cfg, p, x, cache=None):
    """x [B,S,d]. cache None → chunked scan (train/prefill, returns no cache);
    cache dict → single-step decode. Returns (y, new_cache)."""
    b, s, d = x.shape
    zxbcdt = x @ p["w_in"]
    z, xc, bc, dt, din, n, heads = _split_in(cfg, zxbcdt)
    ph = cfg.ssm_headdim
    conv_in = jnp.concatenate([xc, bc], -1)          # [B,S,din+2n]

    if cache is None:
        k = cfg.ssm_conv
        padded = jnp.pad(conv_in, ((0, 0), (k - 1, 0), (0, 0)))
        stacked = jnp.stack([padded[:, i:i + s] for i in range(k)], 2)
        conv = jax.nn.silu(jnp.einsum("bskc,kc->bsc", stacked, p["conv_w"])
                           + p["conv_b"])
        xh = conv[..., :din].reshape(b, s, heads, ph)
        bmat = conv[..., din:din + n]                # [B,S,N] (1 group)
        cmat = conv[..., din + n:]
        dtv = jax.nn.softplus(dt + p["dt_bias"])     # [B,S,H]
        a = -jnp.exp(p["a_log"])                     # [H] < 0
        loga = dtv * a                               # [B,S,H] ≤ 0
        y = _ssd_chunked(cfg, xh * dtv[..., None], bmat, cmat, loga)
        y = y + xh * p["d_skip"][None, None, :, None]
        new_cache = None
    else:
        # decode: s == 1
        window = jnp.concatenate([cache["conv"], conv_in], axis=1)[:, 1:]
        conv = jax.nn.silu(_conv_step(p, window))
        xh = conv[..., :din].reshape(b, heads, ph)
        bmat = conv[..., din:din + n]
        cmat = conv[..., din + n:]
        dtv = jax.nn.softplus(dt[:, 0] + p["dt_bias"])  # [B,H]
        a = -jnp.exp(p["a_log"])
        decay = jnp.exp(dtv * a)                     # [B,H]
        upd = jnp.einsum("bhp,bn->bhpn", xh * dtv[..., None], bmat)
        state = cache["state"] * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, cmat)
        y = (y + xh * p["d_skip"][None, :, None])[:, None]
        new_cache = {"conv": window, "state": state}

    y = y.reshape(b, -1, din)
    y = rmsnorm_apply(p["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["w_out"], new_cache


def _ssd_chunked(cfg, xdt, bmat, cmat, loga):
    """xdt [B,S,H,P] (already Δ-scaled), b/c [B,S,N], loga [B,S,H] ≤ 0."""
    b, s, h, ph = xdt.shape
    n = bmat.shape[-1]
    l = min(cfg.ssm_chunk, s)
    while s % l:
        l //= 2
    nc = s // l
    xc = xdt.reshape(b, nc, l, h, ph)
    bc = bmat.reshape(b, nc, l, n)
    cc = cmat.reshape(b, nc, l, n)
    la = loga.reshape(b, nc, l, h)
    ca = jnp.cumsum(la, axis=2)                      # [B,nc,L,H]

    # intra-chunk: y_i = Σ_{j≤i} exp(ca_i − ca_j)·(C_i·B_j)·xdt_j
    # mask BEFORE the exp: the upper triangle has ca_i − ca_j > 0 and
    # overflows to inf, which turns into NaN grads through jnp.where
    g = jnp.einsum("bcin,bcjn->bcij", cc, bc)        # [B,nc,L,L]
    mask = jnp.tril(jnp.ones((l, l), bool))
    diff = ca[:, :, :, None, :] - ca[:, :, None, :, :]          # [B,nc,L,L,H]
    diff = jnp.where(mask[None, None, :, :, None], diff, -1e30)
    w = g[..., None] * jnp.exp(diff)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)

    # chunk summaries: state increment + total decay
    dec_end = jnp.exp(ca[:, :, -1:, :] - ca)         # exp(ca_L − ca_j)
    inc = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", dec_end, bc, xc)
    tot = jnp.exp(ca[:, :, -1])                      # [B,nc,H]

    def scan_fn(state, xs):
        inc_c, tot_c = xs
        new = state * tot_c[..., None, None] + inc_c
        return new, state                            # emit state at chunk start

    init = jnp.zeros((b, h, ph, n), xdt.dtype)
    _, states = jax.lax.scan(scan_fn, init,
                             (inc.swapaxes(0, 1), tot.swapaxes(0, 1)))
    states = states.swapaxes(0, 1)                   # [B,nc,H,P,N]

    # inter-chunk: y_i += exp(ca_{i}) · C_i · S_chunkstart
    pref = jnp.exp(ca)                               # includes step i decay
    y_inter = jnp.einsum("bcih,bcin,bchpn->bcihp", pref, cc, states)
    return (y_intra + y_inter).reshape(b, s, h, ph)


def mamba2_cache_init(cfg, batch: int, dtype=jnp.float32) -> dict:
    din = cfg.ssm_expand * cfg.d_model
    heads = din // cfg.ssm_headdim
    conv_dim = din + 2 * cfg.ssm_state
    return {"conv": jnp.zeros((batch, cfg.ssm_conv, conv_dim), dtype),
            "state": jnp.zeros((batch, heads, cfg.ssm_headdim,
                                cfg.ssm_state), dtype)}
