"""RWKV-6 "Finch" time-mix — data-dependent per-channel decay (rwkv6-7b).

Recurrence per head (K = V = head dim):
    y_t = r_t · (S_{t−1} + diag(u)·k_tᵀ v_t)
    S_t = diag(w_t) · S_{t−1} + k_tᵀ v_t
with data-dependent decay w_t = exp(−exp(w0 + lora(x̃_t))) (Finch), learned
bonus u, and token-shift mixing on every projection input.

Chunked evaluation: the within-chunk attention factorizes as
(r·exp(cw)) @ (k·exp(−cw))ᵀ with exponents re-centered per chunk; cross-
chunk state is a ``lax.scan``. All cross-chunk exponents are ≤ 0; the
re-centered intra-chunk factors are bounded by exp(chunk·|log w|/2) —
chunks default to 32 (DESIGN.md notes this in place of RWKV's segmented
CUDA kernel). Decode is the exact single-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nnlib.core import normal_init, rmsnorm_init, rmsnorm_apply

RWKV_CHUNK = 32
LORA_DIM = 64


def rwkv6_init(key, cfg) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    heads = d // cfg.rwkv_head_dim
    return {
        "mu": 0.5 * jnp.ones((5, d)),                    # r,k,v,g,w mixes
        "w_r": normal_init(ks[0], (d, d), std=d ** -0.5),
        "w_k": normal_init(ks[1], (d, d), std=d ** -0.5),
        "w_v": normal_init(ks[2], (d, d), std=d ** -0.5),
        "w_g": normal_init(ks[3], (d, d), std=d ** -0.5),
        "w_o": normal_init(ks[4], (d, d), std=d ** -0.5),
        "w0": jnp.full((d,), -2.0),                      # decay base
        "w_lora_a": normal_init(ks[5], (d, LORA_DIM), std=d ** -0.5),
        "w_lora_b": normal_init(ks[6], (LORA_DIM, d), std=LORA_DIM ** -0.5),
        "u": normal_init(ks[7], (d,), std=0.3),          # bonus
        "ln_x": rmsnorm_init(d),
    }


def _mix(x, shifted, mu):
    return x + (shifted - x) * mu


def _projections(cfg, p, x, shifted):
    """Returns r,k,v,g [B,S,H,K] and log-decay lw [B,S,H,K] ≤ 0."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    r = _mix(x, shifted, p["mu"][0]) @ p["w_r"]
    k = _mix(x, shifted, p["mu"][1]) @ p["w_k"]
    v = _mix(x, shifted, p["mu"][2]) @ p["w_v"]
    g = _mix(x, shifted, p["mu"][3]) @ p["w_g"]
    xw = _mix(x, shifted, p["mu"][4])
    lw = -jnp.exp(p["w0"] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"])
    shape = (b, s, h, hd)
    return (r.reshape(shape), k.reshape(shape), v.reshape(shape),
            g.reshape(b, s, d), lw.reshape(shape))


def rwkv6_apply(cfg, p, x, cache=None):
    """x [B,S,d]. cache None → chunked (no cache out); dict → decode step."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    if cache is None:
        shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        r, k, v, g, lw = _projections(cfg, p, x, shifted)
        u = p["u"].reshape(h, hd)
        y = _wkv_chunked(r, k, v, lw, u)
        new_cache = None
    else:
        shifted = cache["x_prev"]
        r, k, v, g, lw = _projections(cfg, p, x, shifted)
        u = p["u"].reshape(h, hd)
        r1, k1, v1, lw1 = (t[:, 0] for t in (r, k, v, lw))
        kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
        y = jnp.einsum("bhk,bhkv->bhv", r1,
                       cache["state"] + u[None, :, :, None] * kv)
        state = cache["state"] * jnp.exp(lw1)[..., None] + kv
        y = y[:, None]
        new_cache = {"state": state.astype(cache["state"].dtype),
                     "x_prev": x.astype(cache["x_prev"].dtype)}
    y = rmsnorm_apply(p["ln_x"], y.reshape(b, -1, d), cfg.norm_eps)
    y = y * jax.nn.silu(g)
    return y @ p["w_o"], new_cache


def _wkv_chunked(r, k, v, lw, u):
    """r/k/v/lw [B,S,H,K], u [H,K] → y [B,S,H,K(V)]."""
    b, s, h, kd = r.shape
    l = min(RWKV_CHUNK, s)
    while s % l:
        l //= 2
    nc = s // l
    rc, kc, vc, lwc = (t.reshape(b, nc, l, h, kd) for t in (r, k, v, lw))
    cw = jnp.cumsum(lwc, axis=2)                     # [B,nc,L,H,K]
    cref = cw[:, :, l // 2:l // 2 + 1]               # re-center
    # intra-chunk: y_i = Σ_{j<i} r_i exp(cw_{i−1}−cw_j) k_j v_j + u·r_i k_i v_i
    cw_im1 = jnp.pad(cw, ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))[:, :, :-1]
    a = rc * jnp.exp(cw_im1 - cref)
    bfac = kc * jnp.exp(cref - cw)
    att = jnp.einsum("bclhk,bcmhk->bchlm", a, bfac)  # score l→m
    mask = jnp.tril(jnp.ones((l, l), bool), -1)      # strict j < i
    att = jnp.where(mask[None, None, None], att, 0.0)
    y = jnp.einsum("bchlm,bcmhv->bclhv", att, vc)
    diag = jnp.einsum("bclhk,hk,bclhk->bclh", rc, u, kc)
    y = y + diag[..., None] * vc
    # cross-chunk state
    dec_end = jnp.exp(cw[:, :, -1:] - cw)            # ≤ 1
    inc = jnp.einsum("bclhk,bclhv->bchkv", kc * dec_end, vc)
    tot = jnp.exp(cw[:, :, -1])                      # [B,nc,H,K]

    def scan_fn(state, xs):
        inc_c, tot_c = xs
        return state * tot_c[..., None] + inc_c, state

    init = jnp.zeros((b, h, kd, kd), r.dtype)
    _, states = jax.lax.scan(scan_fn, init,
                             (inc.swapaxes(0, 1), tot.swapaxes(0, 1)))
    states = states.swapaxes(0, 1)                   # state at chunk start
    pref = rc * jnp.exp(cw_im1)                      # decay to chunk start
    y = y + jnp.einsum("bclhk,bchkv->bclhv", pref, states)
    return y.reshape(b, s, h, kd)


def rwkv6_cache_init(cfg, batch: int, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    return {"state": jnp.zeros((batch, d // hd, hd, hd), dtype),
            "x_prev": jnp.zeros((batch, 1, d), dtype)}
