"""The unified model: embeddings → staged blocks (lax.scan) → head.

Covers all 10 assigned architectures through ModelConfig/LayerSpec:
dense GQA (qwen3, danube, gemma2), MoE (mixtral, deepseek-MLA), hybrid
Mamba2+attention (zamba2), attention-free RWKV6, encoder–decoder audio
(seamless — stub frame embeddings), VLM prefix (internvl — stub patch
embeddings).

Entry points:
  init_params(cfg, key)                      → params pytree (fp32 master)
  forward(cfg, params, batch, shard_ctx)     → logits        (train/prefill)
  loss_fn / make_train_step                  → CE + AdamW step
  init_cache(cfg, batch, max_len)            → decode cache pytree
  decode_step(cfg, params, cache, tok, pos)  → (logits, cache)   serve_step

``shard_ctx`` is an optional dict of NamedShardings used by the dry-run to
pin the residual-stream layout (sequence-parallel between blocks).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.config import LayerSpec, ModelConfig, Stage
from repro.nnlib.core import normal_init, rmsnorm_init, rmsnorm_apply
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

AUX_LOSS_WEIGHT = 0.01


def _constrain(x, shard_ctx, name):
    if shard_ctx and name in shard_ctx:
        return jax.lax.with_sharding_constraint(x, shard_ctx[name])
    return x


# ---------------------------------------------------------------------------
# layer init / apply
# ---------------------------------------------------------------------------

def layer_init(cfg: ModelConfig, spec: LayerSpec, key) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: dict[str, Any] = {"norm1": rmsnorm_init(d)}
    if spec.mixer == "attn":
        p["attn"] = attn.gqa_init(ks[0], cfg)
    elif spec.mixer == "mla":
        p["attn"] = attn.mla_init(ks[0], cfg)
    elif spec.mixer == "mamba2":
        p["mamba"] = ssm_mod.mamba2_init(ks[0], cfg)
    elif spec.mixer == "rwkv6":
        p["rwkv"] = rwkv_mod.rwkv6_init(ks[0], cfg)
    if spec.cross_attn:
        p["cross"] = attn.cross_init(ks[1], cfg)
        p["norm_cross"] = rmsnorm_init(d)
    if spec.ffn == "dense":
        p["norm2"] = rmsnorm_init(d)
        p["mlp"] = ffn_mod.mlp_init(ks[2], d, cfg.d_ff)
    elif spec.ffn == "moe":
        p["norm2"] = rmsnorm_init(d)
        p["moe"] = ffn_mod.moe_init(ks[2], cfg)
    elif spec.ffn == "rwkv_cm":
        p["norm2"] = rmsnorm_init(d)
        p["cm"] = ffn_mod.rwkv_cm_init(ks[2], d, cfg.d_ff)
    if spec.post_norm:
        p["post1"] = rmsnorm_init(d)
        if spec.ffn != "none":
            p["post2"] = rmsnorm_init(d)
    return p


def layer_apply(cfg: ModelConfig, spec: LayerSpec, p: dict, h, ctx: dict,
                cache: dict | None):
    """Returns (h, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}
    x = rmsnorm_apply(p["norm1"], h, cfg.norm_eps)
    if spec.mixer in ("attn", "mla"):
        sub = None if cache is None else cache.get("attn")
        fn = attn.gqa_apply if spec.mixer == "attn" else attn.mla_apply
        if spec.mixer == "attn" and not ctx.get("causal", True):
            # encoder layers: bidirectional full attention
            mx, _ = _encoder_attention(cfg, p["attn"], x, ctx)
        else:
            mx, nc = fn(cfg, spec, p["attn"], x, positions=ctx["positions"],
                        cache=sub)
            if nc is not None:
                new_cache["attn"] = nc
    elif spec.mixer == "mamba2":
        sub = None if cache is None else cache.get("mamba")
        mx, nc = ssm_mod.mamba2_apply(cfg, p["mamba"], x, cache=sub)
        if nc is not None:
            new_cache["mamba"] = nc
    elif spec.mixer == "rwkv6":
        sub = None if cache is None else cache.get("rwkv")
        mx, nc = rwkv_mod.rwkv6_apply(cfg, p["rwkv"], x, cache=sub)
        if nc is not None:
            new_cache["rwkv"] = nc
    else:
        mx = jnp.zeros_like(h)
    if spec.post_norm:
        mx = rmsnorm_apply(p["post1"], mx, cfg.norm_eps)
    h = h + mx.astype(h.dtype)
    h = _constrain(h, ctx.get("shard_ctx"), "residual")

    if spec.cross_attn:
        xc = rmsnorm_apply(p["norm_cross"], h, cfg.norm_eps)
        if cache is not None:
            enc_kv = cache["cross_kv"]
            new_cache["cross_kv"] = enc_kv
        else:
            enc_kv = attn.cross_kv(cfg, p["cross"], ctx["enc_out"])
        h = h + attn.cross_apply(cfg, p["cross"], xc, enc_kv).astype(h.dtype)

    if spec.ffn != "none":
        x2 = rmsnorm_apply(p["norm2"], h, cfg.norm_eps)
        if spec.ffn == "dense":
            fx = ffn_mod.mlp_apply(p["mlp"], x2)
        elif spec.ffn == "moe":
            fx, aux = ffn_mod.moe_apply(cfg, p["moe"], x2,
                                        ctx.get("shard_ctx"))
        else:  # rwkv_cm
            prev = (cache or {}).get(
                "cm_prev", jnp.zeros_like(x2[:, :1]))
            fx, last = ffn_mod.rwkv_cm_apply(p["cm"], x2.astype(prev.dtype)
                                             if cache is not None else x2,
                                             prev)
            if cache is not None:
                new_cache["cm_prev"] = last.astype(prev.dtype)
        if spec.post_norm:
            fx = rmsnorm_apply(p["post2"], fx, cfg.norm_eps)
        h = h + fx.astype(h.dtype)
        h = _constrain(h, ctx.get("shard_ctx"), "residual")
    return h, new_cache, aux


def _encoder_attention(cfg, p, x, ctx):
    b, s, d = x.shape
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, kv, dh)
    v = (x @ p["wv"]).reshape(b, s, kv, dh)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_apply(p["k_norm"], k, cfg.norm_eps)
    cos, sin = attn.rope_cos_sin(ctx["positions"], dh, cfg.rope_theta)
    q = attn.apply_rope(q, cos, sin)
    k = attn.apply_rope(k, cos, sin)
    out = attn._chunked_scores_softmax(q, k, v, offset=0, causal=False,
                                       window=None, softcap=None)
    return out.reshape(b, s, h * dh) @ p["wo"], None


# ---------------------------------------------------------------------------
# stages (scan over stacked layer params)
# ---------------------------------------------------------------------------

def _stage_init(cfg, stage: Stage, key) -> tuple:
    keys = jax.random.split(key, stage.reps * len(stage.unit))
    out = []
    for u, spec in enumerate(stage.unit):
        ks = jnp.stack([keys[r * len(stage.unit) + u]
                        for r in range(stage.reps)])
        out.append(jax.vmap(lambda k: layer_init(cfg, spec, k))(ks))
    return tuple(out)


def _run_stages(cfg, stages_cfg, stages_params, h, ctx, caches):
    """caches: None (no cache) or list per stage (pytrees, leading dim reps).

    Layers run under ``lax.scan`` over the stacked reps by default; the
    dry-run sets ``ctx['unroll']`` to get exact per-layer FLOP/byte counts
    out of ``cost_analysis`` (XLA counts a while-loop body once, not
    ×trip-count). Returns (h, new_caches, aux_total)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    unroll = bool(ctx.get("unroll", False))
    for si, (stage, sp) in enumerate(zip(stages_cfg, stages_params)):
        cache_s = None if caches is None else caches[si]

        def body(carry, xs):
            h, aux = carry
            unit_params, unit_cache = xs
            new_unit_cache = []
            for u, spec in enumerate(stage.unit):
                uc = None if unit_cache is None else unit_cache[u]
                h, nc, a = layer_apply(cfg, spec, unit_params[u], h, ctx, uc)
                new_unit_cache.append(nc)
                aux = aux + a
            ys = tuple(new_unit_cache) if unit_cache is not None else None
            return (h, aux), ys

        body = jax.checkpoint(body)
        xs = (sp, cache_s)
        if unroll:
            carry = (h, aux_total)
            ys_list = []
            for r in range(stage.reps):
                xs_r = jax.tree_util.tree_map(lambda x: x[r], xs)
                carry, ys_r = body(carry, xs_r)
                ys_list.append(ys_r)
            (h, aux_total) = carry
            ys = (jax.tree_util.tree_map(
                lambda *leaves: jnp.stack(leaves), *ys_list)
                if cache_s is not None else None)
        else:
            (h, aux_total), ys = jax.lax.scan(body, (h, aux_total), xs)
        new_caches.append(ys)
    return h, (new_caches if caches is not None else None), aux_total


# ---------------------------------------------------------------------------
# model init / forward
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 6 + len(cfg.stages) +
                          len(cfg.encoder_stages))
    d = cfg.d_model
    v = cfg.padded_vocab
    params: dict[str, Any] = {
        "embed": normal_init(ks[0], (v, d), std=0.02),
        "final_norm": rmsnorm_init(d),
        "lm_head": normal_init(ks[1], (d, v), std=d ** -0.5),
        "stages": [_stage_init(cfg, s, ks[6 + i])
                   for i, s in enumerate(cfg.stages)],
    }
    if cfg.num_prefix_tokens and cfg.prefix_dim:
        params["prefix_proj"] = normal_init(
            ks[2], (cfg.prefix_dim, d), std=cfg.prefix_dim ** -0.5)
    if cfg.encoder_stages:
        base = 6 + len(cfg.stages)
        params["encoder"] = {
            "in_proj": normal_init(ks[3], (cfg.prefix_dim or d, d),
                                   std=d ** -0.5),
            "stages": [_stage_init(cfg, s, ks[base + i])
                       for i, s in enumerate(cfg.encoder_stages)],
            "final_norm": rmsnorm_init(d),
        }
    return params


def _embed(cfg, params, tokens):
    h = params["embed"][tokens]
    if cfg.embed_scale:
        h = h * jnp.sqrt(jnp.array(cfg.d_model, h.dtype))
    return h


def _encode(cfg, params, frames, shard_ctx, unroll=False):
    """Audio/enc-dec encoder over stub frame embeddings [B,Se,prefix_dim]."""
    h = frames @ params["encoder"]["in_proj"]
    ctx = {"positions": jnp.arange(frames.shape[1]), "causal": False,
           "shard_ctx": shard_ctx, "unroll": unroll}
    h, _, _ = _run_stages(cfg, cfg.encoder_stages,
                          params["encoder"]["stages"], h, ctx, None)
    return rmsnorm_apply(params["encoder"]["final_norm"], h, cfg.norm_eps)


def _cast_params(params, dtype, shard_ctx=None):
    if dtype is None:
        return params
    cast = jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, params)
    # pin the bf16 copy to the params' sharded layout — otherwise GSPMD is
    # free to hoist the cast past the FSDP all-gathers and every weight
    # crosses ICI in f32 (2× bytes; §Perf-1)
    if shard_ctx and "params_sh" in shard_ctx:
        cast = jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            cast, shard_ctx["params_sh"])
    return cast


def forward_hidden(cfg: ModelConfig, params, batch: dict, shard_ctx=None,
                   compute_dtype=None, unroll=False):
    """Everything up to (and including) the final norm.

    Returns (h [B,S_text,d], aux_loss, cast_params)."""
    params = _cast_params(params, compute_dtype, shard_ctx)
    tokens = batch["tokens"]
    h = _embed(cfg, params, tokens)
    n_prefix = 0
    if cfg.num_prefix_tokens and "prefix_emb" in batch:
        pre = batch["prefix_emb"] @ params["prefix_proj"]
        h = jnp.concatenate([pre.astype(h.dtype), h], axis=1)
        n_prefix = pre.shape[1]
    ctx = {"positions": jnp.arange(h.shape[1]), "causal": True,
           "shard_ctx": shard_ctx, "unroll": unroll}
    if cfg.encoder_stages:
        ctx["enc_out"] = _encode(cfg, params, batch["frames"], shard_ctx,
                                 unroll)
    h = _constrain(h, shard_ctx, "residual")
    h, _, aux = _run_stages(cfg, cfg.stages, params["stages"], h, ctx, None)
    h = rmsnorm_apply(params["final_norm"], h, cfg.norm_eps)
    if n_prefix:
        h = h[:, n_prefix:]
    return h, aux, params


def _head(cfg, params, h):
    logits = h @ params["lm_head"]
    if cfg.final_logit_softcap:
        logits = cfg.final_logit_softcap * jnp.tanh(
            logits / cfg.final_logit_softcap)
    return logits


def forward(cfg: ModelConfig, params, batch: dict, shard_ctx=None,
            compute_dtype=None, unroll=False):
    """Training/prefill forward. batch: tokens [B,S] (+ prefix_emb /
    frames). Returns (logits [B,S,V], aux_loss)."""
    h, aux, params = forward_hidden(cfg, params, batch, shard_ctx,
                                    compute_dtype, unroll)
    return _head(cfg, params, h), aux


LOSS_CHUNK = 1024    # sequence chunk for the f32 log-softmax (vocab is big)


def loss_fn(cfg: ModelConfig, params, batch: dict, shard_ctx=None,
            compute_dtype=None, unroll=False):
    h, aux, params = forward_hidden(cfg, params, batch, shard_ctx,
                                    compute_dtype, unroll)
    targets = batch["targets"]
    b, s, _ = h.shape

    def ce_of(args):
        hc, tc = args
        logits = _head(cfg, params, hc)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]

    if s % LOSS_CHUNK == 0 and s > LOSS_CHUNK:
        nc = s // LOSS_CHUNK
        hs = h.reshape(b, nc, LOSS_CHUNK, -1).swapaxes(0, 1)
        ts = targets.reshape(b, nc, LOSS_CHUNK).swapaxes(0, 1)
        nll = jax.lax.map(ce_of, (hs, ts)).swapaxes(0, 1).reshape(b, s)
    else:
        nll = ce_of((h, targets))
    loss = jnp.mean(nll) + AUX_LOSS_WEIGHT * aux
    return loss, {"loss": loss, "ce": jnp.mean(nll), "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    shard_ctx=None, compute_dtype=None, unroll=False,
                    microbatches: int = 1, bf16_grads: bool = True):
    """One optimizer step. ``microbatches`` > 1 splits the global batch and
    accumulates fp32 grads sequentially (bounds activation transients —
    needed for the MoE archs' train shapes on 16 GB/chip).

    ``bf16_grads`` (default, §Perf-1): differentiate w.r.t. the *bf16 cast*
    of the fp32 master — every backward cotangent (and therefore every
    cross-device gradient reduction) is bf16; the optimizer still
    accumulates fp32 moments. False = paper-faithful f32 backward
    (baseline in EXPERIMENTS.md §Perf)."""
    opt_cfg = opt_cfg or AdamWConfig(lr=3e-4, weight_decay=0.01)

    def grads_of(params, batch):
        if bf16_grads and compute_dtype is not None:
            pb = _cast_params(params, compute_dtype, shard_ctx)
            return jax.value_and_grad(
                lambda q: loss_fn(cfg, q, batch, shard_ctx, None, unroll),
                has_aux=True)(pb)
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, shard_ctx, compute_dtype,
                              unroll),
            has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches, x.shape[0] //
                                     microbatches) + x.shape[1:]), batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_step(carry, mb):
                gacc, lacc = carry
                (l, _), g = grads_of(params, mb)
                gacc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32) / microbatches,
                    gacc, g)
                return (gacc, lacc + l / microbatches), None

            if unroll:
                carry = (zeros, jnp.zeros((), jnp.float32))
                for m in range(microbatches):
                    mb = jax.tree_util.tree_map(lambda x: x[m], micro)
                    carry, _ = acc_step(carry, mb)
                grads, loss = carry
            else:
                (grads, loss), _ = jax.lax.scan(
                    acc_step, (zeros, jnp.zeros((), jnp.float32)), micro)
            metrics = {"loss": loss, "ce": loss,
                       "aux": jnp.zeros((), jnp.float32)}
        params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, metrics

    return train_step


def init_opt(params):
    return adamw_init(params)


# ---------------------------------------------------------------------------
# serving: cache init + decode step
# ---------------------------------------------------------------------------

def _layer_cache_init(cfg, spec, batch, max_len, enc_out=None, dtype=jnp.bfloat16):
    c: dict[str, Any] = {}
    if spec.mixer == "attn":
        c["attn"] = attn.gqa_cache_init(cfg, spec, batch, max_len, dtype)
    elif spec.mixer == "mla":
        c["attn"] = attn.mla_cache_init(cfg, spec, batch, max_len, dtype)
    elif spec.mixer == "mamba2":
        c["mamba"] = ssm_mod.mamba2_cache_init(cfg, batch, jnp.float32)
    elif spec.mixer == "rwkv6":
        c["rwkv"] = rwkv_mod.rwkv6_cache_init(cfg, batch, jnp.float32)
    if spec.cross_attn:
        se = cfg.encoder_seq_len or 1
        kv, dh = cfg.num_kv_heads, cfg.head_dim
        c["cross_kv"] = {"k": jnp.zeros((batch, se, kv, dh), dtype),
                         "v": jnp.zeros((batch, se, kv, dh), dtype)}
    if spec.ffn == "rwkv_cm":
        c["cm_prev"] = jnp.zeros((batch, 1, cfg.d_model), jnp.float32)
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Decode cache pytree: list per stage of tuples per unit position,
    leaves stacked [reps, ...]."""
    caches = []
    for stage in cfg.stages:
        unit_caches = []
        for spec in stage.unit:
            one = _layer_cache_init(cfg, spec, batch, max_len, dtype=dtype)
            stacked = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (stage.reps,) + x.shape).copy()
                if stage.reps > 1 else x[None], one)
            unit_caches.append(stacked)
        caches.append(tuple(unit_caches))
    return caches


def decode_step(cfg: ModelConfig, params, caches, token, pos,
                shard_ctx=None, compute_dtype=None, unroll=False):
    """serve_step: ONE new token [B,1] against the cache; absolute position
    ``pos`` (scalar int32). Returns (logits [B,1,V], new_caches)."""
    params = _cast_params(params, compute_dtype)
    h = _embed(cfg, params, token)
    ctx = {"positions": jnp.full((1,), pos, jnp.int32), "causal": True,
           "shard_ctx": shard_ctx, "unroll": unroll}
    h = _constrain(h, shard_ctx, "decode_residual")
    h, new_caches, _ = _run_stages(cfg, cfg.stages, params["stages"], h,
                                   ctx, caches)
    h = rmsnorm_apply(params["final_norm"], h, cfg.norm_eps)
    logits = h @ params["lm_head"]
    if cfg.final_logit_softcap:
        logits = cfg.final_logit_softcap * jnp.tanh(
            logits / cfg.final_logit_softcap)
    return logits, new_caches
