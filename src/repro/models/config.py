"""Model configuration for the assigned-architecture stack.

A model is a sequence of **stages**; each stage is a repeated **unit** of
layer specs and is lowered as one ``lax.scan`` over stacked params (keeps
the HLO small enough to GSPMD-partition 80 dry-run combos on one CPU core,
and gives per-unit remat). Heterogeneous patterns (gemma2 local/global,
zamba2 mamba+shared-attention, deepseek first-dense) are expressed as
multi-layer units / prefix stages.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside a stage unit."""
    mixer: str = "attn"          # attn | mla | mamba2 | rwkv6 | none
    ffn: str = "dense"           # dense | moe | rwkv_cm | none
    window: int | None = None    # sliding-window size (None = full attention)
    cross_attn: bool = False     # decoder layer with encoder cross-attention
    post_norm: bool = False      # gemma2-style post-block RMSNorm


@dataclass(frozen=True)
class Stage:
    unit: tuple[LayerSpec, ...]
    reps: int

    @property
    def num_layers(self) -> int:
        return len(self.unit) * self.reps


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    vocab_size: int
    stages: tuple[Stage, ...]
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    rope_theta: float = 10000.0
    # ffn
    d_ff: int = 0
    # MLA (deepseek)
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # RWKV6
    rwkv_head_dim: int = 64
    # encoder-decoder (seamless)
    encoder_stages: tuple[Stage, ...] = ()
    encoder_seq_len: int = 0     # stub frame count fed to the encoder
    # multimodal prefix (internvl)
    num_prefix_tokens: int = 0
    prefix_dim: int = 0          # stub frontend embedding dim
    # misc
    norm_eps: float = 1e-6
    embed_scale: bool = False    # gemma-style sqrt(d) embedding scale
    long_context_ok: bool = False  # may run the long_500k shape (DESIGN.md)
    source: str = ""             # citation for the config numbers

    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.stages)

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab_size // 16) * 16   # divisible by the model axis

    def layer_specs(self) -> list[LayerSpec]:
        out = []
        for s in self.stages:
            out.extend(list(s.unit) * s.reps)
        return out

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS in §Roofline)."""
        d = self.d_model
        n = 2 * self.padded_vocab * d            # embed + head
        for spec in self.layer_specs():
            if spec.mixer == "attn":
                n += d * self.num_heads * self.head_dim * 2        # q, o
                n += d * self.num_kv_heads * self.head_dim * 2     # k, v
            elif spec.mixer == "mla":
                r, dn, dr, dv = (self.kv_lora_rank, self.qk_nope_dim,
                                 self.qk_rope_dim, self.v_head_dim)
                h = self.num_heads
                n += d * h * (dn + dr)                             # q
                n += d * (r + dr) + r * h * (dn + dv)              # kv lora
                n += h * dv * d                                    # o
            elif spec.mixer == "mamba2":
                din = self.ssm_expand * d
                heads = din // self.ssm_headdim
                n += d * (2 * din + 2 * self.ssm_state + heads) + din * d
            elif spec.mixer == "rwkv6":
                n += 5 * d * d + d * d                             # r,k,v,g,w,o
            if spec.cross_attn:
                n += d * self.num_heads * self.head_dim * 2
                n += d * self.num_kv_heads * self.head_dim * 2
            if spec.ffn == "dense":
                n += 3 * d * self.d_ff
            elif spec.ffn == "moe":
                n += 3 * d * self.moe_d_ff * (self.num_experts +
                                              self.num_shared_experts)
                n += d * self.num_experts
            elif spec.ffn == "rwkv_cm":
                n += 2 * d * self.d_ff + d * d
        for s in self.encoder_stages:
            for spec in list(s.unit) * s.reps:
                n += d * self.num_heads * self.head_dim * 2
                n += d * self.num_kv_heads * self.head_dim * 2
                n += 3 * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE counts top-k + shared experts)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(1 for s in self.layer_specs() if s.ffn == "moe")
        all_e = 3 * self.d_model * self.moe_d_ff * self.num_experts
        act_e = 3 * self.d_model * self.moe_d_ff * self.num_experts_per_tok
        return full - moe_layers * (all_e - act_e)


def reduced(cfg: ModelConfig, d_model: int = 256, layers: int = 2,
            d_ff: int = 512, experts: int = 4, vocab: int = 512,
            ) -> ModelConfig:
    """Smoke-test variant: same family, tiny dims (≤4 experts, ≤2 layers)."""
    head_dim = 32
    heads = max(2, min(4, cfg.num_heads or 4))
    kv = max(1, min(heads, cfg.num_kv_heads or heads))
    # keep one unit of each distinct stage, reps scaled down
    stages = []
    seen = 0
    for s in cfg.stages:
        if seen >= layers:
            break
        unit = s.unit[:max(1, layers - seen)]
        stages.append(Stage(unit=unit, reps=1))
        seen += len(unit)
    enc_stages = tuple(Stage(unit=s.unit[:1], reps=1)
                       for s in cfg.encoder_stages[:1])
    return replace(
        cfg, name=cfg.name + "-reduced", d_model=d_model, d_ff=d_ff,
        vocab_size=vocab, stages=tuple(stages), encoder_stages=enc_stages,
        num_heads=heads, num_kv_heads=kv, head_dim=head_dim,
        kv_lora_rank=min(cfg.kv_lora_rank, 64) if cfg.kv_lora_rank else 0,
        qk_nope_dim=32 if cfg.qk_nope_dim else 0,
        qk_rope_dim=16 if cfg.qk_rope_dim else 0,
        v_head_dim=32 if cfg.v_head_dim else 0,
        num_experts=min(cfg.num_experts, experts) if cfg.num_experts else 0,
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2)
        if cfg.num_experts_per_tok else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        moe_d_ff=128 if cfg.moe_d_ff else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=32 if cfg.ssm_state else 64,
        ssm_chunk=32,
        rwkv_head_dim=32,
        encoder_seq_len=min(cfg.encoder_seq_len, 16),
        num_prefix_tokens=min(cfg.num_prefix_tokens, 8),
        prefix_dim=min(cfg.prefix_dim, 64) if cfg.prefix_dim else 0,
    )
