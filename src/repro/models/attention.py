"""Attention mixers: GQA (with qk-norm / sliding window / logit softcap),
MLA (DeepSeek-V2 compressed KV, absorbed decode path), and cross-attention.

Prefill/train attention is computed in query chunks (``lax.map`` over Q
blocks) so the [S, S] score matrix is never fully materialized — the pure-
XLA analogue of flash attention that the dry-run lowers (the Pallas flash
kernel in ``repro/kernels/flash_attention`` is the TPU hot path and is
validated against the same math).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.nnlib.core import normal_init, rmsnorm_init, rmsnorm_apply

Q_CHUNK = 1024     # static query block for chunked attention

# §Perf toggle: upcast k/v to f32 before the score/context einsums (True =
# baseline) vs keeping bf16 operands with f32 accumulation via
# preferred_element_type (False) — halves the HBM traffic of the upcast
# copies (EXPERIMENTS.md §Perf-3).
UPCAST_KV = True


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: jnp.ndarray, dim: int, theta: float
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions [...,] → cos/sin [..., dim/2]."""
    freq = 1.0 / theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray
               ) -> jnp.ndarray:
    """x [..., S, H, dh]; cos/sin [..., S, dh/2] (broadcast over heads)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c, s = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


def _softcap(scores: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": normal_init(ks[0], (d, h * dh), std=d ** -0.5),
        "wk": normal_init(ks[1], (d, kv * dh), std=d ** -0.5),
        "wv": normal_init(ks[2], (d, kv * dh), std=d ** -0.5),
        "wo": normal_init(ks[3], (h * dh, d), std=(h * dh) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh)
        p["k_norm"] = rmsnorm_init(dh)
    return p


def _chunked_scores_softmax(q, k, v, *, offset, causal, window, softcap,
                            kv_pos=None):
    """q [B,Sq,H,dh] against full k/v [B,Sk,KV,dh] in query chunks.

    offset: absolute position of q[0]. kv_pos: [Sk] absolute key positions
    (defaults to arange). Returns [B,Sq,H,dh]."""
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kvh
    scale = dh ** -0.5
    kv_pos = jnp.arange(sk) if kv_pos is None else kv_pos
    qc = Q_CHUNK if sq % Q_CHUNK == 0 and sq > Q_CHUNK else sq

    def block(args):
        qb, qpos = args                     # [B,qc,H,dh], [qc]
        qg = qb.reshape(b, qc, kvh, g, dh)
        if UPCAST_KV:
            s = jnp.einsum("bqkgd,bskd->bqkgs", qg.astype(jnp.float32),
                           k.astype(jnp.float32)) * scale
        else:
            s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k,
                           preferred_element_type=jnp.float32) * scale
        s = _softcap(s, softcap)
        m = jnp.ones((qc, sk), bool)
        if causal:
            m &= qpos[:, None] >= kv_pos[None, :]
        if window is not None:
            m &= qpos[:, None] - kv_pos[None, :] < window
        m &= kv_pos[None, :] >= 0           # −1 marks empty cache slots
        s = jnp.where(m[None, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        if UPCAST_KV:
            o = jnp.einsum("bqkgs,bskd->bqkgd", p, v.astype(jnp.float32))
        else:
            o = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(q.dtype), v,
                           preferred_element_type=jnp.float32)
        return o.reshape(b, qc, h, dv).astype(q.dtype)

    if qc == sq:
        return block((q, offset + jnp.arange(sq)))
    nc = sq // qc
    qs = q.reshape(b, nc, qc, h, dh).swapaxes(0, 1)
    pos = (offset + jnp.arange(sq)).reshape(nc, qc)
    out = jax.lax.map(block, (qs, pos))
    return out.swapaxes(0, 1).reshape(b, sq, h, dv)


def gqa_apply(cfg, spec, p, x, *, positions, cache=None):
    """x [B,S,d]. cache: None (train/prefill w/o cache) or dict for decode.

    Returns (out [B,S,d], new_cache)."""
    b, s, d = x.shape
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, kv, dh)
    v = (x @ p["wv"]).reshape(b, s, kv, dh)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_apply(p["k_norm"], k, cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache is None:
        out = _chunked_scores_softmax(
            q, k, v, offset=0, causal=True, window=spec.window,
            softcap=cfg.attn_logit_softcap)
        new_cache = None
    else:
        # decode: s == 1; ring-buffer cache of width W
        w = cache["k"].shape[1]
        pos = positions[0]                   # scalar absolute position
        slot = pos % w
        quant = "k_scale" in cache
        if quant:
            k_q, k_s = _quantize_kv(k)
            v_q, v_s = _quantize_kv(v)
            kw, vw = k_q, v_q
        else:
            kw, vw = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
        ck = jax.lax.dynamic_update_slice(cache["k"], kw, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vw, (0, slot, 0, 0))
        cpos = jax.lax.dynamic_update_slice(cache["pos"],
                                            pos[None].astype(jnp.int32),
                                            (slot,))
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        if quant:
            cks = jax.lax.dynamic_update_slice(cache["k_scale"], k_s,
                                               (0, slot, 0))
            cvs = jax.lax.dynamic_update_slice(cache["v_scale"], v_s,
                                               (0, slot, 0))
            new_cache["k_scale"] = cks
            new_cache["v_scale"] = cvs
            kr = ck.astype(jnp.bfloat16) * cks[..., None]
            vr = cv.astype(jnp.bfloat16) * cvs[..., None]
        else:
            kr, vr = ck, cv
        out = _chunked_scores_softmax(
            q, kr, vr, offset=pos, causal=True, window=spec.window,
            softcap=cfg.attn_logit_softcap, kv_pos=cpos)
    return (out.reshape(b, s, h * dh) @ p["wo"]), new_cache


def gqa_cache_init(cfg, spec, batch: int, max_len: int, dtype=jnp.bfloat16
                   ) -> dict:
    """dtype=jnp.int8 → quantized cache (per-token-per-head symmetric
    scales) — §Perf-3 optimization, halves cache HBM traffic on TPU."""
    w = max_len if spec.window is None else min(spec.window, max_len)
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    c = {"k": jnp.zeros((batch, w, kv, dh), dtype),
         "v": jnp.zeros((batch, w, kv, dh), dtype),
         "pos": jnp.full((w,), -1, jnp.int32)}
    if dtype == jnp.int8:
        c["k_scale"] = jnp.zeros((batch, w, kv), jnp.bfloat16)
        c["v_scale"] = jnp.zeros((batch, w, kv), jnp.bfloat16)
    return c


def _quantize_kv(x):
    """x [B,1,kv,dh] → (int8 values, [B,1,kv] scale)."""
    scale = jnp.max(jnp.abs(x), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------

def cross_init(key, cfg) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": normal_init(ks[0], (d, h * dh), std=d ** -0.5),
        "wk": normal_init(ks[1], (d, kv * dh), std=d ** -0.5),
        "wv": normal_init(ks[2], (d, kv * dh), std=d ** -0.5),
        "wo": normal_init(ks[3], (h * dh, d), std=(h * dh) ** -0.5),
    }


def cross_apply(cfg, p, x, enc_kv):
    """x [B,S,d] attends (unmasked) over precomputed encoder k/v."""
    b, s, _ = x.shape
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    out = _chunked_scores_softmax(q, enc_kv["k"], enc_kv["v"], offset=0,
                                  causal=False, window=None, softcap=None)
    return out.reshape(b, s, h * dh) @ p["wo"]


def cross_kv(cfg, p, enc_out):
    b, se, _ = enc_out.shape
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    return {"k": (enc_out @ p["wk"]).reshape(b, se, kv, dh),
            "v": (enc_out @ p["wv"]).reshape(b, se, kv, dh)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed KV cache + absorbed decode
# ---------------------------------------------------------------------------

def mla_init(key, cfg) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    ks = jax.random.split(key, 5)
    return {
        "wq": normal_init(ks[0], (d, h * (dn + dr)), std=d ** -0.5),
        "w_dkv": normal_init(ks[1], (d, r + dr), std=d ** -0.5),
        "w_uk": normal_init(ks[2], (r, h, dn), std=r ** -0.5),
        "w_uv": normal_init(ks[3], (r, h, dv), std=r ** -0.5),
        "wo": normal_init(ks[4], (h * dv, d), std=(h * dv) ** -0.5),
        "kv_norm": rmsnorm_init(r),
    }


def mla_apply(cfg, spec, p, x, *, positions, cache=None):
    b, s, d = x.shape
    h = cfg.num_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    scale = (dn + dr) ** -0.5
    q = (x @ p["wq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckr = x @ p["w_dkv"]
    c_kv = rmsnorm_apply(p["kv_norm"], ckr[..., :r], cfg.norm_eps)
    k_rope = ckr[..., r:][:, :, None, :]            # [B,S,1,dr] shared head
    cos, sin = rope_cos_sin(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)[:, :, 0]  # [B,S,dr]

    if cache is None:
        # prefill: expand the latent to per-head k/v (kv heads = h)
        k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uk"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, s, h, dr))], -1)
        v = jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uv"])
        q_full = jnp.concatenate([q_nope, q_rope], -1)   # roped rope-part
        out = _chunked_scores_softmax(q_full, k, v, offset=0, causal=True,
                                      window=spec.window, softcap=None)
        new_cache = None
    else:
        # decode: absorbed attention in the r-dim latent space
        pos = positions[0]
        w = cache["c_kv"].shape[1]
        slot = pos % w
        cc = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype)[:, :1],
            (0, slot, 0))
        cr = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype)[:, :1],
            (0, slot, 0))
        cpos = jax.lax.dynamic_update_slice(cache["pos"],
                                            pos[None].astype(jnp.int32),
                                            (slot,))
        q_lat = jnp.einsum("bthd,rhd->bthr", q_nope.astype(jnp.float32),
                           p["w_uk"].astype(jnp.float32))
        sc = jnp.einsum("bthr,bsr->bths", q_lat,
                        cc.astype(jnp.float32)) + \
            jnp.einsum("bthp,bsp->bths", q_rope.astype(jnp.float32),
                       cr.astype(jnp.float32))
        sc = sc * scale
        mask = (cpos >= 0) & (cpos <= pos)
        sc = jnp.where(mask[None, None, None, :], sc, -1e30)
        pr = jax.nn.softmax(sc, axis=-1)
        ctx = jnp.einsum("bths,bsr->bthr", pr, cc.astype(jnp.float32))
        out = jnp.einsum("bthr,rhv->bthv", ctx,
                         p["w_uv"].astype(jnp.float32)).astype(x.dtype)
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": cpos}
    return out.reshape(b, s, -1) @ p["wo"], new_cache


def mla_cache_init(cfg, spec, batch: int, max_len: int, dtype=jnp.bfloat16
                   ) -> dict:
    return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
            "pos": jnp.full((max_len,), -1, jnp.int32)}
