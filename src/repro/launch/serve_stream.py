"""Streaming serving launcher — open-loop load against the front-end.

    PYTHONPATH=src python -m repro.launch.serve_stream --devices 4 \
        --arrival-rate 50 --tenants 3 --deadline 2.0 --queue-depth 64

Drives the production-shaped request front of DESIGN.md §7
(:class:`repro.serve.StreamingFrontend` over the pipelined
:class:`repro.serve.ServingEngine`) with an **open-loop Poisson workload**:
``--count`` requests arrive at ``--arrival-rate`` req/s on their own
schedule regardless of service progress, spread over ``--tenants`` tenants
and ``--topologies`` distinct perturbed graph layouts, each carrying a
``--deadline``-second SLO budget. The front-end queues them (bounded at
``--queue-depth``, explicit ``queue_full`` backpressure), groups queued
requests sharing a cached plan into continuous batches of up to
``--max-batch``, runs the ``--admission`` controller (``lyapunov`` with
``--v``/``--theta`` drift-plus-penalty knobs, ``static`` priority, or
``admit_all``) and prints the SLO telemetry: per-phase
p50/p95/p99 latency, sustained req/s, and the conservation ledger
(admitted + rejected + deferred + migrated == submitted).

``--faults`` arms the deterministic chaos harness (DESIGN.md §9): a
comma-separated ``cycle:kind[:arg[:scale]]`` schedule of server failures /
recoveries / degradations and user arrival/departure waves, applied at
pump-cycle boundaries through :class:`repro.serve.FaultInjector`. Server
events reprice the network, migrate every queued request to a warm-recut
plan (nothing is lost — the conservation ledger still balances) and are
reported with per-fault recovery latency.

Every served output is checked against the single-device ``gcn_apply``
oracle — batched members must match the sequential result exactly.
(Entry-point orientation: see the ``repro.launch`` package docstring.)
"""
from __future__ import annotations

import argparse

from repro.launch.serve_gnn import _ensure_virtual_devices


def _parse_args() -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--users", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=0,
                    help="graph-state capacity (0 → users + 8)")
    ap.add_argument("--features", type=int, default=32)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--arrival-rate", type=float, default=50.0,
                    help="open-loop Poisson arrival rate, requests/sec")
    ap.add_argument("--count", type=int, default=64,
                    help="total requests injected by the workload")
    ap.add_argument("--tenants", type=int, default=3,
                    help="requests round-robin over this many tenant ids")
    ap.add_argument("--deadline", type=float, default=2.0,
                    help="per-request SLO budget in seconds (0 → none)")
    ap.add_argument("--queue-depth", type=int, default=64,
                    help="bounded request queue; overflow is rejected "
                         "with reason queue_full")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="continuous-batching cap (bucketed to powers "
                         "of two)")
    ap.add_argument("--topologies", type=int, default=2,
                    help="distinct perturbed graph layouts cycled through "
                         "the stream (each is one plan-cache entry)")
    ap.add_argument("--admission", default="lyapunov",
                    choices=("lyapunov", "static", "admit_all"))
    ap.add_argument("--v", type=float, default=1.0,
                    help="lyapunov drift-plus-penalty weight V")
    ap.add_argument("--theta", type=float, default=8.0,
                    help="lyapunov admission backlog bound θ")
    ap.add_argument("--tenant-weights", default="",
                    help="weighted per-tenant service shares for the "
                         "lyapunov controller, e.g. '0:3,1:1' (tenants "
                         "not listed default to weight 1)")
    ap.add_argument("--faults", default="",
                    help="deterministic fault schedule: comma-separated "
                         "'cycle:kind[:arg[:scale]]' items, e.g. "
                         "'2:server_down:1,4:arrive:6,7:server_up:1' "
                         "(kinds: server_down, server_up, degrade, arrive, "
                         "depart; cycles are pump cycles)")
    ap.add_argument("--faults-seed", type=int, default=0,
                    help="rng seed for fault-schedule user-churn waves")
    ap.add_argument("--cross-topology", action="store_true",
                    help="batch requests across topologies: one dispatch "
                         "serves different cached plans padded to a "
                         "shared shape bucket")
    ap.add_argument("--threaded", action="store_true",
                    help="concurrent intake: a producer thread injects "
                         "arrivals while the pump loop dispatches")
    ap.add_argument("--plan-cache-size", type=int, default=16)
    ap.add_argument("--partitioner", default="hicut_jax")
    ap.add_argument("--policy", default="greedy_jit")
    ap.add_argument("--change-rate", type=float, default=0.2)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args()


def _fmt_phase(name: str, block: dict) -> str:
    return (f"  {name:<10s} p50={block['p50'] * 1e3:8.2f}ms  "
            f"p95={block['p95'] * 1e3:8.2f}ms  "
            f"p99={block['p99'] * 1e3:8.2f}ms  "
            f"max={block['max'] * 1e3:8.2f}ms")


def main() -> None:
    args = _parse_args()
    _ensure_virtual_devices(args.devices)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import costs
    from repro.core.api import GraphEdgeController
    from repro.core.dynamic_graph import perturb_scenario, random_scenario
    from repro.gnn.layers import gcn_apply, gcn_init
    from repro.serve import (AdmitAll, FaultInjector, FaultSchedule,
                             LyapunovAdmission, ServingEngine,
                             StaticPriorityAdmission, StreamRequest,
                             StreamingFrontend, poisson_workload)

    rng = np.random.default_rng(args.seed)
    capacity = args.capacity or args.users + 8
    devices = min(args.devices, len(jax.devices()))
    net = costs.default_network(rng, capacity, args.devices)
    controller = GraphEdgeController(net=net, policy=args.policy,
                                     partitioner=args.partitioner)
    params = gcn_init(jax.random.PRNGKey(args.seed),
                      [args.features, args.hidden, args.classes])
    mesh = Mesh(np.array(jax.devices()[:devices]), ("servers",))
    engine = ServingEngine(controller=controller, params=params, mesh=mesh,
                           axis="servers", num_devices=devices,
                           plan_cache_size=args.plan_cache_size)

    if args.admission == "lyapunov":
        weights = {}
        for pair in filter(None, args.tenant_weights.split(",")):
            tenant, _, w = pair.partition(":")
            weights[int(tenant)] = float(w)
        admission = LyapunovAdmission(num_tenants=args.tenants, v=args.v,
                                      theta=args.theta, weights=weights)
        if weights:
            print(f"tenant weights: {weights} (starvation bound from "
                  f"backlog θ+4: "
                  + ", ".join(
                      f"τ{t}≤{admission.starvation_bound(t, args.theta + 4)}"
                      f" cycles" for t in range(args.tenants)))
    elif args.admission == "static":
        admission = StaticPriorityAdmission()
    else:
        admission = AdmitAll()
    states = [random_scenario(rng, capacity, args.users, 3 * args.users)]
    for _ in range(args.topologies - 1):
        states.append(perturb_scenario(rng, states[-1], args.change_rate))
    deadline = args.deadline if args.deadline > 0 else None

    faults = None
    if args.faults:
        faults = FaultInjector(FaultSchedule.parse(args.faults), net,
                               state=states[0], seed=args.faults_seed)
    frontend = StreamingFrontend(engine=engine,
                                 queue_depth=args.queue_depth,
                                 max_batch=args.max_batch,
                                 admission=admission,
                                 cross_topology=args.cross_topology,
                                 faults=faults)

    def make_request(i: int) -> StreamRequest:
        # under fault churn the injector's evolving layout is the request
        # source (lazy workload: snapshotted at arrival, not construction)
        state = faults.state if faults is not None and \
            faults.state is not None else states[i % len(states)]
        x = rng.normal(size=(capacity, args.features)).astype(np.float32)
        return StreamRequest(state, x,
                             tenant=i % args.tenants, deadline=deadline)

    print(f"streaming {args.count} requests @ {args.arrival_rate} req/s "
          f"(open loop): {args.tenants} tenants, {args.topologies} "
          f"topologies, deadline={args.deadline}s, "
          f"queue_depth={args.queue_depth}, max_batch={args.max_batch}, "
          f"admission={args.admission}, {devices} mesh devices")
    workload = poisson_workload(rng, args.arrival_rate, args.count,
                                make_request, lazy=faults is not None)
    results = frontend.run_threaded(workload) if args.threaded \
        else frontend.run(workload)

    err = 0.0
    for res in results:
        st = res.request.state
        oracle = np.asarray(gcn_apply(params, jnp.asarray(res.request.x),
                                      st.adj, st.mask))
        served = np.nonzero(np.asarray(st.mask) > 0)[0]
        err = max(err, float(np.abs(res.output[served] -
                                    oracle[served]).max()))
    assert err < 1e-4, "streamed serve diverged from the oracle"

    stats = frontend.stats.as_dict()
    summary = frontend.slo_summary()
    print(f"served {stats['served']}/{stats['submitted']} "
          f"(admitted={stats['admitted']}, "
          f"rejected={stats['rejected_total']} {stats['rejected']}, "
          f"defer_events={stats['defer_events']})  "
          f"conservation={'ok' if stats['conservation_ok'] else 'VIOLATED'}")
    print(f"batches={stats['batches']} "
          f"batched_requests={stats['batched_requests']} "
          f"cross_batches={stats['cross_batches']}  "
          f"|serve - oracle|max={err:.2e}")
    cyc = frontend.cycles.as_dict()
    if cyc["cycles"]:
        print(f"cycles={cyc['cycles']} batch_hist={cyc['batch_hist']} "
              f"decide p50={cyc['decide']['p50'] * 1e3:.2f}ms "
              f"p95={cyc['decide']['p95'] * 1e3:.2f}ms")
    if summary.get("served"):
        print(f"sustained {summary['sustained_rps']:.2f} req/s")
        for phase in ("queue_wait", "decide", "forward", "total"):
            print(_fmt_phase(phase, summary[phase]))
    pc = engine.plan_cache_info()
    print(f"plan cache: {pc.hits} hits / {pc.misses} misses "
          f"({pc.currsize}/{pc.maxsize} entries)")
    if faults is not None:
        print(f"faults: migrated={stats['requests_migrated']} "
              f"(served {stats['migrated_served']})  "
              f"net_swaps={engine.net_swaps}  "
              f"servers up={faults.num_up}/{args.devices}")
        for rec in frontend.fault_trace:
            kinds = ",".join(e["kind"] for e in rec["events"])
            print(f"  cycle {rec['cycle']}: {kinds}  "
                  f"queued={rec['queued']} migrated={rec['migrated']} "
                  f"recut={rec['recut_topologies']} "
                  f"recovery={rec.get('recovery_cycles', '-')} cycles")
    assert stats["conservation_ok"], "request accounting does not conserve"


if __name__ == "__main__":
    main()
