"""Multi-host SPMD serving launcher — simulated process grids on one box.

    PYTHONPATH=src python -m repro.launch.serve_multihost --processes 2 \
        --devices 4 --vertices 100000 --edges 300000 --steps 5

Promotes serving to true SPMD over a process grid (DESIGN.md §8): the
parent spawns ``--processes`` worker copies of itself, each pinned to
``--devices / --processes`` virtual CPU devices
(``--xla_force_host_platform_device_count``), wired together with
``jax.distributed.initialize`` over a local coordinator and the gloo CPU
collectives backend. Every worker builds only its own shard of the
partition plan (:func:`repro.gnn.multihost.make_partition_plan_shard`),
keeps its feature blocks resident (:func:`put_feature_blocks`), and the
forward exchanges *only halo rows* between processes — an ``all_to_all``
over exactly the cut edges (``--exchange pair``; ``gather`` serves the
all-gather layout for comparison).

Two arms share every flag:

* ``--arm resident`` — the multi-host path: sharded plan cache
  (:class:`repro.gnn.multihost.ShardedPlanCache`, keyed identically on
  every process), resident features, halo-only exchange. Outputs stay
  sharded on their owning hosts.
* ``--arm engine`` — the single-process serving engine's data path on the
  same graph (one full plan build, per-step ``plan.scatter`` → jitted
  forward on replicated blocks → ``plan.gather``): the replicate-
  everything baseline the bench compares against. Single process only.

Process 0 prints one JSON record (steps/sec, halo vs replicate bytes per
step, parity against ``--ref-in``); ``--json-out`` also writes it to a
file — that is the interface ``benchmarks/bench_serving.py``'s multihost
arm drives. ``--ref-out`` saves the gathered output for cross-host-count
parity: resident arms at different ``--processes`` must match **bitwise**
(the collectives only move rows; every per-device instruction sequence is
identical).

Importing this module has no side effects; env mutation happens inside
worker ``main`` before jax is imported (same contract as ``serve_gnn``).
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import time


def _parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--processes", type=int, default=1,
                    help="simulated hosts (spawned worker subprocesses)")
    ap.add_argument("--devices", type=int, default=4,
                    help="total mesh devices across all processes")
    ap.add_argument("--vertices", type=int, default=100_000)
    ap.add_argument("--edges", type=int, default=300_000)
    ap.add_argument("--cross-frac", type=float, default=0.01,
                    help="fraction of cross-community edge draws")
    ap.add_argument("--features", type=int, default=32)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--arm", choices=("resident", "engine"),
                    default="resident")
    ap.add_argument("--exchange", choices=("pair", "gather"),
                    default="pair")
    ap.add_argument("--aggregate", default="auto")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default="",
                    help="write process 0's JSON record to this path")
    ap.add_argument("--ref-out", default="",
                    help="save the gathered output (.npy) for parity")
    ap.add_argument("--ref-in", default="",
                    help="compare the output against this .npy (max err)")
    ap.add_argument("--quick", action="store_true",
                    help="shrink to a smoke-size graph")
    # internal: set by the spawning parent for worker subprocesses
    ap.add_argument("--process-id", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--coordinator", default="",
                    help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.quick:
        args.vertices = min(args.vertices, 20_000)
        args.edges = min(args.edges, 60_000)
    return args


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _spawn(args: argparse.Namespace) -> int:
    """Parent: launch one worker per simulated host and relay process 0."""
    port = _free_port()
    per = args.devices // args.processes
    assert per * args.processes == args.devices, \
        (args.devices, args.processes)
    cmd_base = [sys.executable, "-m", "repro.launch.serve_multihost",
                "--coordinator", f"127.0.0.1:{port}"]
    passthrough = ["--processes", str(args.processes),
                   "--devices", str(args.devices),
                   "--vertices", str(args.vertices),
                   "--edges", str(args.edges),
                   "--cross-frac", str(args.cross_frac),
                   "--features", str(args.features),
                   "--hidden", str(args.hidden),
                   "--classes", str(args.classes),
                   "--steps", str(args.steps),
                   "--arm", args.arm, "--exchange", args.exchange,
                   "--aggregate", args.aggregate,
                   "--seed", str(args.seed)]
    for opt, val in (("--json-out", args.json_out),
                     ("--ref-out", args.ref_out),
                     ("--ref-in", args.ref_in)):
        if val:
            passthrough += [opt, val]
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={per}"
    src = os.path.join(os.path.dirname(__file__), "..", "..")
    env["PYTHONPATH"] = os.path.abspath(src) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    procs = [subprocess.Popen(
        cmd_base + passthrough + ["--process-id", str(i)],
        env=env, stdout=subprocess.PIPE if i else None,
        stderr=subprocess.STDOUT if i else None)
        for i in range(args.processes)]
    rc = 0
    for i, pr in enumerate(procs):
        out, _ = pr.communicate(timeout=1800)
        if pr.returncode != 0:
            rc = pr.returncode or 1
            if out:
                sys.stderr.write(out.decode(errors="replace")[-4000:])
    return rc


def _worker(args: argparse.Namespace) -> int:
    nproc = args.processes
    pid = args.process_id or 0
    if "jax" not in sys.modules and \
            "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count"
            f"={args.devices // nproc}").strip()
    import jax
    if nproc > 1:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(args.coordinator, nproc, pid)
    import numpy as np
    from jax.sharding import Mesh

    from repro.data.graphs import community_graph
    from repro.gnn.layers import gcn_init
    from repro.gnn.multihost import (ShardedPlanCache, fetch_global,
                                     put_feature_blocks)

    assert len(jax.devices()) == args.devices, \
        (len(jax.devices()), args.devices)
    mesh = Mesh(np.array(jax.devices()), ("servers",))
    n = args.vertices
    edges, assign = community_graph(n, args.edges, args.devices,
                                    cross_frac=args.cross_frac,
                                    seed=args.seed)
    rng = np.random.default_rng(args.seed + 1)
    x = rng.normal(size=(n, args.features)).astype(np.float32)
    dims = [args.features, args.hidden, args.classes]
    params = gcn_init(jax.random.PRNGKey(args.seed), dims)
    layer_widths = dims[1:]          # exchanged row width per layer (dense/
    #                                  sparse aggregate post-matmul widths)

    t0 = time.perf_counter()
    if args.arm == "resident":
        cache = ShardedPlanCache(mesh, "servers", exchange=args.exchange,
                                 aggregate=args.aggregate)
        _, shard, forward, _ = cache.entry(edges, assign, args.devices)
        plan_s = time.perf_counter() - t0
        xb = put_feature_blocks(mesh, "servers", shard, x)
        out = jax.block_until_ready(forward(xb, params))     # warm compile
        # verify the shard caches agree across hosts (keyed identically)
        _, _, _, hit = cache.entry(edges, assign, args.devices)
        assert hit, "plan shard cache must hit on the same topology"
        t0 = time.perf_counter()
        for _ in range(args.steps):
            out = jax.block_until_ready(forward(xb, params))
        dt = time.perf_counter() - t0
        gathered = shard.gather(fetch_global(out))
        halo, block = shard.halo, shard.block
        pb = shard.bytes_per_aggregate
        rb = shard.replicate_bytes_per_aggregate
    else:
        assert nproc == 1, "--arm engine is the single-process baseline"
        from repro.gnn.distributed import (make_forward_fn,
                                           make_partition_plan_sparse)
        plan = make_partition_plan_sparse(edges, assign, args.devices, n=n,
                                          exchange=args.exchange)
        forward = make_forward_fn(mesh, "servers", plan, args.aggregate)
        plan_s = time.perf_counter() - t0
        gathered = plan.gather(np.asarray(
            forward(plan.scatter(x), params)))               # warm compile
        t0 = time.perf_counter()
        for _ in range(args.steps):
            out = forward(plan.scatter(x), params)
            gathered = plan.gather(np.asarray(out))
        dt = time.perf_counter() - t0
        halo, block = plan.halo, plan.block
        pb = plan.bytes_per_aggregate
        rb = plan.replicate_bytes_per_aggregate

    rec = {
        "mode": "multihost", "arm": args.arm, "hosts": nproc,
        "devices": args.devices, "n": n, "edges": int(len(edges)),
        "exchange": args.exchange, "block": int(block), "halo": int(halo),
        "steps": args.steps, "steps_per_s": args.steps / dt,
        "plan_build_s": plan_s,
        "halo_bytes_per_step": sum(pb(w) for w in layer_widths),
        "replicate_bytes_per_step": sum(rb(w) for w in layer_widths),
    }
    rec["halo_frac"] = (rec["halo_bytes_per_step"]
                        / max(rec["replicate_bytes_per_step"], 1))
    if args.ref_in:
        ref = np.load(args.ref_in)
        rec["parity_max_err"] = float(np.abs(gathered - ref).max())
    if pid == 0:
        if args.ref_out:
            np.save(args.ref_out, gathered)
        line = json.dumps(rec)
        print(line, flush=True)
        if args.json_out:
            with open(args.json_out, "w") as f:
                f.write(line + "\n")
    return 0


def main(argv=None) -> int:
    args = _parse_args(argv)
    if args.process_id is None and args.processes > 1:
        return _spawn(args)
    return _worker(args)


if __name__ == "__main__":
    raise SystemExit(main())
