"""Framework training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 50 [--ckpt /tmp/lm.npz]

Runs the same ``make_train_step`` the multi-pod dry-run lowers — on this
CPU container with ``--reduced`` dims; on a real TPU slice the identical
code path runs the full config under ``make_production_mesh()`` with the
FSDP+TP+SP shardings (``--production`` wires them; it requires the real
device count and is exercised offline by the dry-run).

This is the *LM framework* trainer (see the ``repro.launch`` package
docstring for the entry-point table). GraphEdge's DRLGO offloading policy
is trained by ``examples/train_drlgo.py`` instead.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.registry import get_config, list_archs
from repro.data.tokens import TokenDataConfig, token_batches
from repro.models import transformer as T
from repro.models.config import reduced as reduce_cfg
from repro.optim.adamw import AdamWConfig, cosine_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--production", action="store_true",
                    help="full config on make_production_mesh() (TPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shard_ctx = None
    in_shardings = None
    if args.production:
        from repro.launch.mesh import make_production_mesh
        from repro.launch.shardings import (activation_shard_ctx,
                                            param_shardings)
        mesh = make_production_mesh()
        shard_ctx = activation_shard_ctx(cfg, mesh, args.seq, args.batch)
    else:
        cfg = reduce_cfg(cfg, d_model=args.d_model)

    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    opt = T.init_opt(params)
    opt_cfg = AdamWConfig(
        lr=cosine_schedule(args.lr, args.warmup, args.steps),
        weight_decay=0.01)
    step = jax.jit(T.make_train_step(
        cfg, opt_cfg, shard_ctx=shard_ctx,
        compute_dtype=jnp.bfloat16 if args.production else None,
        microbatches=args.microbatches))

    data = token_batches(TokenDataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=args.seq,
                                         batch_size=args.batch,
                                         seed=args.seed))
    extras = {}
    if cfg.num_prefix_tokens and cfg.prefix_dim:
        extras["prefix_emb"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.num_prefix_tokens, cfg.prefix_dim))
    if cfg.encoder_stages:
        extras["frames"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.encoder_seq_len, cfg.prefix_dim))

    t0 = time.time()
    for i, batch in zip(range(args.steps), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()} | extras
        params, opt, m = step(params, opt, batch)
        if (i + 1) % max(args.steps // 10, 1) == 0:
            tps = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"step {i + 1:5d}  loss {float(m['loss']):.4f}  "
                  f"{tps:,.0f} tok/s", flush=True)
    if args.ckpt:
        ckpt.save(args.ckpt, {"params": params})
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
