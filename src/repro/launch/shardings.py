"""GSPMD sharding rules (MaxText-flavored FSDP + TP).

* params: 2-D weight matrices shard [in → 'data' (FSDP/ZeRO), out → 'model'
  (TP)] where divisible; embeddings [vocab → 'model', d → 'data']; MoE
  expert tensors use expert-parallel over 'model' when the expert count
  divides the axis (deepseek 64e), else TP inside the expert (mixtral 8e).
  Optimizer state mirrors the params (ZeRO falls out for free).
* batch: [('pod','data'), …]; batch-1 shapes (long_500k) replicate batch.
* residual stream: [batch, 'model', d] — Megatron-style sequence parallel.
* decode caches: [batch-sharded B, sequence → 'model' (+'data' when B = 1),
  heads/state → 'model' where divisible].

Every rule degrades to replication when a dim is not divisible by the mesh
axis, so any (arch × shape × mesh) combination lowers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, batch_axes
from repro.optim.adamw import AdamState


def _maybe(axis, dim: int, mesh) -> str | None:
    """Use ``axis`` only if ``dim`` divides evenly on the mesh."""
    if axis is None:
        return None
    sizes = [axis_size(mesh, a) for a in
             (axis if isinstance(axis, tuple) else (axis,))]
    total = 1
    for s in sizes:
        total *= s
    return axis if total > 1 and dim % total == 0 else None


# base specs by parameter name (without scan-stacking leading dims)
_IN_OUT = ("data", "model")        # [in, out]
_OUT_IN = ("model", "data")        # [out, in]
_RULES: dict[str, tuple] = {
    "embed": ("model", "data"),
    "lm_head": _IN_OUT,
    "prefix_proj": _IN_OUT,
    "in_proj": _IN_OUT,
    "wq": _IN_OUT,
    "wk": ("data", None),
    "wv": ("data", None),
    "wo": _OUT_IN,
    "w_gate": _IN_OUT, "w_up": _IN_OUT, "w_down": _OUT_IN,
    "router": ("data", None),
    "w_dkv": ("data", None),
    "w_uk": (None, "model", None),
    "w_uv": (None, "model", None),
    "w_in": _IN_OUT, "w_out": _OUT_IN,
    "conv_w": (None, "model"),
    "w_r": _IN_OUT, "w_k": _IN_OUT, "w_v": _OUT_IN, "w_g": _IN_OUT,
    "w_o": _IN_OUT,
    "w_lora_a": ("data", None), "w_lora_b": (None, "model"),
}


def _spec_for_leaf(path, shape, mesh) -> P:
    names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    name = names[-1]
    nd = len(shape)
    if name in ("we_gate", "we_up", "we_down"):
        e = shape[-3]
        if _maybe("model", e, mesh):
            base = ("model", "data", None) if name != "we_down" else \
                ("model", None, "data")
        else:
            base = (None, "data", "model") if name != "we_down" else \
                (None, "model", "data")
    elif name in _RULES:
        base = _RULES[name]
    else:
        base = ()                     # norms, biases, scalars: replicate
    base = tuple(base[-nd:]) if nd >= len(base) else tuple(base[:nd])
    pad = (None,) * (nd - len(base))
    dims = shape[nd - len(base):]
    resolved = tuple(_maybe(a, d, mesh) for a, d in zip(base, dims))
    return P(*(pad + resolved))


def param_shardings(params, mesh):
    """NamedSharding tree mirroring ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = [NamedSharding(mesh, _spec_for_leaf(p, l.shape, mesh))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_shardings(param_sh, mesh):
    scalar = NamedSharding(mesh, P())
    return AdamState(step=scalar, mu=param_sh,
                     nu=jax.tree_util.tree_map(lambda s: s, param_sh))


def batch_shardings(batch_sds, mesh):
    """Shard the leading batch dim over ('pod','data') where divisible."""
    ba = batch_axes(mesh)

    def spec(sds):
        b = sds.shape[0]
        axis = _maybe(ba, b, mesh)
        return NamedSharding(mesh, P(axis, *([None] * (len(sds.shape) - 1))))
    return jax.tree_util.tree_map(spec, batch_sds)


def activation_shard_ctx(cfg, mesh, seq_len: int, batch: int) -> dict:
    """shard_ctx passed into forward/decode (residual-stream constraints)."""
    ba = _maybe(batch_axes(mesh), batch, mesh)
    seq = _maybe("model", seq_len, mesh)
    return {
        "residual": NamedSharding(mesh, P(ba, seq, None)),
        "decode_residual": NamedSharding(mesh, P(ba, None, None)),
        # MoE dispatch operands: batch over data; expert/cap/d left to TP
        "moe_tok": NamedSharding(mesh, P(ba, None, None)),
        "moe_route": NamedSharding(mesh, P(ba, None, None)),
        # expert buffers follow the expert-weight sharding: EP over 'model'
        # when the expert count divides the axis (deepseek 64e), else TP on
        # the expert hidden dim (mixtral 8e)
        "moe_xe": NamedSharding(mesh, P(
            ba, _maybe("model", cfg.num_experts, mesh), None, None)),
        "moe_he": NamedSharding(mesh, P(
            ba, _maybe("model", cfg.num_experts, mesh), None,
            None if _maybe("model", cfg.num_experts, mesh) else "model")),
    }


def cache_shardings(cfg, cache, mesh, batch: int):
    """Decode-cache sharding: leaves are [reps, B, ...]."""
    ba = _maybe(batch_axes(mesh), batch, mesh)
    seq_axes = "model" if ba is not None else ("data", "model")

    def spec(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        shp = leaf.shape
        if name in ("k", "v"):                       # [reps,B,W,kv,dh]
            if len(shp) == 5:
                return P(None, ba, _maybe(seq_axes, shp[2], mesh), None, None)
            return P(ba, _maybe(seq_axes, shp[1], mesh), None, None)
        if name in ("c_kv", "k_rope"):               # [reps,B,W,r]
            return P(None, ba, _maybe(seq_axes, shp[2], mesh), None)
        if name in ("k_scale", "v_scale"):           # [reps,B,W,kv]
            return P(None, ba, _maybe(seq_axes, shp[2], mesh), None)
        if name == "pos":
            return P(*([None] * len(shp)))
        if name == "state":                          # [reps,B,H,P,N]
            return P(None, ba, _maybe("model", shp[2], mesh), None, None)
        if name == "conv":                           # [reps,B,K,C]
            return P(None, ba, None, _maybe("model", shp[3], mesh))
        if name in ("x_prev", "cm_prev"):            # [reps,B,1,d]
            return P(None, ba, None, None)
        return P(*([None] * len(shp)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = [NamedSharding(mesh, spec(p, l)) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)
