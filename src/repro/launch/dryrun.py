"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

The ``os.environ`` line below MUST run before any other import — jax locks
the device count on first init, and only the dry-run wants 512 placeholder
host devices (smoke tests and benches see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k [--multipod] [--json out.json]

Prints ``compiled.memory_analysis()`` (proves the per-device footprint
fits 16 GB HBM) and ``cost_analysis()`` FLOPs/bytes, plus the §Roofline
terms derived from the compiled HLO. (Entry-point orientation: see the
``repro.launch`` package docstring.)
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (INPUT_SHAPES, applicable, decode_specs,
                                 input_specs, params_specs)
from repro.launch.shardings import (activation_shard_ctx, batch_shardings,
                                    cache_shardings, opt_shardings,
                                    param_shardings)
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, AdamState

HBM_PER_CHIP = 16e9   # v5e

# §Perf toggle: bf16 backward/gradient reductions (True = optimized
# default; False = f32-backward baseline for the §Perf log)
BF16_GRADS = True

# §Perf toggle: int8-quantized KV cache for decode shapes (§Perf-3)
KV_INT8 = False

# grad-accumulation microbatch count for the train shape (memory-bound
# archs need >1 to fit activation transients in 16 GB/chip)
TRAIN_MICROBATCHES = {
    "mixtral-8x7b": 4,            # µb=2 would cut collectives 13% but OOMs
    "deepseek-v2-lite-16b": 2,    # §Perf: 4→2 confirmed (−8% collective)
    "internvl2-26b": 2,           # §Perf: 4→2 confirmed (−46% collective)
    "gemma2-9b": 2,               # µb=1 cuts collectives 33% but OOMs (19.8 GB)
    "rwkv6-7b": 1,                # §Perf: 2→1 confirmed (−26% collective, fits)
    "zamba2-2.7b": 2,             # µb=1 OOMs (23.0 GB)
}


def _override_reps(cfg, reps_map: dict[int, int]):
    """Config variant with per-stage rep counts replaced (cost calibration)."""
    import dataclasses
    from repro.models.config import Stage
    stages = tuple(
        Stage(unit=s.unit, reps=reps_map.get(i, s.reps))
        for i, s in enumerate(cfg.stages))
    enc = tuple(
        Stage(unit=s.unit, reps=reps_map.get(("enc", i), s.reps))
        for i, s in enumerate(cfg.encoder_stages))
    return dataclasses.replace(cfg, stages=stages, encoder_stages=enc)


def lower_combo(arch: str, shape_name: str, multi_pod: bool,
                donate: bool = True, extra_shard_ctx=None,
                unroll: bool = False, reps_map: dict | None = None):
    cfg = get_config(arch)
    if reps_map is not None:
        cfg = _override_reps(cfg, reps_map)
    shape = INPUT_SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multipod": multi_pod,
                "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    num_chips = mesh.size
    shard_ctx = activation_shard_ctx(
        cfg, mesh, shape.seq_len, shape.global_batch)
    if extra_shard_ctx:
        shard_ctx.update(extra_shard_ctx)
    t0 = time.time()

    if shape.kind == "train":
        p_sds = params_specs(cfg)                      # fp32 master
        p_sh = param_shardings(p_sds, mesh)
        shard_ctx["params_sh"] = p_sh                  # bf16 cast stays sharded
        o_sds = jax.eval_shape(lambda: AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p_sds),
            nu=jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p_sds)))
        o_sh = opt_shardings(p_sh, mesh)
        b_sds = input_specs(cfg, shape)
        b_sh = batch_shardings(b_sds, mesh)
        step = T.make_train_step(cfg, AdamWConfig(lr=3e-4),
                                 shard_ctx=shard_ctx,
                                 compute_dtype=jnp.bfloat16, unroll=unroll,
                                 microbatches=TRAIN_MICROBATCHES.get(
                                     arch, 1),
                                 bf16_grads=BF16_GRADS)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1) if donate else ())
        lowered = fn.lower(p_sds, o_sds, b_sds)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * cfg.active_param_count() * tokens
    elif shape.kind == "prefill":
        p_sds = params_specs(cfg, dtype=jnp.bfloat16)  # serving weights
        p_sh = param_shardings(p_sds, mesh)
        b_sds = input_specs(cfg, shape)
        b_sh = batch_shardings(b_sds, mesh)

        def prefill(params, batch):
            logits, _ = T.forward(cfg, params, batch, shard_ctx=shard_ctx,
                                  unroll=unroll)
            return logits
        fn = jax.jit(prefill, in_shardings=(p_sh, b_sh))
        lowered = fn.lower(p_sds, b_sds)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * cfg.active_param_count() * tokens
    else:  # decode
        p_sds = params_specs(cfg, dtype=jnp.bfloat16)
        p_sh = param_shardings(p_sds, mesh)
        io, cache_sds, _ = decode_specs(
            cfg, shape,
            cache_dtype=jnp.int8 if KV_INT8 else jnp.bfloat16)
        c_sh = cache_shardings(cfg, cache_sds, mesh, shape.global_batch)
        tok_sh = batch_shardings({"token": io["token"]}, mesh)["token"]
        from jax.sharding import NamedSharding, PartitionSpec as P
        pos_sh = NamedSharding(mesh, P())

        def serve_step(params, cache, token, pos):
            return T.decode_step(cfg, params, cache, token, pos,
                                 shard_ctx=shard_ctx, unroll=unroll)
        fn = jax.jit(serve_step,
                     in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                     out_shardings=(None, c_sh),
                     donate_argnums=(1,) if donate else ())
        lowered = fn.lower(p_sds, cache_sds, io["token"], io["pos"])
        tokens = shape.global_batch * 1
        model_flops = 2.0 * cfg.active_param_count() * tokens

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    rf = RL.analyze(compiled, num_chips=num_chips, model_flops=model_flops,
                    hlo_text=hlo)
    mem_total = (mem.temp_size_in_bytes + mem.argument_size_in_bytes +
                 mem.output_size_in_bytes - mem.alias_size_in_bytes)
    result = {
        "arch": arch, "shape": shape_name, "multipod": multi_pod,
        "num_chips": num_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": mem_total,
            "fits_hbm": bool(mem_total <= HBM_PER_CHIP),
        },
        "roofline": RL.to_dict(rf),
    }
    return result


def calibrated(arch: str, shape_name: str, multi_pod: bool) -> dict:
    """Full scanned compile (memory proof + schedule) + exact roofline.

    ``cost_analysis`` counts a while-loop (scan) body once, not
    ×trip-count, so the scanned numbers undercount. We calibrate with tiny
    *unrolled* variants: A = all stages at 1 rep, and per-stage variants at
    2 reps; body_s = variant_s − A; exact = A + Σ (reps_s − 1)·body_s.
    Exact for FLOPs; near-exact for bytes/collectives (layout may shift
    slightly between variants — noted in EXPERIMENTS.md)."""
    full = lower_combo(arch, shape_name, multi_pod)
    if "skipped" in full:
        return full
    cfg = get_config(arch)
    keys = list(range(len(cfg.stages))) + \
        [("enc", i) for i in range(len(cfg.encoder_stages))]
    reps_of = {}
    for i, s in enumerate(cfg.stages):
        reps_of[i] = s.reps
    for i, s in enumerate(cfg.encoder_stages):
        reps_of[("enc", i)] = s.reps
    base_map = {k: 1 for k in keys}
    a = lower_combo(arch, shape_name, multi_pod, unroll=True,
                    reps_map=base_map)

    def raw(res):
        r = res["roofline"]
        out = {"flops": r["flops_per_device"], "bytes": r["bytes_per_device"],
               "coll": r["coll_bytes_per_device"]}
        out.update({f"c_{k}": v for k, v in r["coll_breakdown"].items()})
        return out

    totals = dict(raw(a))
    calib = {"A_compile_s": a["compile_s"], "variants": []}
    for k in keys:
        if reps_of[k] <= 1:
            continue
        vmap = dict(base_map)
        vmap[k] = 2
        v = lower_combo(arch, shape_name, multi_pod, unroll=True,
                        reps_map=vmap)
        body = {kk: max(0.0, raw(v)[kk] - raw(a)[kk]) for kk in raw(a)}
        calib["variants"].append({"stage": str(k), "reps": reps_of[k],
                                  "compile_s": v["compile_s"],
                                  "body": body})
        for kk in totals:
            totals[kk] += (reps_of[k] - 1) * body[kk]

    rf = full["roofline"]
    model_flops = rf["model_flops"]
    compute_s = totals["flops"] / RL.PEAK_FLOPS
    memory_s = totals["bytes"] / RL.HBM_BW
    collective_s = totals["coll"] / RL.ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    full["roofline_exact"] = {
        "flops_per_device": totals["flops"],
        "bytes_per_device": totals["bytes"],
        "coll_bytes_per_device": totals["coll"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": max(terms, key=terms.get),
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / max(
            totals["flops"] * full["num_chips"], 1.0),
        "coll_breakdown": {k[2:]: v for k, v in totals.items()
                           if k.startswith("c_") and k != "c_count"},
        "calibration": calib,
    }
    return full


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="scanned compile only, skip roofline calibration")
    # §Perf experiment toggles
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-ye-constraint", action="store_true")
    ap.add_argument("--no-upcast-kv", action="store_true")
    ap.add_argument("--moe-bf16-reduce", action="store_true")
    ap.add_argument("--f32-grads", action="store_true",
                    help="paper-faithful f32 backward (the §Perf baseline)")
    ap.add_argument("--moe-group", type=int, default=None)
    ap.add_argument("--kv-int8", action="store_true")
    args = ap.parse_args(argv)
    if args.moe_bf16_reduce:
        import repro.models.ffn as _ffn2
        _ffn2.BF16_REDUCE = True
    if args.f32_grads:
        global BF16_GRADS
        BF16_GRADS = False
    if args.moe_group:
        import repro.models.ffn as _ffn3
        _ffn3.MOE_GROUP = args.moe_group
    if args.kv_int8:
        global KV_INT8
        KV_INT8 = True
    if args.microbatches is not None:
        TRAIN_MICROBATCHES[args.arch] = args.microbatches
    if args.no_ye_constraint:
        import repro.models.ffn as _ffn
        _ffn.YE_CONSTRAINT = False
    if args.no_upcast_kv:
        import repro.models.attention as _attn
        _attn.UPCAST_KV = False
    if args.fast:
        res = lower_combo(args.arch, args.shape, args.multipod)
    else:
        res = calibrated(args.arch, args.shape, args.multipod)
    print(json.dumps(res, indent=2, default=str))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2, default=str)
    if "skipped" not in res and not res["memory"]["fits_hbm"]:
        print("WARNING: does not fit HBM", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
