"""Framework serving launcher: prefill + autoregressive decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --prompt-len 16 --gen 24 [--kv-int8]

Runs the same ``decode_step`` (serve_step) the decode-shape dry-runs lower:
teacher-forced prefill fills the cache token by token, then greedy decode
generates. ``--kv-int8`` turns on the §Perf-3 quantized cache.

For the GraphEdge control-plane serving path (controller decision →
partition plan → distributed GNN inference) see ``repro.launch.serve_gnn``;
the ``repro.launch`` package docstring has the full entry-point table.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, list_archs
from repro.models import transformer as T
from repro.models.config import reduced as reduce_cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduce_cfg(get_config(args.arch), d_model=args.d_model)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    max_len = args.prompt_len + args.gen
    cache = T.init_cache(cfg, args.batch, max_len=max_len,
                         dtype=jnp.int8 if args.kv_int8 else jnp.float32)
    step = jax.jit(lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos))

    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):             # prefill via serve_step
        logits, cache = step(params, cache, prompt[:, t:t + 1],
                             jnp.int32(t))
    toks = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for t in range(args.prompt_len, max_len):    # greedy decode
        toks.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    dt = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"{cfg.name}: served batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen} kv_int8={args.kv_int8}")
    print(f"generated ids[0]: {out[0].tolist()}")
    print(f"{args.batch * max_len / dt:,.0f} tok/s "
          f"({dt:.1f}s incl. compile)")


if __name__ == "__main__":
    main()
