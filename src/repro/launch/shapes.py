"""The four assigned input shapes + ShapeDtypeStruct input specs.

``input_specs`` returns abstract stand-ins (weak-type-correct, shardable,
no device allocation) for every model input; the modality frontends are
stubbed exactly here — VLM patch embeddings / audio frame embeddings appear
as precomputed [B, P, dim] inputs per the assignment carve-out.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether this (arch, shape) pair runs (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return False, "pure full-attention family: long_500k skipped " \
                      "(see DESIGN.md decode-shape table)"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Batch input ShapeDtypeStructs for train/prefill kinds."""
    b, s = shape.global_batch, shape.seq_len
    batch: dict = {}
    text = s
    if cfg.num_prefix_tokens and cfg.prefix_dim:
        text = s - cfg.num_prefix_tokens           # VLM: patches + text = S
        batch["prefix_emb"] = _sds((b, cfg.num_prefix_tokens,
                                    cfg.prefix_dim), jnp.bfloat16)
    if cfg.encoder_stages:
        batch["frames"] = _sds((b, cfg.encoder_seq_len, cfg.prefix_dim),
                               jnp.bfloat16)
    batch["tokens"] = _sds((b, text), jnp.int32)
    if shape.kind == "train":
        batch["targets"] = _sds((b, text), jnp.int32)
    return batch


def decode_specs(cfg: ModelConfig, shape: InputShape,
                 cache_dtype=jnp.bfloat16) -> tuple[dict, object, object]:
    """(token/pos specs, cache specs) for decode kinds — via eval_shape so
    nothing is allocated."""
    b, s = shape.global_batch, shape.seq_len
    token = _sds((b, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, b, max_len=s, dtype=cache_dtype))
    return {"token": token, "pos": pos}, cache, None


def params_specs(cfg: ModelConfig, dtype=None):
    """Abstract params (eval_shape of init), optionally re-typed (bf16 for
    serving, fp32 master for training)."""
    sds = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    if dtype is None:
        return sds
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, dtype if jnp.issubdtype(x.dtype, jnp.floating)
            else x.dtype), sds)
