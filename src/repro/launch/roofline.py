"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

``cost_analysis`` operates on the partitioned (per-device) module, so the
terms above are already per-chip; the prompt's "…/(chips × …)" form is the
same quantity. Collective bytes are not in cost_analysis — we parse the
compiled HLO text and sum result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op (an
operand-side approximation, noted in EXPERIMENTS.md).

CAVEAT (EXPERIMENTS.md §Perf): the CPU backend legalizes bf16 → f32 during
compilation, so bytes for bf16 traffic are counted at f32 width — terms
are ~2× pessimistic in absolute value for bf16 quantities; relative
comparisons across combos remain valid.

Library module (no CLI) — consumed by ``repro.launch.dryrun``; see the
``repro.launch`` package docstring for the entry-point table.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16 FLOP/s
HBM_BW = 819e9           # B/s
ICI_BW = 50e9            # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind from HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            # match '= TYPE kind(' — the op use, not metadata mentions
            m = re.search(r"=\s+(.+?)\s+" + kind + r"(-start|-done)?\(", line)
            if m:
                if m.group(2) == "-done":
                    continue          # counted at -start
                out[kind] += _shape_bytes(m.group(1))
                out["count"] += 1
                break
    return out


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_flops_ratio: float
    coll_breakdown: dict


def analyze(compiled, *, num_chips: int, model_flops: float,
            hlo_text: str | None = None) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    cbytes = float(sum(v for k, v in coll.items() if k != "count"))
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = cbytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / max(flops * num_chips, 1.0)
    return Roofline(flops, byts, cbytes, compute_s, memory_s, collective_s,
                    bottleneck, model_flops, useful, coll)


def to_dict(r: Roofline) -> dict:
    return asdict(r)
