"""Production meshes (TPU v5e): 16×16 single pod / 2×16×16 multi-pod.

A function, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch dimension (data parallel incl. pods)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
