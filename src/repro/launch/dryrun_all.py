"""Sweep driver: every (arch × shape × mesh) dry-run combo in subprocesses.

Single-pod runs get the full roofline calibration; multi-pod runs prove the
'pod' axis shards (scanned compile only, --fast) per the assignment: the
roofline table is single-pod only.

    PYTHONPATH=src python -m repro.launch.dryrun_all --out results/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "qwen3-0.6b", "qwen3-1.7b", "h2o-danube-1.8b", "seamless-m4t-large-v2",
    "zamba2-2.7b", "gemma2-9b", "deepseek-v2-lite-16b", "mixtral-8x7b",
    "internvl2-26b", "rwkv6-7b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_one(arch: str, shape: str, multipod: bool, out_dir: str,
            timeout: int = 3000) -> dict:
    tag = f"{arch}_{shape}_{'pod2' if multipod else 'pod1'}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--json", path]
    if multipod:
        cmd += ["--multipod", "--fast"]
    t0 = time.time()
    env = dict(os.environ)
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, env=env)
    took = time.time() - t0
    if not os.path.exists(path):
        err = {"arch": arch, "shape": shape, "multipod": multipod,
               "error": proc.stderr[-3000:], "took_s": round(took, 1)}
        with open(path, "w") as f:
            json.dump(err, f, indent=2)
        return err
    with open(path) as f:
        res = json.load(f)
    res["took_s"] = round(took, 1)
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-multipod", action="store_true")
    ap.add_argument("--only-multipod", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    pods = []
    if not args.only_multipod:
        pods.append(False)
    if not args.skip_multipod:
        pods.append(True)
    total = ok = 0
    for multipod in pods:
        for arch in ARCHS:
            for shape in SHAPES:
                total += 1
                try:
                    res = run_one(arch, shape, multipod, args.out)
                except subprocess.TimeoutExpired:
                    res = {"error": "timeout"}
                if "skipped" in res:
                    status = "SKIP(" + res["skipped"][:40] + ")"
                    ok += 1
                elif "error" in res:
                    status = "ERROR"
                else:
                    fits = res["memory"]["fits_hbm"]
                    status = (f"ok compile={res['compile_s']}s "
                              f"peak={res['memory']['peak_bytes']/1e9:.1f}GB "
                              f"fits={fits}")
                    ok += 1 if fits else 0
                print(f"[{total:3d}] {arch:24s} {shape:12s} "
                      f"{'pod2' if multipod else 'pod1'}  {status}",
                      flush=True)
    print(f"done: {ok}/{total} ok")


if __name__ == "__main__":
    main()
