"""Distributed GNN serving launcher driven by the GraphEdge controller.

    PYTHONPATH=src python -m repro.launch.serve_gnn --devices 4 \
        --users 48 --partitioner hicut_jax --policy greedy --steps 3

End-to-end control → serving loop on a virtual device mesh (edge server →
mesh device): each dynamic time step the
:class:`repro.core.api.GraphEdgeController` perceives the perturbed user
topology, partitions it, offloads users to servers and accounts the exact
system cost (Eqs. 12–14); the resulting :class:`~repro.core.api.Decision`
bridges via ``to_partition_plan()`` into
:func:`repro.gnn.distributed.distributed_gcn_forward`, whose output is
checked against the single-device ``gcn_apply`` oracle every step.

``--dataset`` switches to large-graph mode (the Fig. 6 axis): serve one of
the synthetic citation datasets (``synth-pubmed`` is ~20k vertices) or a
``random`` graph of ``--vertices``/``--edges``, partitioned by HiCut on the
raw edge list and planned through the sparse O(E)
:func:`~repro.gnn.distributed.make_partition_plan_sparse` path — no dense
N×N adjacency is ever built. Outputs are verified against the dense oracle
up to 4096 vertices, and against the single-host sparse gather oracle
above that.

    PYTHONPATH=src python -m repro.launch.serve_gnn --devices 8 \
        --dataset synth-pubmed

NOTE: sets XLA_FLAGS before importing jax — run as a script/module entry,
not via import-then-call. (Entry-point orientation: see the
``repro.launch`` package docstring.)
"""
from __future__ import annotations

import argparse
import os
import time

# dense-oracle cutover: above this many vertices the check runs against the
# sparse gather oracle instead of materializing the N×N adjacency
DENSE_ORACLE_MAX_VERTICES = 4096


def _parse_args() -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--users", type=int, default=48)
    ap.add_argument("--capacity", type=int, default=0,
                    help="graph-state capacity (0 → users + 8)")
    ap.add_argument("--features", type=int, default=32)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--partitioner", default="hicut_jax")
    ap.add_argument("--policy", default="greedy")
    ap.add_argument("--change-rate", type=float, default=0.2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dataset", default="",
                    help="large-graph mode: synth-citeseer | synth-cora | "
                         "synth-pubmed | random (skips the controller loop)")
    ap.add_argument("--vertices", type=int, default=20_000,
                    help="--dataset random: vertex count")
    ap.add_argument("--edges", type=int, default=200_000,
                    help="--dataset random: edge count")
    return ap.parse_args()


def _serve_dataset(args) -> None:
    """Large-graph one-shot serve: sparse plan + gather aggregation."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core.hicut import hicut_ref
    from repro.data.graphs import DATASETS, make_graph, random_graph
    from repro.gnn.distributed import (distributed_gcn_forward,
                                       make_partition_plan_sparse)
    from repro.gnn.layers import gcn_apply, gcn_init, gcn_norm_sparse
    from repro.kernels.gnn_aggregate.ops import gather_aggregate

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    if args.dataset == "random":
        g = random_graph(args.vertices, args.edges, seed=args.seed)
    else:
        g = make_graph(DATASETS[args.dataset], seed=args.seed)
    n = g.num_vertices
    print(f"{g.name}: {n} vertices, {g.num_edges} edges "
          f"(built in {time.perf_counter() - t0:.1f}s)")

    t0 = time.perf_counter()
    assign = hicut_ref(n, g.edges) % args.devices
    t_cut = time.perf_counter() - t0
    t0 = time.perf_counter()
    plan = make_partition_plan_sparse(g.edges, assign, args.devices, n=n)
    t_plan = time.perf_counter() - t0
    print(f"hicut {t_cut:.1f}s, sparse plan {t_plan:.2f}s: "
          f"block={plan.block} halo={plan.halo} max_deg={plan.max_degree} "
          f"collective={plan.bytes_per_aggregate(args.hidden)} B/layer")

    params = gcn_init(jax.random.PRNGKey(args.seed),
                      [args.features, args.hidden, args.classes])
    x = rng.normal(size=(n, args.features)).astype(np.float32)
    mesh = Mesh(np.array(jax.devices()[:args.devices]), ("servers",))
    t0 = time.perf_counter()
    out = distributed_gcn_forward(mesh, "servers", plan, params, x)
    t_fwd = time.perf_counter() - t0

    if n <= DENSE_ORACLE_MAX_VERTICES:
        oracle = np.asarray(gcn_apply(params, jnp.asarray(x),
                                      jnp.asarray(g.adjacency()),
                                      jnp.ones(n)))
        which = "dense gcn_apply"
    else:   # single-host sparse oracle: Â = A + I through the gather op
        idx, val, dinv = gcn_norm_sparse(g.edges, n)
        h = jnp.asarray(x)
        for li, layer in enumerate(params):
            h = gather_aggregate(idx, val, h @ jnp.asarray(layer["w"]),
                                 dinv, dinv)
            if li < len(params) - 1:
                h = jax.nn.relu(h)
        oracle = np.asarray(h)
        which = "single-host sparse gather"
    err = float(np.abs(out - oracle).max())
    print(f"forward {t_fwd:.2f}s  |serve - {which} oracle|max = {err:.2e}")
    assert err < 1e-3, "distributed serve diverged from the oracle"


def main() -> None:
    args = _parse_args()
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")

    if args.dataset:
        _serve_dataset(args)
        return

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import costs
    from repro.core.api import GraphEdgeController
    from repro.core.dynamic_graph import perturb_scenario, random_scenario
    from repro.gnn.distributed import distributed_gcn_forward
    from repro.gnn.layers import gcn_apply, gcn_init

    rng = np.random.default_rng(args.seed)
    capacity = args.capacity or args.users + 8
    state = random_scenario(rng, capacity, args.users, 3 * args.users)
    net = costs.default_network(rng, capacity, args.devices)
    controller = GraphEdgeController(net=net, policy=args.policy,
                                     partitioner=args.partitioner)
    params = gcn_init(jax.random.PRNGKey(args.seed),
                      [args.features, args.hidden, args.classes])
    mesh = Mesh(np.array(jax.devices()[:args.devices]), ("servers",))

    print(f"serving {args.steps} dynamic steps: {args.users} users, "
          f"{args.devices} edge servers, {args.partitioner} + {args.policy}")
    for t in range(args.steps):
        if t:
            state = perturb_scenario(rng, state, args.change_rate)
        decision = controller.step(state)
        plan = decision.to_partition_plan(args.devices)
        x = rng.normal(size=(capacity, args.features)).astype(np.float32)
        out = distributed_gcn_forward(mesh, "servers", plan, params, x)
        oracle = np.asarray(gcn_apply(params, jnp.asarray(x), state.adj,
                                      state.mask))
        served = np.nonzero(np.asarray(state.mask) > 0)[0]
        err = float(np.abs(out[served] - oracle[served]).max())
        print(f"t={t}: C={float(decision.cost.c):8.3f}  "
              f"subgraphs={decision.partition.num_subgraphs:3d}  "
              f"halo={plan.halo:3d} rows/device  "
              f"collective={plan.bytes_per_aggregate(args.hidden):8d} B  "
              f"|serve - oracle|max={err:.2e}")
        assert err < 1e-4, "distributed serve diverged from the oracle"
    print(f"partition cache: {controller.cache_hits} hits, "
          f"{controller.cache_misses} misses")


if __name__ == "__main__":
    main()
