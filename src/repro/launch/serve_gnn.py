"""Distributed GNN serving launcher — thin CLI over the serving engine.

    PYTHONPATH=src python -m repro.launch.serve_gnn --devices 4 \
        --users 48 --partitioner hicut_jax --policy greedy_jit --steps 3

End-to-end control → serving on a virtual device mesh (edge server → mesh
device), driven by :class:`repro.serve.ServingEngine`: each dynamic time
step the :class:`repro.core.api.GraphEdgeController` perceives the
perturbed user topology, partitions it (LRU-cached on the topology
fingerprint; any registry backend — ``hicut_jax``, ``multilevel``,
``multilevel_jax``, ``mincut``, …), offloads users to servers (one jitted
scan for the ``JitPolicy`` entries ``greedy_jit``/``local_jit``/
``lyapunov``), and the engine pipelines the resulting plan
+ :func:`repro.gnn.distributed.make_forward_fn` inference against the
*next* step's decision (async dispatch, bounded plan cache — DESIGN.md
§5). ``--requests-per-step`` issues several inference requests per
topology interval; repeats hit the plan cache. Every output is checked
against the single-device ``gcn_apply`` oracle.

``--faults`` arms the deterministic chaos harness (DESIGN.md §9) on the
raw engine: the schedule's *user* waves churn the request stream (applied
in the generator, request-index clock) while its *server* events drive the
engine's drain-then-swap migration — the in-flight forward completes on
the old network, then the plan caches are invalidated and every later
decision prices against the degraded topology.

``--dataset`` switches to large-graph mode (the Fig. 6 axis): serve one of
the synthetic citation datasets (``synth-pubmed`` is ~20k vertices) or a
``random`` graph of ``--vertices``/``--edges``, partitioned by HiCut on the
raw edge list and planned through the sparse O(E)
:func:`~repro.gnn.distributed.make_partition_plan_sparse` path — no dense
N×N adjacency is ever built. Outputs are verified against the dense oracle
up to 4096 vertices, and against the single-host sparse gather oracle
above that.

    PYTHONPATH=src python -m repro.launch.serve_gnn --devices 8 \
        --dataset synth-pubmed

Importing this module has no side effects: the ``XLA_FLAGS`` virtual-device
mutation happens inside :func:`main`, and only when jax has not been
imported yet (when it has, the mesh falls back to however many devices the
already-initialized backend exposes). (Entry-point orientation: see the
``repro.launch`` package docstring.)
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# dense-oracle cutover: above this many vertices the check runs against the
# sparse gather oracle instead of materializing the N×N adjacency
DENSE_ORACLE_MAX_VERTICES = 4096


def _parse_args() -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--users", type=int, default=48)
    ap.add_argument("--capacity", type=int, default=0,
                    help="graph-state capacity (0 → users + 8)")
    ap.add_argument("--features", type=int, default=32)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--requests-per-step", type=int, default=1,
                    help="inference requests served per topology step "
                         "(repeats hit the engine's plan cache)")
    ap.add_argument("--plan-cache-size", type=int, default=16)
    ap.add_argument("--partitioner", default="hicut_jax")
    ap.add_argument("--policy", default="greedy_jit")
    ap.add_argument("--change-rate", type=float, default=0.2)
    ap.add_argument("--faults", default="",
                    help="deterministic fault schedule: comma-separated "
                         "'cycle:kind[:arg[:scale]]' items, e.g. "
                         "'1:server_down:1,2:arrive:4,4:server_up:1' "
                         "(cycles are request indices on the raw engine)")
    ap.add_argument("--faults-seed", type=int, default=0,
                    help="rng seed for fault-schedule user-churn waves")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dataset", default="",
                    help="large-graph mode: synth-citeseer | synth-cora | "
                         "synth-pubmed | random (skips the controller loop)")
    ap.add_argument("--vertices", type=int, default=20_000,
                    help="--dataset random: vertex count")
    ap.add_argument("--edges", type=int, default=200_000,
                    help="--dataset random: edge count")
    return ap.parse_args()


def _serve_dataset(args) -> None:
    """Large-graph one-shot serve: sparse plan + gather aggregation."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core.hicut import hicut_ref
    from repro.data.graphs import DATASETS, make_graph, random_graph
    from repro.gnn.distributed import (distributed_gcn_forward,
                                       make_partition_plan_sparse)
    from repro.gnn.layers import gcn_apply, gcn_init, gcn_norm_sparse
    from repro.kernels.gnn_aggregate.ops import gather_aggregate

    rng = np.random.default_rng(args.seed)
    devices = min(args.devices, len(jax.devices()))
    t0 = time.perf_counter()
    if args.dataset == "random":
        g = random_graph(args.vertices, args.edges, seed=args.seed)
    else:
        g = make_graph(DATASETS[args.dataset], seed=args.seed)
    n = g.num_vertices
    print(f"{g.name}: {n} vertices, {g.num_edges} edges "
          f"(built in {time.perf_counter() - t0:.1f}s)")

    t0 = time.perf_counter()
    assign = hicut_ref(n, g.edges) % devices
    t_cut = time.perf_counter() - t0
    t0 = time.perf_counter()
    plan = make_partition_plan_sparse(g.edges, assign, devices, n=n)
    t_plan = time.perf_counter() - t0
    print(f"hicut {t_cut:.1f}s, sparse plan {t_plan:.2f}s: "
          f"block={plan.block} halo={plan.halo} max_deg={plan.max_degree} "
          f"collective={plan.bytes_per_aggregate(args.hidden)} B/layer")

    params = gcn_init(jax.random.PRNGKey(args.seed),
                      [args.features, args.hidden, args.classes])
    x = rng.normal(size=(n, args.features)).astype(np.float32)
    mesh = Mesh(np.array(jax.devices()[:devices]), ("servers",))
    t0 = time.perf_counter()
    out = distributed_gcn_forward(mesh, "servers", plan, params, x)
    t_fwd = time.perf_counter() - t0

    if n <= DENSE_ORACLE_MAX_VERTICES:
        oracle = np.asarray(gcn_apply(params, jnp.asarray(x),
                                      jnp.asarray(g.adjacency()),
                                      jnp.ones(n)))
        which = "dense gcn_apply"
    else:   # single-host sparse oracle: Â = A + I through the gather op
        idx, val, dinv = gcn_norm_sparse(g.edges, n)
        h = jnp.asarray(x)
        for li, layer in enumerate(params):
            h = gather_aggregate(idx, val, h @ jnp.asarray(layer["w"]),
                                 dinv, dinv)
            if li < len(params) - 1:
                h = jax.nn.relu(h)
        oracle = np.asarray(h)
        which = "single-host sparse gather"
    err = float(np.abs(out - oracle).max())
    print(f"forward {t_fwd:.2f}s  |serve - {which} oracle|max = {err:.2e}")
    assert err < 1e-3, "distributed serve diverged from the oracle"


def _ensure_virtual_devices(devices: int) -> None:
    """Request ``devices`` virtual CPU devices — only effective before the
    first jax import (XLA reads the flag at backend init). Importing this
    module never mutates the environment; calling main() after jax is
    already up silently serves on however many devices exist."""
    if "jax" not in sys.modules:
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={devices}")


def main() -> None:
    args = _parse_args()
    _ensure_virtual_devices(args.devices)

    if args.dataset:
        _serve_dataset(args)
        return

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import costs
    from repro.core.api import GraphEdgeController
    from repro.core.dynamic_graph import perturb_scenario, random_scenario
    from repro.gnn.layers import gcn_apply, gcn_init
    from repro.serve import (FaultInjector, FaultSchedule, ServeRequest,
                             ServingEngine)

    rng = np.random.default_rng(args.seed)
    capacity = args.capacity or args.users + 8
    state = random_scenario(rng, capacity, args.users, 3 * args.users)
    devices = min(args.devices, len(jax.devices()))
    net = costs.default_network(rng, capacity, args.devices)
    controller = GraphEdgeController(net=net, policy=args.policy,
                                     partitioner=args.partitioner)
    params = gcn_init(jax.random.PRNGKey(args.seed),
                      [args.features, args.hidden, args.classes])
    mesh = Mesh(np.array(jax.devices()[:devices]), ("servers",))
    engine = ServingEngine(controller=controller, params=params, mesh=mesh,
                           axis="servers", num_devices=devices,
                           plan_cache_size=args.plan_cache_size)

    user_inj = server_inj = None
    if args.faults:
        schedule = FaultSchedule.parse(args.faults)
        # split clocks: user waves churn the stream in the generator,
        # server events drive the engine's drain-then-swap migration
        user_inj = FaultInjector(schedule.user_events(), net,
                                 state=state, seed=args.faults_seed)
        server_inj = FaultInjector(schedule.server_events(), net)

    def requests():
        nonlocal state
        idx = 0
        for t in range(args.steps):
            if t:
                state = perturb_scenario(rng, state, args.change_rate)
            for _ in range(args.requests_per_step):
                if user_inj is not None:
                    upd = user_inj.poll(idx)
                    if upd is not None and upd.state is not None:
                        state = upd.state
                x = rng.normal(size=(capacity, args.features))
                yield ServeRequest(state, x.astype(np.float32))
                idx += 1

    total = args.steps * args.requests_per_step
    print(f"serving {total} requests over {args.steps} dynamic steps: "
          f"{args.users} users, {devices} mesh devices, "
          f"{args.partitioner} + {args.policy} (pipelined engine)")
    t0 = time.perf_counter()
    for res in engine.serve(requests(), faults=server_inj):
        st = res.request.state
        oracle = np.asarray(gcn_apply(params, jnp.asarray(res.request.x),
                                      st.adj, st.mask))
        served = np.nonzero(np.asarray(st.mask) > 0)[0]
        err = float(np.abs(res.output[served] - oracle[served]).max())
        print(f"req={res.step}: C={float(res.decision.cost.c):8.3f}  "
              f"subgraphs={res.decision.partition.num_subgraphs:3d}  "
              f"halo={res.plan.halo:3d} rows/device  "
              f"collective={res.plan.bytes_per_aggregate(args.hidden):8d} B  "
              f"plan={'hit ' if res.plan_cache_hit else 'miss'}  "
              f"|serve - oracle|max={err:.2e}")
        assert err < 1e-4, "distributed serve diverged from the oracle"
    dt = time.perf_counter() - t0
    pc, cc = engine.plan_cache_info(), controller.cache_info()
    print(f"{total / dt:.2f} req/s  "
          f"partition cache: {cc.hits} hits / {cc.misses} misses  "
          f"plan cache: {pc.hits} hits / {pc.misses} misses "
          f"({pc.currsize}/{pc.maxsize} entries)")
    if server_inj is not None:
        applied = len(server_inj.applied) + len(user_inj.applied)
        print(f"faults: {applied} events applied  "
              f"net_swaps={engine.net_swaps}  "
              f"servers up={server_inj.num_up}/{args.devices}")


if __name__ == "__main__":
    main()
