"""Distributed GNN serving launcher driven by the GraphEdge controller.

    PYTHONPATH=src python -m repro.launch.serve_gnn --devices 4 \
        --users 48 --partitioner hicut_jax --policy greedy --steps 3

End-to-end control → serving loop on a virtual device mesh (edge server →
mesh device): each dynamic time step the
:class:`repro.core.api.GraphEdgeController` perceives the perturbed user
topology, partitions it, offloads users to servers and accounts the exact
system cost (Eqs. 12–14); the resulting :class:`~repro.core.api.Decision`
bridges via ``to_partition_plan()`` into
:func:`repro.gnn.distributed.distributed_gcn_forward`, whose output is
checked against the single-device ``gcn_apply`` oracle every step.

NOTE: sets XLA_FLAGS before importing jax — run as a script/module entry,
not via import-then-call. (Entry-point orientation: see the
``repro.launch`` package docstring.)
"""
from __future__ import annotations

import argparse
import os


def _parse_args() -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--users", type=int, default=48)
    ap.add_argument("--capacity", type=int, default=0,
                    help="graph-state capacity (0 → users + 8)")
    ap.add_argument("--features", type=int, default=32)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--partitioner", default="hicut_jax")
    ap.add_argument("--policy", default="greedy")
    ap.add_argument("--change-rate", type=float, default=0.2)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args()


def main() -> None:
    args = _parse_args()
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import costs
    from repro.core.api import GraphEdgeController
    from repro.core.dynamic_graph import perturb_scenario, random_scenario
    from repro.gnn.distributed import distributed_gcn_forward
    from repro.gnn.layers import gcn_apply, gcn_init

    rng = np.random.default_rng(args.seed)
    capacity = args.capacity or args.users + 8
    state = random_scenario(rng, capacity, args.users, 3 * args.users)
    net = costs.default_network(rng, capacity, args.devices)
    controller = GraphEdgeController(net=net, policy=args.policy,
                                     partitioner=args.partitioner)
    params = gcn_init(jax.random.PRNGKey(args.seed),
                      [args.features, args.hidden, args.classes])
    mesh = Mesh(np.array(jax.devices()[:args.devices]), ("servers",))

    print(f"serving {args.steps} dynamic steps: {args.users} users, "
          f"{args.devices} edge servers, {args.partitioner} + {args.policy}")
    for t in range(args.steps):
        if t:
            state = perturb_scenario(rng, state, args.change_rate)
        decision = controller.step(state)
        plan = decision.to_partition_plan(args.devices)
        x = rng.normal(size=(capacity, args.features)).astype(np.float32)
        out = distributed_gcn_forward(mesh, "servers", plan, params, x)
        oracle = np.asarray(gcn_apply(params, jnp.asarray(x), state.adj,
                                      state.mask))
        served = np.nonzero(np.asarray(state.mask) > 0)[0]
        err = float(np.abs(out[served] - oracle[served]).max())
        print(f"t={t}: C={float(decision.cost.c):8.3f}  "
              f"subgraphs={decision.partition.num_subgraphs:3d}  "
              f"halo={plan.halo:3d} rows/device  "
              f"collective={plan.bytes_per_aggregate(args.hidden):8d} B  "
              f"|serve - oracle|max={err:.2e}")
        assert err < 1e-4, "distributed serve diverged from the oracle"
    print(f"partition cache: {controller.cache_hits} hits, "
          f"{controller.cache_misses} misses")


if __name__ == "__main__":
    main()
