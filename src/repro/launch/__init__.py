"""Launchers and deployment tooling — which entry point do I want?

Two families live here: the **GraphEdge control plane** (the paper
reproduction: controller → distributed GNN serving) and the **LM framework
lane** (the transformer stack this repo also carries: training/serving
launchers plus the multi-pod dry-run and roofline tooling).

Runnable entry points (``PYTHONPATH=src python -m repro.launch.<name>``):

| entry point | lane | what it does |
|---|---|---|
| ``serve_gnn``  | GraphEdge | thin CLI over the pipelined :class:`repro.serve.ServingEngine`: control decisions (jitted for the ``JitPolicy`` entries ``greedy_jit`` [default] / ``local_jit`` / ``lyapunov``) overlap in-flight distributed GCN forwards, plans are LRU-cached on (topology, assignment, network) behind ``--plan-cache-size`` (default 16), every output checked against the single-device oracle. ``--partitioner``/``--policy`` select any registry backend (e.g. ``multilevel`` + ``lyapunov``); ``--dataset synth-pubmed`` serves a ~20k-vertex graph through the sparse O(E) plan + gather path; ``--faults`` replays a deterministic failure/churn schedule with drain-then-swap network migration |
| ``serve_multihost`` | GraphEdge | SPMD serving over a simulated process grid: spawns ``--processes`` workers (``jax.distributed`` + gloo collectives, ``--devices`` total mesh devices split evenly), each building only its shard of the partition plan (:mod:`repro.gnn.multihost`) with features resident on their owning host and halo-only ``--exchange pair`` all_to_all between processes; ``--arm resident`` vs the replicate-everything single-process ``--arm engine`` baseline, ``--vertices``/``--edges`` synthetic community graph, JSON record with steps/sec + halo vs replicate bytes (``--json-out``), cross-host-count bitwise parity via ``--ref-out``/``--ref-in`` |
| ``serve_stream`` | GraphEdge | open-loop Poisson load against the streaming front-end (:class:`repro.serve.StreamingFrontend`): ``--arrival-rate`` req/s over ``--tenants`` tenants with ``--deadline``-second SLO budgets into a ``--queue-depth``-bounded queue; continuous batching up to ``--max-batch`` on shared plan-cache entries, ``--admission lyapunov`` (``--v``/``--theta``) vs ``static`` vs ``admit_all``, prints per-phase p50/p95/p99 + sustained req/s and the conservation ledger; ``--faults`` injects server failures + user waves at pump boundaries (queued requests migrate to warm-recut plans, per-fault recovery latency reported) |
| ``train``      | LM        | training loop for a registry arch (``--reduced`` CPU dims or ``--production`` mesh shardings) |
| ``serve``      | LM        | prefill + autoregressive decode (optionally ``--kv-int8``) |
| ``dryrun``     | LM        | lower + compile one (arch × shape × mesh) combo; memory/FLOPs analysis |
| ``dryrun_all`` | LM        | sweep every combo in subprocesses, JSON per run |
| ``report``     | LM        | render the dry-run/roofline tables from the sweep JSON |

Libraries (imported, not run): ``mesh`` (production mesh shapes),
``shapes`` (assigned input shapes / abstract input specs), ``shardings``
(FSDP+TP+SP GSPMD rules), ``roofline`` (compute/memory/collective terms
from compiled HLO).

DRLGO (offloading-policy) training is not a launcher — use
``examples/train_drlgo.py`` (``--batch B`` for the vmapped batched
environment) or drive :class:`repro.core.offload.drlgo.DRLGOTrainer`
directly. See README.md for the repo-level map.
"""
