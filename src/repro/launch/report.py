"""Render the §Dry-run and §Roofline tables from results/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report --out results/dryrun \
        [--md results/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.dryrun_all import ARCHS, SHAPES


def load(out_dir: str) -> dict:
    res = {}
    for path in glob.glob(os.path.join(out_dir, "*.json")):
        with open(path) as f:
            d = json.load(f)
        if "arch" not in d:
            continue
        res[(d["arch"], d["shape"], bool(d.get("multipod")))] = d
    return res


def fmt_bytes(n: float) -> str:
    return f"{n / 1e9:.2f}"


def dryrun_table(res: dict, multipod: bool) -> list[str]:
    lines = [
        "| arch | shape | compile s | peak GB/dev | fits 16 GB | "
        "collectives (count) |",
        "|---|---|---:|---:|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            d = res.get((arch, shape, multipod))
            if d is None:
                lines.append(f"| {arch} | {shape} | — | — | MISSING | |")
                continue
            if "skipped" in d:
                lines.append(f"| {arch} | {shape} | — | — | "
                             f"SKIP ({d['skipped'][:48]}…) | |")
                continue
            if "error" in d:
                lines.append(f"| {arch} | {shape} | — | — | ERROR | |")
                continue
            m = d["memory"]
            cb = d["roofline"]["coll_breakdown"]
            kinds = ",".join(k.split("-")[0] + "-" + k.split("-")[1][:1]
                             for k, v in cb.items()
                             if k != "count" and v > 0) or "none"
            lines.append(
                f"| {arch} | {shape} | {d['compile_s']} | "
                f"{fmt_bytes(m['peak_bytes'])} | "
                f"{'yes' if m['fits_hbm'] else 'NO'} | "
                f"{kinds} ({cb.get('count', 0)}) |")
    return lines


def roofline_table(res: dict) -> list[str]:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck "
        "| MODEL_FLOPS | useful ratio | what would move the dominant term |",
        "|---|---|---:|---:|---:|---|---:|---:|---|",
    ]
    hints = {
        ("memory", "train"): "fuse optimizer+cast; bf16 master copy; "
                             "reduce remat recompute reads",
        ("memory", "prefill"): "flash-attention kernel (cut score "
                               "materialization reads)",
        ("memory", "decode"): "decode is cache-BW bound by nature; "
                              "quantize KV cache (int8) to halve reads",
        ("collective", "train"): "overlap FSDP all-gathers with compute; "
                                 "reduce-scatter grads in-loop",
        ("collective", "prefill"): "shard seq instead of gathering KV "
                                   "(ring attention)",
        ("collective", "decode"): "keep cache seq-sharded with LSE-combine "
                                  "instead of gathering",
        ("compute", "train"): "MoE dispatch einsum → sort-based / Pallas "
                              "gmm dispatch",
        ("compute", "prefill"): "same",
        ("compute", "decode"): "same",
    }
    for arch in ARCHS:
        for shape in SHAPES:
            d = res.get((arch, shape, False))
            if d is None or "skipped" in d or "error" in d:
                continue
            r = d.get("roofline_exact") or d["roofline"]
            kind = ("train" if shape.startswith("train") else
                    "prefill" if shape.startswith("prefill") else "decode")
            hint = hints.get((r["bottleneck"], kind), "")
            lines.append(
                f"| {arch} | {shape} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                f"**{r['bottleneck']}** | {r['model_flops']:.2e} | "
                f"{r['useful_flops_ratio']:.2f} | {hint} |")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    res = load(args.out)
    chunks = ["### Dry-run — single pod (16×16 = 256 chips)", ""]
    chunks += dryrun_table(res, multipod=False)
    chunks += ["", "### Dry-run — multi-pod (2×16×16 = 512 chips)", ""]
    chunks += dryrun_table(res, multipod=True)
    chunks += ["", "### Roofline (single-pod, calibrated exact counts)", ""]
    chunks += roofline_table(res)
    text = "\n".join(chunks)
    print(text)
    if args.md:
        with open(args.md, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
