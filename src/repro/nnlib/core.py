"""Minimal neural-network substrate (no flax in this container).

Params are plain pytrees of jnp arrays; every module is an (init, apply)
pair. Used by the DRLGO actor/critic networks, the GNN layers, and the
transformer stack's small components.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Params = Any  # pytree of jnp arrays


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def uniform_init(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def glorot_init(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    scale = math.sqrt(6.0 / (fan_in + fan_out))
    return uniform_init(key, shape, scale, dtype)


def he_init(key, shape, dtype=jnp.float32):
    fan_in = shape[-2]
    std = math.sqrt(2.0 / fan_in)
    return jax.random.normal(key, shape, dtype) * std


def normal_init(key, shape, std=0.02, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * std


# ---------------------------------------------------------------------------
# dense / mlp
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, *, init=glorot_init,
               bias: bool = True, dtype=jnp.float32) -> Params:
    kw, _ = jax.random.split(key)
    p = {"w": init(kw, (in_dim, out_dim), dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def mlp_init(key, sizes: Sequence[int], *, bias: bool = True,
             init=glorot_init, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, len(sizes) - 1)
    return [dense_init(k, i, o, init=init, bias=bias, dtype=dtype)
            for k, i, o in zip(keys, sizes[:-1], sizes[1:])]


def mlp_apply(p: Params, x: jnp.ndarray,
              activation: Callable = jax.nn.relu,
              final_activation: Callable | None = None) -> jnp.ndarray:
    for i, layer in enumerate(p):
        x = dense_apply(layer, x)
        if i < len(p) - 1:
            x = activation(x)
        elif final_activation is not None:
            x = final_activation(x)
    return x


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def layernorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p: Params, x: jnp.ndarray, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def rmsnorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p: Params, x: jnp.ndarray, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# pytree utilities
# ---------------------------------------------------------------------------

def tree_size(tree: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def tree_polyak(new: Params, old: Params, tau: float) -> Params:
    """Soft update: tau * new + (1 - tau) * old  (paper Eqs. 31-32)."""
    return jax.tree_util.tree_map(lambda n, o: tau * n + (1 - tau) * o, new, old)
