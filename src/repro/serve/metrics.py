"""SLO telemetry for the streaming serving front-end.

Every request that flows through :class:`repro.serve.frontend.
StreamingFrontend` is stamped on a **monotonic tick clock** at four points
— arrival (submit), admit (admission decision), dispatch (batched forward
launched) and done (output fetched) — giving the four per-request phase
latencies the SLO accounting is built on:

    queue_wait = admit − arrival      (time spent queued / deferred)
    decide     = dispatch − admit     (control step + scatter + dispatch)
    forward    = done − dispatch      (device compute + output fetch)
    total      = done − arrival       (the end-to-end request latency)

The tick clock is injectable: :class:`MonotonicClock` (the default) reads
``time.perf_counter`` so ticks are wall-clock seconds; :class:`ManualClock`
is a deterministic logical clock for tests and simulated workloads — the
front-end only ever calls ``now()`` and ``sleep()``, so the two are
interchangeable. All tick arithmetic is float seconds in either case.

:func:`summarize` aggregates a batch of timings into the
``BENCH_serving.json`` streaming-record shape: p50/p95/p99/mean/max per
phase plus **sustained requests/sec** (served count over the
first-arrival→last-done span — the open-loop throughput number, not the
inverse mean latency).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

PERCENTILES = (50, 95, 99)


class MonotonicClock:
    """Wall tick clock: ``now()`` is ``time.perf_counter`` seconds."""

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class ManualClock:
    """Deterministic logical tick clock for tests/simulation: time moves
    only via ``sleep``/``advance`` (and an optional fixed per-``now`` tick
    so busy-loops cannot live-lock a simulated run)."""

    def __init__(self, start: float = 0.0, tick_per_now: float = 0.0):
        self._t = float(start)
        self.tick_per_now = float(tick_per_now)

    def now(self) -> float:
        self._t += self.tick_per_now
        return self._t

    def sleep(self, dt: float) -> None:
        self.advance(dt)

    def advance(self, dt: float) -> None:
        if dt > 0:
            self._t += float(dt)


@dataclass
class RequestTiming:
    """The four tick stamps of one served request (−1 = not reached)."""
    arrival: float
    admit: float = -1.0
    dispatch: float = -1.0
    done: float = -1.0

    @property
    def queue_wait(self) -> float:
        return self.admit - self.arrival

    @property
    def decide(self) -> float:
        return self.dispatch - self.admit

    @property
    def forward(self) -> float:
        return self.done - self.dispatch

    @property
    def total(self) -> float:
        return self.done - self.arrival

    def phases(self) -> dict[str, float]:
        return {"queue_wait": self.queue_wait, "decide": self.decide,
                "forward": self.forward, "total": self.total}


def percentiles(values, pcts=PERCENTILES) -> dict[str, float]:
    """{"p50": …, "p95": …, "p99": …, "mean": …, "max": …} of ``values``."""
    arr = np.asarray(list(values), np.float64)
    if arr.size == 0:
        return {f"p{p}": float("nan") for p in pcts} | \
            {"mean": float("nan"), "max": float("nan")}
    out = {f"p{p}": float(np.percentile(arr, p)) for p in pcts}
    out["mean"] = float(arr.mean())
    out["max"] = float(arr.max())
    return out


class CycleTelemetry:
    """Decide-stage telemetry, one sample per scheduling cycle: the batch
    size the cycle's single (vmapped) control dispatch covered, and the
    decide-phase latency (admit→dispatch ticks) of that cycle.

    ``as_dict`` emits the per-cycle batch-size histogram plus decide
    p50/p95 — the numbers that show the batched controller amortizing
    (cycle batch sizes ≫ 1 while decide-per-request falls). Deterministic
    under :class:`ManualClock`; the front-end records one sample per
    non-empty ``pump`` cycle."""

    def __init__(self):
        self.batch_sizes: list[int] = []
        self.decide_ticks: list[float] = []

    def record(self, batch_size: int, decide: float) -> None:
        self.batch_sizes.append(int(batch_size))
        self.decide_ticks.append(float(decide))

    def histogram(self) -> dict[int, int]:
        out: dict[int, int] = {}
        for b in self.batch_sizes:
            out[b] = out.get(b, 0) + 1
        return dict(sorted(out.items()))

    def as_dict(self) -> dict:
        dec = percentiles(self.decide_ticks)
        per_req = [t / b for t, b in
                   zip(self.decide_ticks, self.batch_sizes)]
        return {"cycles": len(self.batch_sizes),
                "batch_hist": {str(k): v
                               for k, v in self.histogram().items()},
                "batch_mean": (float(np.mean(self.batch_sizes))
                               if self.batch_sizes else 0.0),
                "decide": {"p50": dec["p50"], "p95": dec["p95"]},
                "decide_per_request": percentiles(per_req)}


def summarize(timings: list[RequestTiming]) -> dict:
    """Aggregate served-request timings into the streaming SLO record:
    per-phase percentile blocks + sustained requests/sec."""
    if not timings:
        return {"served": 0, "sustained_rps": 0.0}
    span = max(t.done for t in timings) - min(t.arrival for t in timings)
    out: dict = {"served": len(timings),
                 "sustained_rps": len(timings) / max(span, 1e-9)}
    for phase in ("queue_wait", "decide", "forward", "total"):
        out[phase] = percentiles(getattr(t, phase) for t in timings)
    return out
