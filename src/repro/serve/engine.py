"""Pipelined GNN serving engine (ROADMAP "Async serving loop").

The paper's per-time-step loop — perceive, HiCut, offload, serve (Fig. 2,
Eqs. 12–14) — ran strictly sequentially in ``repro.launch.serve_gnn``: one
controller decision, one blocking ``distributed_gcn_forward``, repeat. That
puts the whole decision latency on the serving critical path even though
the two stages use disjoint resources (host Python/XLA-control vs the
device computation). This engine rebuilds serving as a request pipeline:

1. **decide** — ``GraphEdgeController.step`` (jitted end to end for
   :class:`~repro.core.api.JitPolicy` policies such as ``greedy_jit``).
2. **plan** — topology-delta detection via the controller's
   ``topology_key`` + a bounded LRU **plan cache**: the key is
   ``(topology fingerprint, offload-assignment digest)`` and the value is
   the built :class:`~repro.gnn.distributed.PartitionPlan` *and* its
   prepared forward (``make_forward_fn`` — normalization scales, extended
   adjacency, jitted shard_map closure). Requests on an unchanged topology
   with an unchanged assignment skip plan construction and forward prep
   entirely.
3. **dispatch** — the forward is dispatched asynchronously (JAX async
   dispatch); the engine immediately starts step t+1's decision while step
   t's inference is in flight, and blocks only when fetching t's output.

Depth-1 pipelining is deliberate: one in-flight forward keeps the device
busy while the host decides, without reordering results or holding >2
request buffers. ``serve`` is a generator that preserves request order.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator, Sequence

import numpy as np
from jax.sharding import Mesh

from repro.core.api import (CacheInfo, Decision, GraphEdgeController,
                            LruCache, topology_key)
from repro.core.dynamic_graph import GraphState
from repro.gnn.distributed import (PLAN_BUCKET_QUANTUM, PartitionPlan,
                                   PlanConsts, _ceil_to,
                                   make_batched_forward_fn, make_forward_fn,
                                   make_multi_forward_fn, pad_plan_to_bucket,
                                   plan_bucket, prepare_plan_consts,
                                   resolve_aggregate)

# adaptive bucket quantums: per-family quantums double up to this cap
PLAN_BUCKET_QUANTUM_CAP = 64
_FAMILY_HIST_MAX = 64                # distinct halo widths kept per family


@dataclass(frozen=True)
class ServeRequest:
    """One inference request: the perceived layout + per-vertex features."""
    state: GraphState
    x: np.ndarray                 # [N, F_in] vertex features


@dataclass(frozen=True)
class ServeResult:
    """One served request, in submission order."""
    step: int
    request: ServeRequest
    decision: Decision
    plan: PartitionPlan
    output: np.ndarray            # [N, F_out] gathered global output
    plan_cache_hit: bool


def _assignment_digest(servers: np.ndarray) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(np.ascontiguousarray(servers, np.int64).tobytes())
    return h.hexdigest()


def network_digest(net) -> str:
    """Fingerprint of an :class:`~repro.core.costs.EdgeNetwork`'s pricing
    surface (capacities, rates, energy constants). Part of the plan-cache
    key: two identical (topology, assignment) pairs priced under different
    networks must NOT share a plan entry — a capacity swap (fault event,
    degradation) would otherwise keep serving plans whose placement the
    live network can no longer host. Cheap: only recomputed on
    :meth:`ServingEngine.swap_network`, never per request."""
    h = hashlib.blake2b(digest_size=16)
    for leaf in net:
        h.update(np.ascontiguousarray(
            np.asarray(leaf, np.float64)).tobytes())
    return h.hexdigest()


@dataclass
class PlanEntry:
    """One plan-cache value: the plan, its prepared single-request forward,
    and — built lazily, only once a continuous batch actually forms on this
    plan — the prepared batched forward (``make_batched_forward_fn``) plus,
    for cross-topology batches, the plan padded to its shape bucket with
    its stackable forward constants (``padded``: bucket → (plan, consts)).
    All lazily-built members stay with the entry, so they age out of the
    LRU together with the plan. ``bucket`` memoizes the shape bucket along
    with the family quantum it was computed at (``bucket_quantum``), so the
    engine can re-bucket the entry when its family's quantum adapts."""
    key: tuple[str, str, str]     # (topology, assignment, network) digests
    plan: PartitionPlan
    forward: Callable
    batched: Callable | None = None
    bucket: tuple | None = None
    bucket_quantum: int | None = None
    padded: dict = field(default_factory=dict)


@dataclass
class BucketFamily:
    """Running halo histogram + adaptive quantum for one ``(P, n, block')``
    plan-shape family (:meth:`ServingEngine.entry_bucket`)."""
    hist: dict = field(default_factory=dict)   # halo width → count
    quantum: int = PLAN_BUCKET_QUANTUM

    def observe(self, halo: int) -> int:
        """Record a halo width; returns the (possibly widened) quantum.

        The quantum doubles (cap :data:`PLAN_BUCKET_QUANTUM_CAP`) until
        the family's observed min/max halo land in ONE bucket. Doubling
        only ever *merges* buckets — two widths sharing a ceiling at
        quantum q share it at 2q — so adaptation never splits a batch
        group that already formed, and re-bucketed entries join, never
        leave, their hot family bucket."""
        if halo not in self.hist and len(self.hist) >= _FAMILY_HIST_MAX:
            self.hist.pop(min(self.hist, key=self.hist.get))
        self.hist[halo] = self.hist.get(halo, 0) + 1
        lo, hi = min(self.hist), max(self.hist)
        while _ceil_to(lo, self.quantum) != _ceil_to(hi, self.quantum) \
                and self.quantum < PLAN_BUCKET_QUANTUM_CAP:
            self.quantum *= 2
        return self.quantum


@dataclass
class ServingEngine:
    """Controller + mesh + params → pipelined request server.

    ``num_devices`` defaults to the mesh size; plans fold server ids onto
    that many devices (``Decision.to_partition_plan``). ``plan_cache_size``
    bounds the LRU of (plan, prepared forward) entries.
    """
    controller: GraphEdgeController
    params: list                  # GCN layer params (repro.gnn.layers)
    mesh: Mesh
    axis: str = "servers"
    num_devices: int | None = None
    plan_cache_size: int = 16
    aggregate: str = "auto"
    exchange: str = "gather"      # halo layout: "gather" | "pair"
                                  # (pair = cut-edges-only all_to_all, the
                                  # multi-host wire — repro.gnn.multihost)

    def __post_init__(self):
        if self.num_devices is None:
            self.num_devices = int(np.prod(list(self.mesh.shape.values())))
        self._plan_cache = LruCache(self.plan_cache_size)
        self._multi_cache = LruCache(self.plan_cache_size)
        self._bucket_families: dict[tuple, BucketFamily] = {}
        self._net_key = network_digest(self.controller.net)
        self.net_swaps = 0

    # -- control + plan stage ------------------------------------------------
    def _plan_for(self, decision: Decision) -> tuple[PlanEntry, bool]:
        """Plan + prepared forward for a decision, through the LRU cache.

        Keyed on (topology fingerprint, assignment digest, network
        digest): the plan is a pure function of the edge list and the
        user→server placement, so repeated requests on an unchanged
        topology whose policy reproduces the same assignment reuse both
        the plan and its jitted forward. The network digest rotates on
        :meth:`swap_network`, so entries priced under a stale network
        (pre-fault capacities) can never be served again — see the
        regression test in ``tests/test_faults.py``."""
        topo = decision.topo_key or topology_key(decision.state)
        key = (topo, _assignment_digest(decision.servers), self._net_key)
        hit = self._plan_cache.get(key)
        if hit is not None:
            return hit, True
        plan = decision.to_partition_plan(self.num_devices,
                                          exchange=self.exchange)
        forward = make_forward_fn(self.mesh, self.axis, plan, self.aggregate)
        entry = PlanEntry(key, plan, forward)
        self._plan_cache.put(key, entry)
        return entry, False

    def decide_entry(self, state: GraphState
                     ) -> tuple[Decision, PlanEntry, bool]:
        """The full control stage for one request (no inference): one
        controller step + the (topology, assignment)-keyed plan LRU."""
        decision = self.controller.step(state)
        entry, hit = self._plan_for(decision)
        return decision, entry, hit

    def decide_entries(self, states: Sequence[GraphState]
                       ) -> list[tuple[Decision, PlanEntry, bool]]:
        """The control stage for a whole scheduling cycle: ALL states are
        decided in one vmapped XLA call (``GraphEdgeController.step_batch``
        — scene build + policy + exact cost stacked over the batch), then
        each decision goes through the plan LRU. This is the batched-decide
        hot path of the streaming front-end's pump loop; per-request decide
        pays one dispatch per request, this pays one per cycle."""
        decisions = self.controller.step_batch(states)
        return [(d,) + self._plan_for(d) for d in decisions]

    def decide(self, state: GraphState
               ) -> tuple[Decision, PartitionPlan, Callable, bool]:
        """Back-compat surface of :meth:`decide_entry`."""
        decision, entry, hit = self.decide_entry(state)
        return decision, entry.plan, entry.forward, hit

    def batched_forward(self, entry: PlanEntry) -> Callable:
        """The prepared *batched* forward of a cached plan, built lazily on
        the first continuous batch that forms on it (the per-plan numpy
        prep runs once; jit then compiles once per batch-size bucket). The
        streaming front-end's dispatch hook (``repro.serve.frontend``)."""
        if entry.batched is None:
            entry.batched = make_batched_forward_fn(self.mesh, self.axis,
                                                    entry.plan,
                                                    self.aggregate)
        return entry.batched

    # -- cross-topology batching ---------------------------------------------
    def entry_bucket(self, entry: PlanEntry) -> tuple:
        """The entry's shape bucket (:func:`plan_bucket`) — the batch key
        for cross-topology continuous batching.

        The quantum is **adaptive per plan-shape family** ``(P, n,
        block')``: each family keeps a small running histogram of the halo
        widths it has served (:class:`BucketFamily`) and doubles its
        quantum until the observed spread fits one bucket — a hot family
        whose halos straddle a fixed ``PLAN_BUCKET_QUANTUM`` boundary
        (e.g. 7 vs 9) no longer splits into two buckets and halves its
        batch size. Memoized on the entry together with the quantum it
        was computed at, so entries re-bucket when their family adapts."""
        plan = entry.plan
        fam_key = (plan.num_devices, plan.n,
                   _ceil_to(plan.block, PLAN_BUCKET_QUANTUM))
        fam = self._bucket_families.setdefault(fam_key, BucketFamily())
        if entry.bucket is None:
            fam.observe(plan.halo)        # first sighting joins the family
        if entry.bucket_quantum != fam.quantum:
            entry.bucket = plan_bucket(plan, fam.quantum)
            entry.bucket_quantum = fam.quantum
        return entry.bucket

    def _padded_member(self, entry: PlanEntry, bucket: tuple
                       ) -> tuple[PartitionPlan, PlanConsts]:
        """The entry's plan padded to ``bucket`` plus its stackable forward
        constants, built once per (entry, bucket). Padding appends inert
        slots only, so the padded forward is bitwise-identical to the
        original plan's (``pad_plan``); the aggregate is resolved on the
        *padded* shapes so every bucket member picks the same kernel."""
        got = entry.padded.get(bucket)
        if got is None:
            plan = pad_plan_to_bucket(entry.plan, bucket)
            agg = resolve_aggregate(plan, self.aggregate)
            got = (plan, prepare_plan_consts(plan, agg), agg)
            entry.padded[bucket] = got
        return got[0], got[1]

    def cross_batched_forward(self, entries: Sequence[PlanEntry]
                              ) -> tuple[list[PartitionPlan], Callable]:
        """One dispatchable forward serving requests resolved against
        *different* cached plans.

        The entries must share a shape bucket (``entry_bucket``). Returns
        the per-member padded plans — whose ``scatter``/``gather`` lay out
        each member's features by its own perm (``scatter_multi``) — and
        the stacked multi-plan forward over [P, B, L, F] blocks. The
        stacked closure is LRU-cached on the ordered member keys: steady
        streams cycling over a hot set of topologies rebuild nothing, and
        the jit cache underneath keys on the bucket shapes, so even a cold
        member set of a warm bucket skips compilation."""
        bucket = self.entry_bucket(entries[0])
        assert all(self.entry_bucket(e) == bucket for e in entries), \
            [self.entry_bucket(e) for e in entries]
        key = (bucket, tuple(e.key for e in entries))
        hit = self._multi_cache.get(key)
        if hit is not None:
            return hit
        members = [self._padded_member(e, bucket) for e in entries]
        plans = [m[0] for m in members]
        agg = entries[0].padded[bucket][2]
        forward = make_multi_forward_fn(self.mesh, self.axis, agg,
                                        [m[1] for m in members])
        self._multi_cache.put(key, (plans, forward))
        return plans, forward

    # -- network swap (fault migration) --------------------------------------
    def swap_network(self, net) -> None:
        """Install a repriced :class:`~repro.core.costs.EdgeNetwork` (fault
        event: server down/up, degradation). Rotates the plan-cache network
        digest so every entry built against the old pricing misses from now
        on (cross-topology stacked forwards key on entry keys, so they
        rotate with it), and flushes the controller's partition cache —
        cached cuts may target a server count the new network no longer
        has. Callers that want warm-started re-cuts install them afterwards
        via ``controller.recut_warm`` (see ``repro.serve.frontend``)."""
        self.controller.net = net
        self.controller.invalidate_partitions()
        self._net_key = network_digest(net)
        self.net_swaps += 1

    # -- serving -------------------------------------------------------------
    def serve(self, requests: Iterable[ServeRequest], faults=None
              ) -> Iterator[ServeResult]:
        """Serve a request stream, pipelined at depth 1.

        For each request the engine runs the control stage and dispatches
        the forward, then yields the *previous* request's result — so step
        t's decision overlaps step t−1's in-flight device computation. The
        final result is flushed after the stream ends; order is preserved.

        A failing request never loses the one already in flight: if the
        decide/dispatch of request t raises (bad state, failing policy,
        poisoned iterator), request t−1's pending result is flushed to the
        consumer first and the exception re-raised on the next pull.

        ``faults`` (a :class:`repro.serve.faults.FaultInjector`) is polled
        once per request with the request index as the logical clock. When
        an update reprices the network, the engine **drains then swaps**:
        the in-flight forward (built against the old plan) is finished and
        yielded first, then :meth:`swap_network` installs the new pricing —
        so no request is ever served against a plan/network mix and none is
        lost (DESIGN.md §9)."""
        pending = None
        it = enumerate(requests)
        while True:
            try:
                try:
                    t, req = next(it)
                except StopIteration:
                    break
                update = faults.poll(t) if faults is not None else None
                if update is not None and update.net is not None:
                    if pending is not None:   # drain before repricing
                        res, pending = self._finish(*pending), None
                        yield res
                    self.swap_network(update.net)
                decision, plan, forward, hit = self.decide(req.state)
                x_blocks = plan.scatter(np.asarray(req.x, np.float32))
                out = forward(x_blocks, self.params)    # async dispatch
            except BaseException:
                if pending is not None:     # flush t−1 before propagating
                    res, pending = self._finish(*pending), None
                    yield res
                raise
            if pending is not None:
                yield self._finish(*pending)
            pending = (t, req, decision, plan, out, hit)
        if pending is not None:
            yield self._finish(*pending)

    def serve_all(self, requests: Iterable[ServeRequest], faults=None
                  ) -> list[ServeResult]:
        return list(self.serve(requests, faults=faults))

    def _finish(self, t, req, decision, plan, out, hit) -> ServeResult:
        output = plan.gather(np.asarray(out))       # blocks on fetch only
        return ServeResult(t, req, decision, plan, output, hit)

    # -- introspection -------------------------------------------------------
    def plan_cache_info(self) -> CacheInfo:
        return self._plan_cache.info()
