"""Streaming request front-end: queue, admission control, continuous batching.

The pipelined :class:`~repro.serve.engine.ServingEngine` serves one
pre-materialized request stream at depth 1 — nothing models concurrent
users pushing requests faster than the engine drains them. This module is
the production-shaped front of the serving tier (the Fograph
fog-serving architecture, arXiv:2307.01684, is the reference shape):

* :class:`RequestQueue` — a **bounded** queue of :class:`StreamRequest`
  (tenant id, arrival tick, deadline). Backpressure is explicit: when the
  queue is full, ``submit`` rejects with reason ``"queue_full"`` and the
  rejection is counted and recorded — requests are *never* silently
  dropped, and ``admitted + rejected + deferred == submitted`` holds at
  every instant (the conservation invariant CI gates on).
* **Continuous batching** — each scheduling cycle groups queued requests
  that share the head-of-line request's *topology fingerprint* (and
  therefore its ``(topology_key, assignment_digest)`` plan-cache entry):
  one control decision, one ``plan.scatter_batch`` to [P, B, L, F], one
  dispatch of the cached plan's batched forward
  (:func:`repro.gnn.distributed.make_batched_forward_fn`). B concurrent
  requests on an unchanged topology cost one XLA dispatch instead of B.
  Batch sizes are padded to power-of-two buckets so compiles stay bounded.
  The GCN output depends only on the topology (adjacency + mask) and the
  features — never on the offload placement — so members of a batch are
  exactly the requests whose output the head's plan computes correctly.
* **Lyapunov admission control** — :class:`LyapunovAdmission` keeps one
  virtual queue per *tenant*, reusing the drift-plus-penalty update
  :func:`repro.core.offload.lyapunov.virtual_queue_update` (the same
  recursion the per-server offload scheduler scans): admitting a tenant's
  request is an arrival on its queue, every serviced batch drains all
  queues by the fair per-tenant share, and the admit/defer/reject decision
  minimizes ``Q_tenant + V · (projected latency / deadline)`` against the
  backlog bound θ. A flooding tenant builds backlog and gets rejected or
  deferred while light tenants keep admitting, so the *admitted* p99
  stays bounded under overload. :class:`StaticPriorityAdmission` is the
  ablation baseline (fixed tenant ranks, no queue state, no deadlines);
  :class:`AdmitAll` is the no-control arm.
* **SLO telemetry** — every request is stamped on the injectable tick
  clock (``repro.serve.metrics``) at arrival/admit/dispatch/done;
  ``stats()`` aggregates p50/p95/p99 per phase and sustained requests/sec
  in the ``BENCH_serving.json`` streaming-record shape.

``StreamingFrontend.run(workload)`` drives an **open-loop** workload (a
sorted ``(arrival_offset, request)`` iterable — see
:func:`poisson_workload`): arrivals are injected on schedule regardless of
service progress, so overload manifests as queue growth → backpressure,
exactly the regime the admission controller is for.
``repro.launch.serve_stream`` is the CLI.
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Iterable, Iterator, Protocol, runtime_checkable

import numpy as np

from repro.core.api import LruCache, topology_key
from repro.core.dynamic_graph import GraphState
from repro.core.offload.lyapunov import virtual_queue_update
from repro.gnn.distributed import gather_multi, scatter_multi
from repro.serve.engine import ServingEngine
from repro.serve.faults import FaultInjector
from repro.serve.metrics import (CycleTelemetry, ManualClock, MonotonicClock,
                                 RequestTiming, summarize)

# rejection reasons (the only terminal states besides "served")
REJECT_QUEUE_FULL = "queue_full"     # bounded-queue backpressure at submit
REJECT_ADMISSION = "admission"       # admission controller said no
REJECT_DEADLINE = "deadline"         # SLO budget already (or provably) blown

ADMIT, DEFER, REJECT = "admit", "defer", "reject"


@dataclass(frozen=True)
class StreamRequest:
    """One streamed inference request.

    ``deadline`` is a *relative* SLO budget in clock ticks (seconds on the
    default monotonic clock) from arrival; ``None`` = best effort.
    ``rid`` is stamped by the front-end at submit when not provided."""
    state: GraphState
    x: np.ndarray                    # [N, F_in] vertex features
    tenant: int = 0
    deadline: float | None = None
    rid: int | None = None


@dataclass
class _Entry:
    """A queued request + its bookkeeping (timing stamps, lazy topo key)."""
    req: StreamRequest
    rid: int
    timing: RequestTiming
    deadline_tick: float | None      # absolute tick, None = best effort
    topo: str | None = None
    defers: int = 0
    migrations: int = 0              # network swaps survived while queued

    def topo_key(self) -> str:
        if self.topo is None:
            self.topo = topology_key(self.req.state)
        return self.topo


@dataclass(frozen=True)
class Rejection:
    """One rejected request — every non-served request gets exactly one."""
    rid: int
    tenant: int
    reason: str
    tick: float
    defers: int = 0


@dataclass(frozen=True)
class StreamResult:
    """One served request. ``decision`` is the control decision of the
    request's *own* topology (decided in the cycle's batched controller
    call); ``batch_size`` is the size of the dispatch group that served
    it."""
    rid: int
    request: StreamRequest
    output: np.ndarray               # [N, F_out] gathered global output
    timing: RequestTiming
    batch_size: int
    plan_cache_hit: bool
    decision: object = None


class RequestQueue:
    """Bounded FIFO of queued entries with explicit backpressure: ``offer``
    returns False (and the front-end records a ``queue_full`` rejection)
    instead of ever dropping silently."""

    def __init__(self, depth: int):
        self.depth = int(depth)
        self._q: list[_Entry] = []

    def offer(self, entry: _Entry) -> bool:
        if len(self._q) >= self.depth:
            return False
        self._q.append(entry)
        return True

    def replace(self, entries: list[_Entry]) -> None:
        """Install the survivors of a scheduling pass (FIFO order kept)."""
        self._q = entries

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self) -> Iterator[_Entry]:
        return iter(self._q)


# ---------------------------------------------------------------------------
# admission controllers
# ---------------------------------------------------------------------------

@runtime_checkable
class AdmissionController(Protocol):
    """Admit/defer/reject decision per candidate request, once per cycle.

    ``decide`` sees the candidate entry, the current tick, the queue
    backlog and the front-end's *amortized* per-request service-time
    estimate (the batched ``decide_entries`` cycle cost spread over the
    batch the backlog supports, plus the per-request forward cost —
    :meth:`StreamingFrontend.est_service`);
    ``on_cycle(served, now)`` is called once per scheduling cycle with the
    number of requests just serviced (0 for an idle/all-deferred cycle) so
    queue-state controllers can drain."""

    def decide(self, entry: _Entry, now: float, backlog: int,
               est_service: float) -> str: ...

    def on_cycle(self, served: int, now: float) -> None: ...


class AdmitAll:
    """No admission control: everything the bounded queue accepted runs."""
    name = "admit_all"

    def decide(self, entry, now, backlog, est_service) -> str:
        return ADMIT

    def on_cycle(self, served, now) -> None:
        pass


class StaticPriorityAdmission:
    """Static-priority baseline (the ablation arm): tenants carry fixed
    ranks (default: tenant id — lower is more important). Below the
    ``high_water`` backlog everyone admits; above it only tenants ranked
    ``<= keep_rank`` do, everyone else is rejected outright. No queue
    state, no deadline awareness — under overload the admitted latency of
    the privileged tenants is protected but nothing bounds anyone's p99."""
    name = "static_priority"

    def __init__(self, high_water: int = 32, keep_rank: int = 0,
                 priority: dict[int, int] | None = None):
        self.high_water = int(high_water)
        self.keep_rank = int(keep_rank)
        self.priority = dict(priority or {})

    def rank(self, tenant: int) -> int:
        return self.priority.get(tenant, tenant)

    def decide(self, entry, now, backlog, est_service) -> str:
        if backlog <= self.high_water:
            return ADMIT
        return ADMIT if self.rank(entry.req.tenant) <= self.keep_rank \
            else REJECT

    def on_cycle(self, served, now) -> None:
        pass


class LyapunovAdmission:
    """Per-tenant virtual-queue drift-plus-penalty admission control.

    The same recursion as the per-server offload scheduler
    (``repro.core.offload.lyapunov``), lifted to the serving tier:

    * admitting a request from tenant τ is an **arrival** on Q_τ
      (``Q_τ ← max(Q_τ + 1 − 0, 0)`` via :func:`virtual_queue_update`);
    * every scheduling cycle **drains** all queues by the fair per-tenant
      service share ``μ_τ = max(served, idle_drain) / T`` — a serviced
      batch is capacity actually delivered, an idle cycle still offers
      ``idle_drain`` of it (so an all-deferred queue always makes
      progress: Q decays until someone admits again);
    * the decision minimizes the drift-plus-penalty score
      ``Q_τ + V · (wait + est_service) / deadline`` against the backlog
      bound ``theta``: admit below it, defer above it while the deadline
      still has slack for another cycle, reject otherwise. A request whose
      budget is already un-meetable (``wait + est_service > deadline``)
      is rejected immediately — admitting it would burn service on a
      guaranteed SLO miss.

    ``theta`` bounds every tenant's admitted-but-unserved backlog, so the
    *admitted* latency tail stays bounded no matter how hard one tenant
    floods; ``V`` trades fairness pressure against deadline pressure
    (``V = 0`` → pure per-tenant fair queueing).

    Per-tenant **service weights** skew the fair share: ``weights[τ]``
    (default 1.0) scales tenant τ's drain rate to
    ``μ_τ = max(served, idle_drain) · w_τ / Σw``, so a weight-3 tenant
    drains — and therefore admits — 3× as fast as a weight-1 tenant under
    contention, while every tenant keeps a *guaranteed* minimum drain of
    ``idle_drain · w_τ / Σw`` per cycle. That minimum gives the starvation
    bound (:meth:`starvation_bound`): a tenant deferred at backlog Q re-
    enters the admit region ``Q ≤ θ`` within ``⌈(Q − θ)·Σw/(d·w_τ)⌉``
    cycles no matter what the other tenants do."""
    name = "lyapunov"

    def __init__(self, num_tenants: int = 1, v: float = 1.0,
                 theta: float = 8.0, idle_drain: float = 1.0,
                 weights: dict[int, float] | None = None):
        self.num_tenants = max(1, int(num_tenants))
        self.v = float(v)
        self.theta = float(theta)
        self.idle_drain = float(idle_drain)
        self.weights = {int(k): float(v_) for k, v_ in
                        (weights or {}).items()}
        if any(w <= 0 for w in self.weights.values()):
            raise ValueError(f"tenant weights must be > 0: {self.weights}")
        self.q: dict[int, float] = {}
        self.queue_max = 0.0          # boundedness certificate for tests

    def weight(self, tenant: int) -> float:
        return self.weights.get(tenant, 1.0)

    def total_weight(self) -> float:
        return sum(self.weight(t) for t in range(self.num_tenants))

    def starvation_bound(self, tenant: int, backlog: float | None = None
                         ) -> int:
        """Worst-case cycles until tenant τ's virtual queue re-enters the
        admit region ``Q_τ ≤ θ`` from ``backlog`` (default: the largest
        backlog any tenant has ever reached). Every ``on_cycle`` drains
        Q_τ by at least ``idle_drain · w_τ / Σw`` — even an idle or
        all-deferred cycle — so no admissible tenant is starved longer
        than ``⌈(Q − θ) · Σw / (idle_drain · w_τ)⌉`` cycles, whatever the
        other tenants submit (tested in ``tests/test_frontend.py``)."""
        q0 = self.queue_max if backlog is None else float(backlog)
        mu_min = self.idle_drain * self.weight(tenant) / self.total_weight()
        return int(math.ceil(max(q0 - self.theta, 0.0) / mu_min))

    def decide(self, entry, now, backlog, est_service) -> str:
        tenant = entry.req.tenant
        wait = now - entry.timing.arrival
        deadline = entry.req.deadline
        projected = wait + est_service
        if deadline is not None and projected > deadline:
            return REJECT             # provably un-meetable SLO
        q_t = self.q.get(tenant, 0.0)
        penalty = (projected / deadline) if deadline else 0.0
        if q_t + self.v * penalty <= self.theta:
            q_t = float(virtual_queue_update(q_t, 1.0, 0.0, xp=np))
            self.q[tenant] = q_t
            self.queue_max = max(self.queue_max, q_t)
            return ADMIT
        # over the backlog bound: hold the request while its budget still
        # has slack for (at least) one more service cycle, else shed it
        if deadline is None or projected + est_service <= deadline:
            return DEFER
        return REJECT

    def on_cycle(self, served, now) -> None:
        cap = max(float(served), self.idle_drain) / self.total_weight()
        for tenant, q_t in self.q.items():
            mu = cap * self.weight(tenant)
            self.q[tenant] = float(virtual_queue_update(q_t, 0.0, mu,
                                                        xp=np))


# ---------------------------------------------------------------------------
# the front-end
# ---------------------------------------------------------------------------

def _bucket(b: int, max_batch: int) -> int:
    """Smallest power-of-two ≥ b, capped at ``max_batch`` — the batch axis
    is padded to these buckets so each plan compiles O(log max_batch)
    times. The cap bounds *padding*, never the members already in the
    batch: a ``b > max_batch`` (callers that batch beyond the front-end's
    own limit) keeps its exact size rather than being truncated below b,
    so the result is always ≥ b and ≤ max(b, max_batch)."""
    p = 1
    while p < b:
        p <<= 1
    return max(b, min(p, max_batch))


@dataclass
class FrontendStats:
    """Terminal-state counters. The conservation invariant —
    ``admitted + rejected + deferred + migrated == submitted`` — holds at
    every instant: ``deferred`` and ``migrated`` together are the requests
    still queued (their decision deferred to a later cycle; ``migrated``
    counts the queued requests that have survived ≥ 1 network swap and
    will be re-planned against the new pricing); at the end of a drained
    run both are 0 and every request is accounted admitted or rejected —
    fault migrations lose nothing."""
    submitted: int = 0
    admitted: int = 0
    served: int = 0
    deferred: int = 0                 # currently queued (non-terminal)
    migrated: int = 0                 # queued across ≥1 net swap (non-term.)
    defer_events: int = 0             # total individual defer decisions
    requests_migrated: int = 0        # distinct requests ever migrated
    migrated_served: int = 0          # migrated requests that reached serve
    rejected: dict[str, int] = field(default_factory=dict)
    batches: int = 0
    batched_requests: int = 0         # requests served in batches of ≥ 2
    cross_batches: int = 0            # dispatches spanning > 1 cached plan
    cross_batched_requests: int = 0

    @property
    def rejected_total(self) -> int:
        return sum(self.rejected.values())

    @property
    def conservation_ok(self) -> bool:
        return self.admitted + self.rejected_total + self.deferred \
            + self.migrated == self.submitted

    def as_dict(self) -> dict:
        return {"submitted": self.submitted, "admitted": self.admitted,
                "served": self.served, "deferred": self.deferred,
                "migrated": self.migrated,
                "defer_events": self.defer_events,
                "requests_migrated": self.requests_migrated,
                "migrated_served": self.migrated_served,
                "rejected": dict(self.rejected),
                "rejected_total": self.rejected_total,
                "batches": self.batches,
                "batched_requests": self.batched_requests,
                "cross_batches": self.cross_batches,
                "cross_batched_requests": self.cross_batched_requests,
                "conservation_ok": self.conservation_ok}


@dataclass
class StreamingFrontend:
    """Bounded queue + admission + continuous batching over a
    :class:`~repro.serve.engine.ServingEngine`.

    ``pump()`` runs one scheduling cycle (admission pass → batch former →
    ONE vmapped control decision for the whole cycle → one batched
    dispatch per plan/bucket group) and returns the served results;
    ``run()`` drives a whole open-loop workload to drain and
    ``run_threaded()`` overlaps arrival and dispatch with a concurrent
    producer thread (``submit`` is thread-safe). The engine's plan cache
    is the batching substrate: with ``cross_topology=False`` the batch
    key is the head's plan-cache key (only same-topology requests group);
    with ``cross_topology=True`` the key is the plan's *shape bucket*
    (:meth:`ServingEngine.entry_bucket`) and one dispatch of the
    multi-plan forward serves requests resolved against different cached
    plans (:meth:`ServingEngine.cross_batched_forward`)."""
    engine: ServingEngine
    queue_depth: int = 64
    max_batch: int = 8
    admission: AdmissionController = field(default_factory=AdmitAll)
    clock: MonotonicClock | ManualClock = field(
        default_factory=MonotonicClock)
    service_ewma: float = 0.2        # EWMA weight of new service samples
    cross_topology: bool = False
    faults: FaultInjector | None = None

    def __post_init__(self):
        self.queue = RequestQueue(self.queue_depth)
        self.stats = FrontendStats()
        self.rejections: list[Rejection] = []
        self.timings: list[RequestTiming] = []
        self.cycles = CycleTelemetry()
        self._est_decide = 0.0       # per-CYCLE batched decide cost (EWMA)
        self._est_forward = 0.0      # per-REQUEST dispatch+fetch cost (EWMA)
        self._next_rid = 0
        self._lock = threading.Lock()   # guards queue + stats + telemetry
        self._topo_memo = LruCache(1024)
        self._cycle = 0              # logical pump clock (drives faults)
        self.fault_trace: list[dict] = []
        self._awaiting_recovery: list[dict] = []
        self._last_subgraph = LruCache(256)   # topo → last decided subgraph

    def _ewma(self, old: float, sample: float) -> float:
        return sample if old == 0.0 else \
            (1 - self.service_ewma) * old + self.service_ewma * sample

    def est_service(self, backlog: int) -> float:
        """Amortized per-request service estimate at the given backlog.

        The cycle's ONE vmapped ``decide_entries`` call costs the same
        whether it decides 1 or ``max_batch`` requests, so its EWMA
        (``_est_decide``, per cycle) is spread over the batch the current
        backlog supports — charging every candidate the *full* decide
        cost (the old behaviour) made admission under overload
        systematically pessimistic, shedding requests whose deadline the
        batched cycle would comfortably meet. The per-request
        dispatch+fetch cost (``_est_forward``) is genuinely per request
        and is charged whole."""
        share = min(max(backlog, 1), self.max_batch)
        return self._est_decide / share + self._est_forward

    def _topo_key_of(self, state: GraphState) -> str:
        """Topology fingerprint, memoized on state *identity*: streaming
        workloads reuse a handful of state objects across thousands of
        requests, and hashing the edge list per request (~70 µs) would
        dominate the batched cycle. The cached value keeps a reference to
        its state, so a recycled ``id`` can never alias a dead object."""
        got = self._topo_memo.get(id(state))
        if got is not None and got[0] is state:
            return got[1]
        key = topology_key(state)
        self._topo_memo.put(id(state), (state, key))
        return key

    # -- intake --------------------------------------------------------------
    def submit(self, req: StreamRequest) -> bool:
        """Enqueue a request; False = backpressure (``queue_full`` reject,
        counted and recorded — never a silent drop). Thread-safe: producer
        threads submit concurrently with the pump loop."""
        with self._lock:
            now = self.clock.now()
            rid = req.rid if req.rid is not None else self._next_rid
            self._next_rid = max(self._next_rid, rid) + 1
            self.stats.submitted += 1
            deadline_tick = None if req.deadline is None \
                else now + float(req.deadline)
            entry = _Entry(req, rid, RequestTiming(arrival=now),
                           deadline_tick, topo=self._topo_key_of(req.state))
            if not self.queue.offer(entry):
                self._reject(entry, REJECT_QUEUE_FULL, now)
                self._sync_queue_stats()
                return False
            self._sync_queue_stats()
            return True

    def _reject(self, entry: _Entry, reason: str, tick: float) -> None:
        self.stats.rejected[reason] = self.stats.rejected.get(reason, 0) + 1
        self.rejections.append(Rejection(entry.rid, entry.req.tenant,
                                         reason, tick, entry.defers))

    def _sync_queue_stats(self) -> None:
        """Recount the non-terminal states from the queue itself (the
        conservation invariant's ``deferred + migrated`` is always derived,
        never incrementally drifted)."""
        mig = sum(1 for e in self.queue if e.migrations)
        self.stats.migrated = mig
        self.stats.deferred = len(self.queue) - mig

    # -- fault injection -----------------------------------------------------
    def _poll_faults(self) -> None:
        """Apply due fault events at a pump boundary (nothing in flight).

        On a server event the engine's network is swapped FIRST (which
        flushes the controller's topology-keyed partition cache) and every
        still-queued topology with a recorded previous cut is then
        warm-recut (:meth:`~repro.core.api.GraphEdgeController.recut_warm`)
        against the surviving servers, so the next cycle's decisions start
        from the migrated plan instead of a cold re-partition. Queued
        requests are marked migrated — never dropped — and the migration
        is appended to :attr:`fault_trace`."""
        if self.faults is None:
            return
        update = self.faults.poll(self._cycle)
        if update is None:
            return
        trace = {"cycle": self._cycle,
                 "events": [ev._asdict() for ev in update.events],
                 "num_up": update.num_up, "queued": len(self.queue),
                 "migrated": 0, "recut_topologies": 0}
        if update.net is not None:
            for e in self.queue:
                if e.migrations == 0:
                    self.stats.requests_migrated += 1
                e.migrations += 1
            trace["migrated"] = len(self.queue)
            # swap (flushes partition cache) BEFORE installing warm cuts
            self.engine.swap_network(update.net)
            seen: set[str] = set()
            for e in self.queue:
                topo = e.topo_key()
                if topo in seen:
                    continue
                seen.add(topo)
                prev = self._last_subgraph.get(topo)
                if prev is None:
                    continue
                self.engine.controller.recut_warm(
                    e.req.state, prev, num_parts=max(1, update.num_up))
                trace["recut_topologies"] += 1
            self._awaiting_recovery.append(trace)
            self._sync_queue_stats()
        self.fault_trace.append(trace)

    def _mark_recovered(self) -> None:
        """Stamp recovery latency (in pump cycles, inclusive) on every
        pending migration once a cycle serves results again."""
        for rec in self._awaiting_recovery:
            rec["recovery_cycles"] = self._cycle - rec["cycle"] + 1
        self._awaiting_recovery.clear()

    # -- one scheduling cycle ------------------------------------------------
    def pump(self) -> list[StreamResult]:
        """Admission pass + batch former + one batched cycle dispatch.

        Walks the queue in FIFO order: expired requests are rejected
        (``deadline``) and each candidate passes its own admission check.
        With ``cross_topology=False`` the first admissible request becomes
        the batch head and only later requests sharing its topology
        fingerprint join (others simply stay queued — only an explicit
        controller decision defers or rejects); with
        ``cross_topology=True`` every admissible request joins up to
        ``max_batch``, whatever its topology. The whole batch is then
        decided in ONE vmapped controller call and dispatched per
        plan/bucket group (:meth:`_serve_cycle`). Returns the served
        results of this cycle (possibly [])."""
        with self._lock:
            now = self.clock.now()
            self._poll_faults()       # pump boundary: nothing in flight
            backlog = len(self.queue)
            est_service = self.est_service(backlog)
            batch: list[_Entry] = []
            survivors: list[_Entry] = []
            head_topo: str | None = None
            for entry in self.queue:
                if entry.deadline_tick is not None \
                        and now > entry.deadline_tick:
                    self._reject(entry, REJECT_DEADLINE, now)
                    continue
                if len(batch) >= self.max_batch or (
                        not self.cross_topology
                        and head_topo is not None
                        and entry.topo_key() != head_topo):
                    survivors.append(entry)
                    continue
                verdict = self.admission.decide(entry, now, backlog,
                                                est_service)
                if verdict == ADMIT:
                    entry.timing.admit = now
                    batch.append(entry)
                    head_topo = entry.topo_key()
                elif verdict == DEFER:
                    entry.defers += 1
                    self.stats.defer_events += 1
                    survivors.append(entry)
                else:
                    self._reject(entry, REJECT_ADMISSION, now)
            self.queue.replace(survivors)
            self._sync_queue_stats()
        if not batch:
            self.admission.on_cycle(0, now)
            self._cycle += 1
            return []
        results = self._serve_cycle(batch)
        self.admission.on_cycle(len(batch), self.clock.now())
        if results:
            self._mark_recovered()
        self._cycle += 1
        return results

    def _serve_cycle(self, batch: list[_Entry]) -> list[StreamResult]:
        """Serve one admitted cycle: ONE vmapped control decision over the
        cycle's unique topologies (:meth:`ServingEngine.decide_entries`),
        then one asynchronous dispatch per plan group — same-plan groups
        through the plan's batched forward, mixed groups sharing a shape
        bucket through the multi-plan cross-topology forward — and only
        then the blocking output fetches, so every group's device work
        overlaps the others' host-side prep."""
        t_admit = batch[0].timing.admit
        # 1. one batched decide over the cycle's unique topologies
        by_topo: dict[str, list[_Entry]] = {}
        for e in batch:
            by_topo.setdefault(e.topo_key(), []).append(e)
        topos = list(by_topo)
        decided = dict(zip(topos, self.engine.decide_entries(
            [by_topo[t][0].req.state for t in topos])))
        for t in topos:
            # remembered as the warm-start seed for fault-time re-cuts
            self._last_subgraph.put(
                t, np.asarray(decided[t][0].partition.subgraph))
        t_decided = self.clock.now()
        # 2. group members by plan (same-topo mode) or shape bucket
        groups: dict[tuple, list[_Entry]] = {}
        for e in batch:
            pe = decided[e.topo_key()][1]
            gk = self.engine.entry_bucket(pe) if self.cross_topology \
                else pe.key
            groups.setdefault(gk, []).append(e)
        # 3. dispatch every group before fetching any output
        inflight = []
        for members in groups.values():
            entries = [decided[e.topo_key()][1] for e in members]
            xs = [e.req.x for e in members]
            bsz = len(members)
            pad = _bucket(bsz, self.max_batch)
            if len({pe.key for pe in entries}) == 1:
                plan = entries[0].plan
                if bsz == 1:
                    out = entries[0].forward(
                        plan.scatter(np.asarray(xs[0], np.float32)),
                        self.engine.params)
                    fetch = (lambda o=out, p=plan:
                             [p.gather(np.asarray(o))])
                else:
                    fwd = self.engine.batched_forward(entries[0])
                    out = fwd(plan.scatter_batch(xs, pad_to=pad),
                              self.engine.params)
                    fetch = (lambda o=out, p=plan, b=bsz:
                             p.gather_batch(np.asarray(o), count=b))
                cross = False
            else:
                # pad the member list to the batch bucket by repeating the
                # tail entry (pad slots carry zero features; outputs are
                # dropped by count=bsz), so compile counts stay bounded
                padded = entries + [entries[-1]] * (pad - bsz)
                plans, fwd = self.engine.cross_batched_forward(padded)
                out = fwd(scatter_multi(plans, xs, pad_to=pad),
                          self.engine.params)
                fetch = (lambda o=out, ps=plans, b=bsz:
                         gather_multi(ps, np.asarray(o), count=b))
                cross = True
            inflight.append((members, fetch, cross))
        t_dispatch = self.clock.now()
        all_results: list[StreamResult] = []
        with self._lock:
            for members, fetch, cross in inflight:
                outputs = fetch()           # blocks on this group's fetch
                bsz = len(members)
                self.stats.batches += 1
                if bsz >= 2:
                    self.stats.batched_requests += bsz
                if cross:
                    self.stats.cross_batches += 1
                    self.stats.cross_batched_requests += bsz
                t_done = self.clock.now()
                for e, output in zip(members, outputs):
                    decision, pe, hit = decided[e.topo_key()]
                    e.timing.dispatch = t_dispatch
                    e.timing.done = t_done
                    if e.migrations:
                        self.stats.migrated_served += 1
                    self.timings.append(e.timing)
                    all_results.append(StreamResult(
                        e.rid, e.req, output, e.timing, bsz, hit,
                        decision))
            t_done = self.clock.now()
            bsz = len(batch)
            # service-time estimates feeding the admission controller:
            # the batched decide is a per-CYCLE cost (amortized at decide
            # time over the backlog — est_service()), the dispatch+fetch
            # a per-REQUEST one
            self._est_decide = self._ewma(self._est_decide,
                                          t_decided - t_admit)
            self._est_forward = self._ewma(self._est_forward,
                                           (t_done - t_decided) / bsz)
            self.stats.admitted += bsz
            self.stats.served += bsz
            self.cycles.record(bsz, t_dispatch - t_admit)
        return all_results

    # -- open-loop workload driver -------------------------------------------
    def run(self, workload: Iterable[tuple[float, StreamRequest]]
            ) -> list[StreamResult]:
        """Drive a sorted ``(arrival_offset, request)`` workload to drain.

        Open loop: requests are injected once their offset (relative to the
        start tick) has passed, regardless of how far serving has fallen
        behind — a rate above the service capacity fills the queue and
        surfaces as backpressure/admission rejections, never as slowed-down
        arrivals. Returns every served result (submission order within a
        batch; batches in service order)."""
        t0 = self.clock.now()
        it = iter(workload)
        nxt = next(it, None)
        results: list[StreamResult] = []
        while nxt is not None or len(self.queue):
            now = self.clock.now() - t0
            while nxt is not None and nxt[0] <= now:
                self.submit(nxt[1])
                nxt = next(it, None)
            if not len(self.queue):
                if nxt is not None:   # idle until the next arrival is due
                    self.clock.sleep(nxt[0] - (self.clock.now() - t0))
                continue
            results.extend(self.pump())
        return results

    def run_threaded(self, workload: Iterable[tuple[float, StreamRequest]],
                     idle_wait: float = 1e-4) -> list[StreamResult]:
        """Concurrent-intake twin of :meth:`run`: a producer thread injects
        the workload's arrivals on schedule through the thread-safe
        ``submit`` while this thread pumps continuously — arrival and
        dispatch overlap instead of strictly alternating, so a long
        in-flight batch no longer delays intake (and the next cycle's
        batch is already formed when the dispatch returns). Wall-clock
        (``MonotonicClock``) only: a shared logical clock would make the
        producer's schedule depend on pump timing."""
        t0 = self.clock.now()
        done = threading.Event()

        def produce():
            try:
                for offset, req in workload:
                    dt = offset - (self.clock.now() - t0)
                    if dt > 0:
                        self.clock.sleep(dt)
                    self.submit(req)
            finally:
                done.set()

        producer = threading.Thread(target=produce, daemon=True)
        producer.start()
        results: list[StreamResult] = []
        while not (done.is_set() and not len(self.queue)):
            if not len(self.queue):
                self.clock.sleep(idle_wait)
                continue
            results.extend(self.pump())
        producer.join()
        return results

    # -- telemetry -----------------------------------------------------------
    def slo_summary(self) -> dict:
        """p50/p95/p99/mean/max per phase + sustained requests/sec."""
        return summarize(self.timings)

    def stats_dict(self) -> dict:
        return {**self.stats.as_dict(), "slo": self.slo_summary(),
                "cycles": self.cycles.as_dict(),
                "est_service": self.est_service(len(self.queue)),
                "est_decide": self._est_decide,
                "est_forward": self._est_forward,
                "plan_cache": self.engine.plan_cache_info()._asdict()}


def poisson_workload(rng: np.random.Generator, rate: float, count: int,
                     make_request, lazy: bool = False
                     ) -> Iterable[tuple[float, StreamRequest]]:
    """Open-loop Poisson-process workload: ``count`` arrivals at ``rate``
    requests/tick (exponential inter-arrival gaps), each request built by
    ``make_request(i)``. The standard "millions of independent users"
    arrival model — bursts and lulls included. With ``lazy=True`` the
    requests are built one-by-one *at injection time* (a generator), so a
    ``make_request`` that snapshots a mutating state — e.g. the fault
    injector's churned user graph — sees the state as of each arrival
    instead of as of workload construction."""
    gaps = rng.exponential(1.0 / float(rate), size=count)
    offsets = np.cumsum(gaps)
    if lazy:
        return ((float(offsets[i]), make_request(i)) for i in range(count))
    return [(float(offsets[i]), make_request(i)) for i in range(count)]
