"""GraphEdge serving subsystem — the pipelined request engine.

``repro.serve.engine`` turns the control plane (`repro.core.api`) plus the
distributed forward (`repro.gnn.distributed`) into a request pipeline:
topology-delta detection, a bounded plan cache, and async-dispatch overlap
of the next control decision with the in-flight GNN forward. See
DESIGN.md §5 ("Serving engine"); ``repro.launch.serve_gnn`` is the CLI.
"""
from repro.serve.engine import ServeRequest, ServeResult, ServingEngine

__all__ = ["ServeRequest", "ServeResult", "ServingEngine"]
