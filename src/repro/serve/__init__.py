"""GraphEdge serving subsystem — pipelined engine + streaming front-end.

``repro.serve.engine`` turns the control plane (`repro.core.api`) plus the
distributed forward (`repro.gnn.distributed`) into a request pipeline:
topology-delta detection, a bounded plan cache, and async-dispatch overlap
of the next control decision with the in-flight GNN forward (DESIGN.md §5).

``repro.serve.frontend`` is the production-shaped request front sitting on
top of it: a bounded :class:`RequestQueue` with explicit backpressure,
continuous batching of concurrent requests sharing a cached plan,
Lyapunov drift-plus-penalty admission control per tenant, and per-request
SLO telemetry (``repro.serve.metrics``) — DESIGN.md §7.

``repro.serve.faults`` is the deterministic chaos harness: seedable
:class:`FaultSchedule` timelines of server failures/recoveries and user
churn waves, injected into the engine/front-end through a clock-driven
:class:`FaultInjector` with drain-then-swap live migration — DESIGN.md §9.
``repro.launch.serve_gnn`` / ``repro.launch.serve_stream`` are the CLIs.
"""
from repro.serve.engine import (PlanEntry, ServeRequest, ServeResult,
                                ServingEngine, network_digest)
from repro.serve.faults import FaultInjector, FaultSchedule, FaultUpdate
from repro.serve.frontend import (AdmitAll, LyapunovAdmission, RequestQueue,
                                  StaticPriorityAdmission, StreamRequest,
                                  StreamResult, StreamingFrontend,
                                  poisson_workload)
from repro.serve.metrics import (CycleTelemetry, ManualClock, MonotonicClock,
                                 RequestTiming, summarize)

__all__ = [
    "AdmitAll", "CycleTelemetry", "FaultInjector", "FaultSchedule",
    "FaultUpdate", "LyapunovAdmission", "ManualClock",
    "MonotonicClock", "PlanEntry", "RequestQueue", "RequestTiming",
    "ServeRequest", "ServeResult", "ServingEngine",
    "StaticPriorityAdmission", "StreamRequest", "StreamResult",
    "StreamingFrontend", "network_digest", "poisson_workload", "summarize",
]
