"""Deterministic fault injection for the serving tier (DESIGN.md §9).

The paper's claim is *dynamic adaptation* — GraphEdge re-cuts and
re-offloads as the environment shifts — but a change-rate perturbation
never exercises the hard regime: an edge server dropping out mid-stream, a
wave of users arriving at once, a server limping along at half capacity.
This module provides the chaos harness every such scenario plugs into:

* :class:`FaultSchedule` — an immutable, sorted list of
  :class:`~repro.core.dynamic_graph.GraphEvent` entries on a **logical
  clock** (frontend pump cycles, or request indices for the raw engine).
  Built from an explicit event list, parsed from a compact CLI spec
  (:meth:`FaultSchedule.parse` — the ``--faults`` flag of ``serve_stream``
  / ``serve_gnn``), or sampled reproducibly (:meth:`FaultSchedule.random`).
* :class:`FaultInjector` — the clock-driven hook. It owns the base
  :class:`~repro.core.costs.EdgeNetwork`, a cumulative
  :class:`~repro.core.costs.ServerProfile`, and (optionally) the evolving
  user :class:`~repro.core.dynamic_graph.GraphState`. ``poll(cycle)``
  applies every event due at or before ``cycle`` exactly once and returns
  a :class:`FaultUpdate`; the consumer decides how to react
  (``ServingEngine.serve`` drains then swaps, ``StreamingFrontend.pump``
  additionally migrates its queue and warm-recuts — DESIGN.md §9 has the
  sequence diagram).

Determinism is the contract: the schedule is data, the injector's own rng
is seeded, and user waves consume randomness in event order — same seed +
same schedule ⇒ identical event trace, identical degraded networks,
identical churned states. Tests and the ``"mode": "failure"`` bench
records lean on this to compare a faulted run against a re-planned oracle
bitwise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Iterator, Sequence

import numpy as np

from repro.core import costs
from repro.core.dynamic_graph import (EVENT_ARRIVE, EVENT_DEGRADE,
                                      EVENT_DEPART, EVENT_KINDS,
                                      EVENT_SERVER_DOWN, EVENT_SERVER_UP,
                                      SERVER_EVENTS, USER_EVENTS, GraphEvent,
                                      GraphState, apply_user_event)

# degraded compute/capacity never scale below this (a server that is
# "down" is modeled by up=0, not by scale=0)
_MIN_DEGRADE = 1e-3


class FaultSchedule:
    """A deterministic, sorted sequence of timed fault events.

    Events are :class:`~repro.core.dynamic_graph.GraphEvent` tuples sorted
    by ``cycle`` (stable in input order within a cycle). The schedule is
    immutable — injectors keep a cursor into it, never mutate it."""

    def __init__(self, events: Iterable[GraphEvent]):
        evs = []
        for ev in events:
            ev = GraphEvent(*ev)
            if ev.kind not in EVENT_KINDS:
                raise ValueError(f"unknown event kind {ev.kind!r}; "
                                 f"expected one of {EVENT_KINDS}")
            evs.append(ev)
        self.events: tuple[GraphEvent, ...] = tuple(
            sorted(evs, key=lambda ev: ev.cycle))

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[GraphEvent]:
        return iter(self.events)

    def __eq__(self, other) -> bool:
        return isinstance(other, FaultSchedule) and \
            self.events == other.events

    def __repr__(self) -> str:
        return f"FaultSchedule({list(self.events)!r})"

    # -- construction --------------------------------------------------------
    @classmethod
    def parse(cls, spec: str) -> "FaultSchedule":
        """Parse the compact ``--faults`` CLI format.

        Comma-separated ``cycle:kind[:arg[:scale]]`` items, where ``arg``
        is the server id for server events and the wave size for user
        events, e.g. ``"2:server_down:1,4:arrive:6,7:server_up:1"`` or
        ``"3:degrade:0:0.5"`` (server 0 at half capacity/compute)."""
        events = []
        for item in filter(None, (s.strip() for s in spec.split(","))):
            parts = item.split(":")
            if len(parts) < 2:
                raise ValueError(f"bad fault item {item!r}; expected "
                                 "'cycle:kind[:arg[:scale]]'")
            cycle, kind = int(parts[0]), parts[1]
            arg = int(parts[2]) if len(parts) > 2 else (1 if kind in
                                                        USER_EVENTS else 0)
            scale = float(parts[3]) if len(parts) > 3 else 0.5
            if kind in USER_EVENTS:
                events.append(GraphEvent(cycle, kind, count=arg))
            else:
                events.append(GraphEvent(cycle, kind, server=arg,
                                         scale=scale))
        return cls(events)

    @classmethod
    def random(cls, seed: int, cycles: int, num_servers: int,
               p_server: float = 0.1, p_user: float = 0.2,
               max_wave: int = 8) -> "FaultSchedule":
        """Sample a reproducible schedule: per cycle, a server flips
        down/up with prob ``p_server`` (downs and ups alternate per
        server so the schedule is always consistent) and a user wave
        arrives/departs with prob ``p_user``."""
        rng = np.random.default_rng(seed)
        down: set[int] = set()
        events = []
        for c in range(int(cycles)):
            if rng.random() < p_server:
                s = int(rng.integers(num_servers))
                if s in down:
                    down.discard(s)
                    events.append(GraphEvent(c, EVENT_SERVER_UP, server=s))
                else:
                    down.add(s)
                    events.append(GraphEvent(c, EVENT_SERVER_DOWN, server=s))
            if rng.random() < p_user:
                kind = EVENT_ARRIVE if rng.random() < 0.5 else EVENT_DEPART
                events.append(GraphEvent(
                    c, kind, count=int(rng.integers(1, max_wave + 1))))
        return cls(events)

    # -- views ---------------------------------------------------------------
    def user_events(self) -> "FaultSchedule":
        """Only the arrive/depart events (e.g. for pre-applying churn to a
        request stream while the engine handles server events)."""
        return FaultSchedule(ev for ev in self.events
                             if ev.kind in USER_EVENTS)

    def server_events(self) -> "FaultSchedule":
        """Only the server down/up/degrade events."""
        return FaultSchedule(ev for ev in self.events
                             if ev.kind in SERVER_EVENTS)

    def events_at(self, cycle: int) -> tuple[GraphEvent, ...]:
        return tuple(ev for ev in self.events if ev.cycle == int(cycle))

    def as_dicts(self) -> list[dict]:
        return [ev._asdict() for ev in self.events]


@dataclass(frozen=True)
class FaultUpdate:
    """What :meth:`FaultInjector.poll` hands back for one clock tick.

    ``net`` is the repriced network when any *server* event fired (None ⇒
    server health unchanged — consumers skip the swap/migration path
    entirely); ``state`` is the churned user layout when any *user* event
    fired (None ⇒ no churn). ``events`` lists exactly what was applied,
    in order, for trace records."""
    cycle: int
    events: tuple[GraphEvent, ...]
    net: costs.EdgeNetwork | None
    state: GraphState | None
    num_up: int


class FaultInjector:
    """Clock-driven fault hook: owns the cumulative server profile and the
    evolving user state; ``poll(cycle)`` applies due events exactly once.

    The injector is strictly forward-moving (a cursor over the sorted
    schedule), so polling with a clock that skips cycles still applies
    every intervening event — late, but never dropped or doubled."""

    def __init__(self, schedule: FaultSchedule,
                 net: costs.EdgeNetwork,
                 state: GraphState | None = None, seed: int = 0):
        self.schedule = schedule
        self.base_net = net
        m = int(np.asarray(net.f_k).shape[0])
        self._up = np.ones(m, np.float32)
        self._compute = np.ones(m, np.float32)
        self._capacity = np.ones(m, np.float32)
        self._energy = np.ones(m, np.float32)
        self.state = state
        self.rng = np.random.default_rng(seed)
        self._cursor = 0
        self.applied: list[GraphEvent] = []

    @property
    def num_up(self) -> int:
        return int(self._up.sum())

    def profile(self) -> costs.ServerProfile:
        """The cumulative per-server health profile applied so far."""
        import jax.numpy as jnp
        return costs.ServerProfile(
            up=jnp.asarray(self._up),
            compute_scale=jnp.asarray(self._compute),
            capacity_scale=jnp.asarray(self._capacity),
            energy_scale=jnp.asarray(self._energy))

    def network(self) -> costs.EdgeNetwork:
        """The base network repriced under the current profile."""
        return costs.degrade_network(self.base_net, self.profile())

    def _apply_server(self, ev: GraphEvent) -> None:
        s = int(ev.server)
        if ev.kind == EVENT_SERVER_DOWN:
            self._up[s] = 0.0
        elif ev.kind == EVENT_SERVER_UP:
            # recovery restores full health, not just reachability
            self._up[s] = 1.0
            self._compute[s] = self._capacity[s] = self._energy[s] = 1.0
        elif ev.kind == EVENT_DEGRADE:
            scale = max(float(ev.scale), _MIN_DEGRADE)
            self._compute[s] = scale
            self._capacity[s] = scale
            self._energy[s] = 1.0 / scale   # degraded silicon burns hotter

    def poll(self, cycle: int) -> FaultUpdate | None:
        """Apply every not-yet-applied event with ``ev.cycle <= cycle``.

        Returns None when nothing was due. User waves consume the
        injector's rng in event order (the determinism contract)."""
        due = []
        events = self.schedule.events
        while self._cursor < len(events) and \
                events[self._cursor].cycle <= int(cycle):
            due.append(events[self._cursor])
            self._cursor += 1
        if not due:
            return None
        server_changed = churned = False
        for ev in due:
            if ev.kind in SERVER_EVENTS:
                self._apply_server(ev)
                server_changed = True
            elif self.state is not None:
                self.state = apply_user_event(self.rng, self.state, ev)
                churned = True
            self.applied.append(ev)
        return FaultUpdate(
            cycle=int(cycle), events=tuple(due),
            net=self.network() if server_changed else None,
            state=self.state if churned else None,
            num_up=self.num_up)
