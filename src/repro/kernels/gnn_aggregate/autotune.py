"""Blocking autotuner for the fused gather–normalize–matmul kernel.

The fused kernel (``fused.py``) is parameterized by a small config:

* ``bm``  — rows per output tile (the gather width),
* ``bf``  — feature columns per tile (both the XC slab slice and the
  matmul K-dim chunk share it, so one knob bounds the VMEM slab),
* ``kc``  — neighbor slots gathered per inner step (the prefetch chunk of
  the two-pass layout: gather ``[bm, kc]`` rows, then accumulate them
  tile-locally before the next chunk lands).

Good choices depend on the *layout*, not the values: the padded slot
count K (``max_degree``), the row/column counts, the feature widths and
the VMEM budget. :func:`heuristic_config` derives a config from those in
closed form (deterministic — same shapes, same config);
:func:`autotune_config` measures a small candidate grid with an
injectable timer and persists the winner in a JSON **tuning table** keyed
by the shape signature, so subsequent runs (and other processes) skip
the search. Table lookup order: explicit ``table_path`` argument, the
``REPRO_GNN_AGG_TUNING`` environment variable, then the checked-in
``tuning_table.json`` next to this module (read-only defaults for the
benchmark shapes).
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Callable, NamedTuple

# Per-core VMEM on current TPUs is 16 MiB; leave headroom for the
# index/value blocks, the accumulator and double buffering.
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024
_LANE = 128          # TPU lane width: feature blocks are multiples of this
_SUBLANE = 8         # f32 sublane: row blocks are multiples of this

_DEFAULT_TABLE = pathlib.Path(__file__).resolve().parent / \
    "tuning_table.json"
_ENV_TABLE = "REPRO_GNN_AGG_TUNING"


class KernelConfig(NamedTuple):
    """Blocking for one fused-aggregate call (see module docstring)."""
    bm: int              # rows per tile
    bf: int              # feature columns per tile
    kc: int              # neighbor slots per gather chunk


def shape_key(n_rows: int, n_cols: int, f_in: int, f_out: int,
              max_degree: int) -> str:
    """Tuning-table key: the layout signature the config depends on."""
    return f"n{n_rows}_c{n_cols}_fi{f_in}_fo{f_out}_k{max_degree}"


def _round_up(x: int, mult: int) -> int:
    return max(mult, ((x + mult - 1) // mult) * mult)


def _round_down_pow2(x: int) -> int:
    p = 1
    while p * 2 <= x:
        p *= 2
    return p


def vmem_bytes(config: KernelConfig, n_cols: int, max_degree: int) -> int:
    """Resident VMEM of one fused tile: the ``[n_cols, bf]`` XC slab, the
    ``[bm, K]`` index/value blocks, the ``[bf, bf]`` weight block, the
    ``[bm, kc, bf]`` gather buffer and the ``[bm, bf]`` accumulator/out."""
    bm, bf, kc = config
    k = _round_up(max_degree, kc)
    return 4 * (n_cols * bf           # XC slab slice
                + 2 * bm * k          # idx (i32) + val (f32)
                + bf * bf             # W block
                + bm * kc * bf        # gathered chunk
                + 2 * bm * bf)        # accumulator + out tile


def heuristic_config(n_rows: int, n_cols: int, f_in: int, f_out: int,
                     max_degree: int,
                     vmem_budget: int = DEFAULT_VMEM_BUDGET
                     ) -> KernelConfig:
    """Deterministic closed-form config from the layout shape.

    ``bf`` covers the feature width up to one lane tile (128), rounded to
    the f32 sublane — narrower features keep narrower tiles instead of
    paying pad-gather work on every slot; it is also the knob that
    shrinks first when the ``n_cols·bf`` slab would blow the budget.
    ``bm`` targets 256 rows (two gathers in flight per tile) and shrinks
    next; ``kc`` is the largest power of two ≤ ``max_degree`` capped at
    8 — deeper chunks enlarge the gather buffer faster than they amortize
    loop overhead (measured on the bench shapes; see BENCH_kernels)."""
    bf = min(_LANE, _round_up(max(f_in, f_out), _SUBLANE))
    bm = min(256, _round_up(n_rows, _SUBLANE))
    kc = min(8, _round_down_pow2(max(1, max_degree)))
    cfg = KernelConfig(bm, bf, kc)
    while vmem_bytes(cfg, n_cols, max_degree) > vmem_budget and \
            cfg.bf > _SUBLANE:
        cfg = cfg._replace(bf=cfg.bf // 2)
    while vmem_bytes(cfg, n_cols, max_degree) > vmem_budget and \
            cfg.bm > _SUBLANE:
        cfg = cfg._replace(bm=max(_SUBLANE, cfg.bm // 2))
    while vmem_bytes(cfg, n_cols, max_degree) > vmem_budget and cfg.kc > 1:
        cfg = cfg._replace(kc=cfg.kc // 2)
    return cfg


# ---------------------------------------------------------------------------
# persisted tuning table
# ---------------------------------------------------------------------------

def table_path(explicit: str | os.PathLike | None = None) -> pathlib.Path:
    if explicit is not None:
        return pathlib.Path(explicit)
    env = os.environ.get(_ENV_TABLE)
    return pathlib.Path(env) if env else _DEFAULT_TABLE


def load_table(path: str | os.PathLike | None = None) -> dict:
    p = table_path(path)
    try:
        raw = json.loads(p.read_text())
    except (OSError, json.JSONDecodeError):
        return {}
    return {k: KernelConfig(*v) for k, v in raw.items()
            if isinstance(v, (list, tuple)) and len(v) == 3}


def save_table(table: dict, path: str | os.PathLike | None = None) -> None:
    p = table_path(path)
    p.write_text(json.dumps({k: list(v) for k, v in sorted(table.items())},
                            indent=2) + "\n")


def get_config(n_rows: int, n_cols: int, f_in: int, f_out: int,
               max_degree: int, vmem_budget: int = DEFAULT_VMEM_BUDGET,
               table: dict | None = None,
               table_path: str | os.PathLike | None = None) -> KernelConfig:
    """Tuned config for a layout: the persisted table when it has the
    shape key (and the entry still fits the budget), else the heuristic.
    Deterministic: same arguments, same config."""
    table = load_table(table_path) if table is None else table
    hit = table.get(shape_key(n_rows, n_cols, f_in, f_out, max_degree))
    if hit is not None and vmem_bytes(hit, n_cols, max_degree) <= \
            vmem_budget:
        return hit
    return heuristic_config(n_rows, n_cols, f_in, f_out, max_degree,
                            vmem_budget)


def candidate_configs(n_rows: int, n_cols: int, f_in: int, f_out: int,
                      max_degree: int,
                      vmem_budget: int = DEFAULT_VMEM_BUDGET
                      ) -> list[KernelConfig]:
    """The small deterministic candidate grid the autotuner measures:
    the heuristic plus neighbors along each axis, budget-filtered."""
    base = heuristic_config(n_rows, n_cols, f_in, f_out, max_degree,
                            vmem_budget)
    seen, out = set(), []
    for bm in (base.bm // 2, base.bm, base.bm * 2):
        for kc in (max(1, base.kc // 2), base.kc, base.kc * 2):
            cfg = KernelConfig(max(_SUBLANE, bm), base.bf,
                               min(kc, max(1, max_degree)))
            if cfg in seen:
                continue
            seen.add(cfg)
            if vmem_bytes(cfg, n_cols, max_degree) <= vmem_budget:
                out.append(cfg)
    return out


def autotune_config(n_rows: int, n_cols: int, f_in: int, f_out: int,
                    max_degree: int,
                    measure: Callable[[KernelConfig], float],
                    vmem_budget: int = DEFAULT_VMEM_BUDGET,
                    persist: bool = False,
                    table_path: str | os.PathLike | None = None
                    ) -> tuple[KernelConfig, dict]:
    """Measure the candidate grid and return (best config, timings µs).

    ``measure(config) -> seconds_or_µs`` is injected so tests can drive
    the search with a deterministic fake timer. Ties break toward the
    candidate-grid order (itself deterministic), so the winner is a pure
    function of the measurements. ``persist=True`` writes the winner into
    the tuning table at ``table_path`` (merging with existing entries)."""
    cands = candidate_configs(n_rows, n_cols, f_in, f_out, max_degree,
                              vmem_budget)
    timings = {cfg: float(measure(cfg)) for cfg in cands}
    best = min(cands, key=lambda c: (timings[c], cands.index(c)))
    if persist:
        table = load_table(table_path)
        table[shape_key(n_rows, n_cols, f_in, f_out, max_degree)] = best
        save_table(table, table_path)
    return best, timings
