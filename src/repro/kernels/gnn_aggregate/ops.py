"""Public ops: normalized_aggregate (dense), gather_aggregate (sparse) and
fused_gather_aggregate (sparse aggregation + layer matmul in one kernel).

``impl`` on all three:
  * "xla"      — plain jnp (runs everywhere; what the dry-run lowers)
  * "pallas"   — the TPU kernel (real hardware)
  * "interpret"— the Pallas kernel in interpret mode (CPU validation)

The sparse op consumes the *padded neighbor-list* layout ([N, K] ``nbr_idx``
int32 + ``nbr_val`` float32, 0-padded): a fixed-shape padded CSR whose pad
slots carry val = 0, so they are numerically inert no matter which (valid)
index they point at. :func:`padded_neighbors_from_coo` /
:func:`dense_to_padded_neighbors` build that layout in O(E) vectorized
numpy; the partition-plan builder (repro.gnn.distributed) and the layer
auto-dispatch (repro.gnn.layers) share them.

``SPARSE_DENSITY_THRESHOLD`` is the density below which callers holding a
dense adjacency should prefer the gather path (see DESIGN.md §4): at
nnz/N² ≈ 0.05 the K·F gather work is ~20× smaller than the N·F dense
contraction, which covers conversion overhead and the gather's worse
MXU utilization with margin.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.gnn_aggregate.autotune import (DEFAULT_VMEM_BUDGET,
                                                  get_config, vmem_bytes)
from repro.kernels.gnn_aggregate.fused import gnn_fused_aggregate_pallas
from repro.kernels.gnn_aggregate.gnn_aggregate import (
    gnn_aggregate_pallas, gnn_gather_aggregate_pallas)
from repro.kernels.gnn_aggregate.ref import (gather_aggregate_ref,
                                             normalized_aggregate_ref)

SPARSE_DENSITY_THRESHOLD = 0.05


def _pad_to(x: jnp.ndarray, mult: int, axes: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, 0)] * x.ndim
    for ax in axes:
        rem = (-x.shape[ax]) % mult
        pads[ax] = (0, rem)
    return jnp.pad(x, pads) if any(p != (0, 0) for p in pads) else x


def normalized_aggregate(adj: jnp.ndarray, x: jnp.ndarray,
                         row_scale, col_scale, impl: str = "xla",
                         block: int = 128) -> jnp.ndarray:
    if impl == "xla":
        return normalized_aggregate_ref(adj, x, row_scale, col_scale)
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    n, f = adj.shape[0], x.shape[1]
    rs = jnp.broadcast_to(jnp.asarray(row_scale, jnp.float32), (n,))
    cs = jnp.broadcast_to(jnp.asarray(col_scale, jnp.float32), (n,))
    adj_p = _pad_to(adj, block, (0, 1))
    x_p = _pad_to(x, block, (0, 1))
    rs_p = _pad_to(rs, block, (0,))
    cs_p = _pad_to(cs, block, (0,))
    y = gnn_aggregate_pallas(adj_p, x_p, rs_p, cs_p,
                             bm=block, bk=block, bf=block,
                             interpret=(impl == "interpret"))
    return y[:n, :f]


# ---------------------------------------------------------------------------
# sparse path: padded neighbor-list layout + gather op
# ---------------------------------------------------------------------------

def rank_within_sorted_groups(groups: np.ndarray, num_groups: int
                              ) -> tuple[np.ndarray, np.ndarray]:
    """For a sorted group-id array, return (rank within group, group sizes).

    The O(E) bucketing primitive behind every padded/blocked-sparse layout
    here (neighbor slots, per-device vertex slots, halo slots)."""
    counts = np.bincount(groups, minlength=num_groups)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.arange(len(groups)) - starts[groups], counts


def padded_neighbors_from_coo(src: np.ndarray, dst: np.ndarray,
                              val: np.ndarray, n_rows: int,
                              min_k: int = 1
                              ) -> tuple[np.ndarray, np.ndarray]:
    """COO triples → padded per-row neighbor lists, O(E) vectorized.

    Returns ``(nbr_idx [n_rows, K] int32, nbr_val [n_rows, K] float32)``
    with K = max(row degree, ``min_k``); pad slots are (0, 0.0). Duplicate
    (src, dst) entries are kept as separate slots (they sum, like COO)."""
    src = np.asarray(src, np.int64).ravel()
    dst = np.asarray(dst, np.int64).ravel()
    val = np.broadcast_to(np.asarray(val, np.float32), src.shape)
    order = np.argsort(src, kind="stable")
    src_s, dst_s, val_s = src[order], dst[order], val[order]
    pos, deg = rank_within_sorted_groups(src_s, n_rows)
    k = max(min_k, int(deg.max(initial=0)))
    nbr_idx = np.zeros((n_rows, k), np.int32)
    nbr_val = np.zeros((n_rows, k), np.float32)
    nbr_idx[src_s, pos] = dst_s
    nbr_val[src_s, pos] = val_s
    return nbr_idx, nbr_val


def dense_to_padded_neighbors(adj: np.ndarray
                              ) -> tuple[np.ndarray, np.ndarray]:
    """Dense [N, M] adjacency → padded neighbor lists (rows gather cols)."""
    adj = np.asarray(adj)
    src, dst = np.nonzero(adj)
    return padded_neighbors_from_coo(src, dst, adj[src, dst].astype(
        np.float32), adj.shape[0])


def sort_neighbor_slots(nbr_idx, nbr_val) -> tuple[np.ndarray, np.ndarray]:
    """Sort every row's neighbor slots by destination index, pads last.

    The host-side "sort-by-slot prefetch" pass of the blocked fused layout
    (kernels.gnn_aggregate.fused): within a row tile the gathers then walk
    the resident XC slab quasi-monotonically instead of in insertion
    order. Pure slot permutation per row — the aggregate is unchanged up
    to float addition order. Works on [..., K] stacks (numpy, host-side)."""
    idx = np.asarray(nbr_idx)
    val = np.asarray(nbr_val)
    key = np.where(val != 0, idx.astype(np.int64), np.iinfo(np.int64).max)
    order = np.argsort(key, axis=-1, kind="stable")
    return (np.take_along_axis(idx, order, -1),
            np.take_along_axis(val, order, -1))


def gather_block_columns(n_cols: int, k: int, block: int = 128,
                         vmem_budget: int = DEFAULT_VMEM_BUDGET) -> int:
    """The feature-block width ``bf`` for ``gnn_gather_aggregate_pallas``.

    Enforces the kernel docstring's precondition — the resident
    [n_cols, bf] XC slab (plus the [block, K] index/value blocks and the
    output tile) must fit the VMEM budget — by halving ``bf`` from
    ``block`` until it fits, and raising a clear error when even the
    minimum width cannot."""
    def resident(bf: int) -> int:
        return 4 * (n_cols * bf + 2 * block * k + block + block * bf)

    bf = block
    while resident(bf) > vmem_budget and bf > 8:
        bf //= 2
    if resident(bf) > vmem_budget:
        raise ValueError(
            f"gather kernel: the [{n_cols}, {bf}] XC slab plus the "
            f"[{block}, {k}] index/value blocks need {resident(bf)} B, "
            f"over the {vmem_budget} B VMEM budget even at the minimum "
            f"feature block — shard the columns or raise the budget")
    return bf


def gather_aggregate(nbr_idx: jnp.ndarray, nbr_val: jnp.ndarray,
                     x: jnp.ndarray, row_scale, col_scale,
                     impl: str = "xla", block: int = 128,
                     vmem_budget: int | None = None) -> jnp.ndarray:
    """Sparse Y = (diag(rs)·A·diag(cs)) @ X over padded neighbor lists."""
    if impl == "xla":
        return gather_aggregate_ref(nbr_idx, nbr_val, x, row_scale,
                                    col_scale)
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    n, k = nbr_idx.shape
    f = x.shape[1]
    budget = DEFAULT_VMEM_BUDGET if vmem_budget is None else int(vmem_budget)
    bf = gather_block_columns(x.shape[0], k, block, budget)
    cs = jnp.broadcast_to(jnp.asarray(col_scale, jnp.float32),
                          (x.shape[0],))
    xc = x.astype(jnp.float32) * cs[:, None]
    rs = jnp.broadcast_to(jnp.asarray(row_scale, jnp.float32), (n,))
    # pad rows of the neighbor lists and features of xc; pad rows of xc are
    # never indexed (indices stay < x.shape[0]) so only F needs padding there
    idx_p = _pad_to(jnp.asarray(nbr_idx), block, (0,))
    val_p = _pad_to(jnp.asarray(nbr_val), block, (0,))
    rs_p = _pad_to(rs, block, (0,))
    xc_p = _pad_to(xc, bf, (1,))
    y = gnn_gather_aggregate_pallas(idx_p, val_p, xc_p, rs_p,
                                    bm=block, bf=bf,
                                    interpret=(impl == "interpret"))
    return y[:n, :f].astype(x.dtype)


def fused_gather_aggregate(nbr_idx: jnp.ndarray, nbr_val: jnp.ndarray,
                           x: jnp.ndarray, row_scale, col_scale,
                           w: jnp.ndarray, impl: str = "xla",
                           config=None,
                           vmem_budget: int | None = None) -> jnp.ndarray:
    """Fused layer hot path Y = (diag(rs)·A·diag(cs)·X) @ W, one kernel.

    The gather+normalize aggregation and the layer weight matmul run in a
    single blocked pass (kernels.gnn_aggregate.fused) — the gathered
    neighborhood feeds the matmul tile-locally, never materializing the
    aggregated [N, F_in] slab. ``config`` (an ``autotune.KernelConfig``)
    overrides the tuned blocking; by default ``autotune.get_config``
    resolves it from the persisted tuning table or the closed-form
    heuristic. Callers should pre-sort slots with
    :func:`sort_neighbor_slots` for the prefetch-friendly layout."""
    if impl == "xla":
        y = gather_aggregate_ref(nbr_idx, nbr_val, x.astype(jnp.float32),
                                 row_scale, col_scale)
        return (y @ jnp.asarray(w, jnp.float32)).astype(x.dtype)
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    n, k = nbr_idx.shape
    n_cols, f_in = x.shape
    f_out = w.shape[1]
    budget = DEFAULT_VMEM_BUDGET if vmem_budget is None else int(vmem_budget)
    if config is None:
        config = get_config(n, n_cols, f_in, f_out, k, vmem_budget=budget)
    if vmem_bytes(config, n_cols, k) > budget:
        raise ValueError(
            f"fused kernel config {tuple(config)} needs "
            f"{vmem_bytes(config, n_cols, k)} B resident for n_cols="
            f"{n_cols}, K={k}, over the {budget} B VMEM budget")
    bm, bf, kc = config
    cs = jnp.broadcast_to(jnp.asarray(col_scale, jnp.float32), (n_cols,))
    xc = x.astype(jnp.float32) * cs[:, None]
    rs = jnp.broadcast_to(jnp.asarray(row_scale, jnp.float32), (n,))
    idx_p = _pad_to(_pad_to(jnp.asarray(nbr_idx), bm, (0,)), kc, (1,))
    val_p = _pad_to(_pad_to(jnp.asarray(nbr_val), bm, (0,)), kc, (1,))
    rs_p = _pad_to(rs, bm, (0,))
    xc_p = _pad_to(xc, bf, (1,))
    w_p = _pad_to(jnp.asarray(w, jnp.float32), bf, (0, 1))
    y = gnn_fused_aggregate_pallas(idx_p, val_p, xc_p, rs_p, w_p,
                                   bm=bm, bf=bf, kc=kc,
                                   interpret=(impl == "interpret"))
    return y[:n, :f_out].astype(x.dtype)
