"""Public op: normalized_aggregate — dispatches XLA / Pallas, handles padding.

``impl``:
  * "xla"      — plain jnp (runs everywhere; what the dry-run lowers)
  * "pallas"   — the TPU kernel (real hardware)
  * "interpret"— the Pallas kernel in interpret mode (CPU validation)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.gnn_aggregate.gnn_aggregate import gnn_aggregate_pallas
from repro.kernels.gnn_aggregate.ref import normalized_aggregate_ref


def _pad_to(x: jnp.ndarray, mult: int, axes: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, 0)] * x.ndim
    for ax in axes:
        rem = (-x.shape[ax]) % mult
        pads[ax] = (0, rem)
    return jnp.pad(x, pads) if any(p != (0, 0) for p in pads) else x


def normalized_aggregate(adj: jnp.ndarray, x: jnp.ndarray,
                         row_scale, col_scale, impl: str = "xla",
                         block: int = 128) -> jnp.ndarray:
    if impl == "xla":
        return normalized_aggregate_ref(adj, x, row_scale, col_scale)
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    n, f = adj.shape[0], x.shape[1]
    rs = jnp.broadcast_to(jnp.asarray(row_scale, jnp.float32), (n,))
    cs = jnp.broadcast_to(jnp.asarray(col_scale, jnp.float32), (n,))
    adj_p = _pad_to(adj, block, (0, 1))
    x_p = _pad_to(x, block, (0, 1))
    rs_p = _pad_to(rs, block, (0,))
    cs_p = _pad_to(cs, block, (0,))
    y = gnn_aggregate_pallas(adj_p, x_p, rs_p, cs_p,
                             bm=block, bk=block, bf=block,
                             interpret=(impl == "interpret"))
    return y[:n, :f]
