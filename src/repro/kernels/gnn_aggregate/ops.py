"""Public ops: normalized_aggregate (dense) and gather_aggregate (sparse).

``impl`` on both:
  * "xla"      — plain jnp (runs everywhere; what the dry-run lowers)
  * "pallas"   — the TPU kernel (real hardware)
  * "interpret"— the Pallas kernel in interpret mode (CPU validation)

The sparse op consumes the *padded neighbor-list* layout ([N, K] ``nbr_idx``
int32 + ``nbr_val`` float32, 0-padded): a fixed-shape padded CSR whose pad
slots carry val = 0, so they are numerically inert no matter which (valid)
index they point at. :func:`padded_neighbors_from_coo` /
:func:`dense_to_padded_neighbors` build that layout in O(E) vectorized
numpy; the partition-plan builder (repro.gnn.distributed) and the layer
auto-dispatch (repro.gnn.layers) share them.

``SPARSE_DENSITY_THRESHOLD`` is the density below which callers holding a
dense adjacency should prefer the gather path (see DESIGN.md §4): at
nnz/N² ≈ 0.05 the K·F gather work is ~20× smaller than the N·F dense
contraction, which covers conversion overhead and the gather's worse
MXU utilization with margin.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.gnn_aggregate.gnn_aggregate import (
    gnn_aggregate_pallas, gnn_gather_aggregate_pallas)
from repro.kernels.gnn_aggregate.ref import (gather_aggregate_ref,
                                             normalized_aggregate_ref)

SPARSE_DENSITY_THRESHOLD = 0.05


def _pad_to(x: jnp.ndarray, mult: int, axes: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, 0)] * x.ndim
    for ax in axes:
        rem = (-x.shape[ax]) % mult
        pads[ax] = (0, rem)
    return jnp.pad(x, pads) if any(p != (0, 0) for p in pads) else x


def normalized_aggregate(adj: jnp.ndarray, x: jnp.ndarray,
                         row_scale, col_scale, impl: str = "xla",
                         block: int = 128) -> jnp.ndarray:
    if impl == "xla":
        return normalized_aggregate_ref(adj, x, row_scale, col_scale)
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    n, f = adj.shape[0], x.shape[1]
    rs = jnp.broadcast_to(jnp.asarray(row_scale, jnp.float32), (n,))
    cs = jnp.broadcast_to(jnp.asarray(col_scale, jnp.float32), (n,))
    adj_p = _pad_to(adj, block, (0, 1))
    x_p = _pad_to(x, block, (0, 1))
    rs_p = _pad_to(rs, block, (0,))
    cs_p = _pad_to(cs, block, (0,))
    y = gnn_aggregate_pallas(adj_p, x_p, rs_p, cs_p,
                             bm=block, bk=block, bf=block,
                             interpret=(impl == "interpret"))
    return y[:n, :f]


# ---------------------------------------------------------------------------
# sparse path: padded neighbor-list layout + gather op
# ---------------------------------------------------------------------------

def rank_within_sorted_groups(groups: np.ndarray, num_groups: int
                              ) -> tuple[np.ndarray, np.ndarray]:
    """For a sorted group-id array, return (rank within group, group sizes).

    The O(E) bucketing primitive behind every padded/blocked-sparse layout
    here (neighbor slots, per-device vertex slots, halo slots)."""
    counts = np.bincount(groups, minlength=num_groups)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.arange(len(groups)) - starts[groups], counts


def padded_neighbors_from_coo(src: np.ndarray, dst: np.ndarray,
                              val: np.ndarray, n_rows: int,
                              min_k: int = 1
                              ) -> tuple[np.ndarray, np.ndarray]:
    """COO triples → padded per-row neighbor lists, O(E) vectorized.

    Returns ``(nbr_idx [n_rows, K] int32, nbr_val [n_rows, K] float32)``
    with K = max(row degree, ``min_k``); pad slots are (0, 0.0). Duplicate
    (src, dst) entries are kept as separate slots (they sum, like COO)."""
    src = np.asarray(src, np.int64).ravel()
    dst = np.asarray(dst, np.int64).ravel()
    val = np.broadcast_to(np.asarray(val, np.float32), src.shape)
    order = np.argsort(src, kind="stable")
    src_s, dst_s, val_s = src[order], dst[order], val[order]
    pos, deg = rank_within_sorted_groups(src_s, n_rows)
    k = max(min_k, int(deg.max(initial=0)))
    nbr_idx = np.zeros((n_rows, k), np.int32)
    nbr_val = np.zeros((n_rows, k), np.float32)
    nbr_idx[src_s, pos] = dst_s
    nbr_val[src_s, pos] = val_s
    return nbr_idx, nbr_val


def dense_to_padded_neighbors(adj: np.ndarray
                              ) -> tuple[np.ndarray, np.ndarray]:
    """Dense [N, M] adjacency → padded neighbor lists (rows gather cols)."""
    adj = np.asarray(adj)
    src, dst = np.nonzero(adj)
    return padded_neighbors_from_coo(src, dst, adj[src, dst].astype(
        np.float32), adj.shape[0])


def gather_aggregate(nbr_idx: jnp.ndarray, nbr_val: jnp.ndarray,
                     x: jnp.ndarray, row_scale, col_scale,
                     impl: str = "xla", block: int = 128) -> jnp.ndarray:
    """Sparse Y = (diag(rs)·A·diag(cs)) @ X over padded neighbor lists."""
    if impl == "xla":
        return gather_aggregate_ref(nbr_idx, nbr_val, x, row_scale,
                                    col_scale)
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    n, _ = nbr_idx.shape
    f = x.shape[1]
    cs = jnp.broadcast_to(jnp.asarray(col_scale, jnp.float32),
                          (x.shape[0],))
    xc = x.astype(jnp.float32) * cs[:, None]
    rs = jnp.broadcast_to(jnp.asarray(row_scale, jnp.float32), (n,))
    # pad rows of the neighbor lists and features of xc; pad rows of xc are
    # never indexed (indices stay < x.shape[0]) so only F needs padding there
    idx_p = _pad_to(jnp.asarray(nbr_idx), block, (0,))
    val_p = _pad_to(jnp.asarray(nbr_val), block, (0,))
    rs_p = _pad_to(rs, block, (0,))
    xc_p = _pad_to(xc, block, (1,))
    y = gnn_gather_aggregate_pallas(idx_p, val_p, xc_p, rs_p,
                                    bm=block, bf=block,
                                    interpret=(impl == "interpret"))
    return y[:n, :f].astype(x.dtype)
