"""Pallas TPU kernel: blocked normalized graph aggregation (masked SpMM).

TPU adaptation of the paper's GNN aggregation hot spot (Eq. 1 / Eq. 10): on
GPU this is gather/scatter message passing; on TPU we reformulate it as a
*blocked dense matmul with fused degree normalization*,

    Y[i, f] = Σ_k  rs[i] · A[i, k] · cs[k] · X[k, f],

tiled to MXU-aligned (128, 128) VMEM blocks. The normalization scales are
fused into the A-tile load, so the normalized adjacency is never
materialized in HBM (saves one full N×N HBM round-trip vs the naive
`(rs*A*cs) @ X` formulation).

Grid = (N/bm, F/bf, N/bk); the k axis is the reduction — o_ref accumulates
across the innermost grid dimension (standard Pallas matmul pattern).

A second, *gather-based* kernel serves the sparse regime (HiCut layouts,
PubMed-scale edge lists): rows carry a padded neighbor list
``nbr_idx``/``nbr_val`` ([N, K], 0-padded) and the kernel walks the K slots,
gathering one [bm, bf] slab of (column-scaled) X rows per slot — O(N·K·F)
instead of O(N²·F). The row/column normalization stays fused: cs is folded
into X by the op wrapper, rs is applied on the accumulator before the
store, so the normalized adjacency is again never materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
# jax<0.5 names this TPUCompilerParams; newer releases renamed it to CompilerParams
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _agg_kernel(a_ref, x_ref, rs_ref, cs_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    a = a * rs_ref[...][:, None] * cs_ref[...][None, :]
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(a, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bf", "interpret"))
def gnn_aggregate_pallas(adj: jnp.ndarray, x: jnp.ndarray,
                         row_scale: jnp.ndarray, col_scale: jnp.ndarray,
                         bm: int = 128, bk: int = 128, bf: int = 128,
                         interpret: bool = False) -> jnp.ndarray:
    """Y = (diag(rs)·A·diag(cs)) @ X with (bm, bk, bf) VMEM tiles.

    Shapes must be multiples of the block sizes (ops.py pads)."""
    n, _ = adj.shape
    f = x.shape[1]
    assert n % bm == 0 and n % bk == 0 and f % bf == 0, (n, f, bm, bk, bf)
    grid = (n // bm, f // bf, n // bk)
    out = pl.pallas_call(
        functools.partial(_agg_kernel, n_k=n // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bf), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(adj, x, jnp.broadcast_to(row_scale, (n,)).astype(jnp.float32),
      jnp.broadcast_to(col_scale, (n,)).astype(jnp.float32))
    return out.astype(x.dtype)


def _gather_kernel(idx_ref, val_ref, xc_ref, rs_ref, o_ref, *, n_k: int):
    """One (bm, bf) output tile: walk the K neighbor slots of the row block,
    gathering the matching rows of the column-scaled X slab."""
    idx = idx_ref[...]
    val = val_ref[...].astype(jnp.float32)
    xc = xc_ref[...].astype(jnp.float32)

    def body(k, acc):
        rows = jnp.take(xc, idx[:, k], axis=0)       # [bm, bf] gather
        return acc + val[:, k][:, None] * rows

    acc = jax.lax.fori_loop(0, n_k, body,
                            jnp.zeros(o_ref.shape, jnp.float32))
    o_ref[...] = acc * rs_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def gnn_gather_aggregate_pallas(nbr_idx: jnp.ndarray, nbr_val: jnp.ndarray,
                                xc: jnp.ndarray, row_scale: jnp.ndarray,
                                bm: int = 128, bf: int = 128,
                                interpret: bool = False) -> jnp.ndarray:
    """Y[i] = rs[i] · Σ_k val[i,k] · XC[idx[i,k]] over padded neighbor rows.

    ``xc`` is X with the column scale already folded in (ops.py does the
    fold + padding). The whole [n_cols, bf] feature slab is resident per
    tile, so n_cols·bf·4 B must fit VMEM alongside the [bm, K] index/value
    blocks — fine for per-device extended blocks (L + P·B rows); at very
    large n_cols shrink ``bf``. The per-slot row gather lowers through
    Mosaic's dynamic-gather path (and runs exactly in interpret mode, which
    is what CI validates on CPU)."""
    n, k = nbr_idx.shape
    n_cols, f = xc.shape
    assert n % bm == 0 and f % bf == 0, (n, f, bm, bf)
    grid = (n // bm, f // bf)
    out = pl.pallas_call(
        functools.partial(_gather_kernel, n_k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((n_cols, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(nbr_idx.astype(jnp.int32), nbr_val.astype(jnp.float32), xc,
      jnp.broadcast_to(row_scale, (n,)).astype(jnp.float32))
    return out
