"""Pure-jnp oracle for the GNN aggregation kernel.

Computes Y = (diag(rs) · A · diag(cs)) @ X — the normalized neighborhood
aggregation D̃^{-1/2} Â D̃^{-1/2} H of GCN Eq. (1) (rs = cs = D̃^{-1/2}), the
mean aggregator of GraphSAGE (rs = 1/deg, cs = 1), etc.
"""
from __future__ import annotations

import jax.numpy as jnp


def normalized_aggregate_ref(adj: jnp.ndarray, x: jnp.ndarray,
                             row_scale: jnp.ndarray,
                             col_scale: jnp.ndarray) -> jnp.ndarray:
    rs = jnp.broadcast_to(jnp.asarray(row_scale), (adj.shape[0],))
    cs = jnp.broadcast_to(jnp.asarray(col_scale), (adj.shape[1],))
    a = adj * rs[:, None] * cs[None, :]
    return (a @ x.astype(jnp.float32)).astype(x.dtype)
