"""Pure-jnp oracles for the GNN aggregation kernels.

Dense: Y = (diag(rs) · A · diag(cs)) @ X — the normalized neighborhood
aggregation D̃^{-1/2} Â D̃^{-1/2} H of GCN Eq. (1) (rs = cs = D̃^{-1/2}), the
mean aggregator of GraphSAGE (rs = 1/deg, cs = 1), etc.

Sparse: the same contraction over a *padded per-row neighbor list*
(``nbr_idx``/``nbr_val``, 0-padded — a padded CSR row layout): for every row
i, Y[i] = rs[i] · Σ_k val[i, k] · cs[idx[i, k]] · X[idx[i, k]].  O(N·K·F)
work instead of O(N²·F); pad slots carry val = 0 so they contribute nothing
regardless of their (valid, 0) index.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normalized_aggregate_ref(adj: jnp.ndarray, x: jnp.ndarray,
                             row_scale: jnp.ndarray,
                             col_scale: jnp.ndarray) -> jnp.ndarray:
    rs = jnp.broadcast_to(jnp.asarray(row_scale), (adj.shape[0],))
    cs = jnp.broadcast_to(jnp.asarray(col_scale), (adj.shape[1],))
    a = adj * rs[:, None] * cs[None, :]
    return (a @ x.astype(jnp.float32)).astype(x.dtype)


def gather_aggregate_ref(nbr_idx: jnp.ndarray, nbr_val: jnp.ndarray,
                         x: jnp.ndarray, row_scale: jnp.ndarray,
                         col_scale: jnp.ndarray) -> jnp.ndarray:
    """Sparse oracle over padded neighbor lists.

    The column scale is folded into X once (O(N·F)), then the scan walks
    the K neighbor slots gathering one [N, F] slab per slot — peak memory
    stays O(N·F), never O(N·K·F)."""
    n, _ = nbr_idx.shape
    rs = jnp.broadcast_to(jnp.asarray(row_scale), (n,)).astype(jnp.float32)
    cs = jnp.broadcast_to(jnp.asarray(col_scale),
                          (x.shape[0],)).astype(jnp.float32)
    xc = x.astype(jnp.float32) * cs[:, None]

    def step(acc, slot):
        idx_k, val_k = slot
        return acc + val_k[:, None].astype(jnp.float32) * xc[idx_k], None

    acc, _ = jax.lax.scan(step, jnp.zeros((n, x.shape[1]), jnp.float32),
                          (nbr_idx.T, nbr_val.T))
    return (acc * rs[:, None]).astype(x.dtype)
