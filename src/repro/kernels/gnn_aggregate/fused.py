"""Pallas TPU kernel: fused gather–normalize–matmul aggregation.

One kernel computes a whole GCN layer's hot path over the padded
neighbor-list layout (see ops.py),

    Y = rs · (Σ_k val[:, k] · XC[idx[:, k]]) @ W,

so the gathered neighborhood feeds the MXU directly instead of being
materialized as an [N, F_in] slab in HBM between a gather kernel and a
matmul (the unfused path does exactly that round-trip). The row scale is
applied on the accumulator before the matmul — linearity lets every
normalization commute through the contraction.

Layout: a *blocked two-pass* schedule replacing the slot-at-a-time
``fori_loop`` of ``gnn_aggregate._gather_kernel``:

* pass 1 (host, ops.py): neighbor slots are sorted by destination index
  with pads last (:func:`~repro.kernels.gnn_aggregate.ops.sort_neighbor_slots`),
  so each tile's gathers walk the resident XC slab quasi-monotonically —
  the prefetch-friendly order for Mosaic's dynamic-gather path;
* pass 2 (kernel): each ``(bm, bf)`` tile gathers ``kc`` slots at a time
  into a ``[bm, kc, bf]`` buffer and accumulates it tile-locally before
  the next chunk lands, amortizing gather issue overhead ``kc``× over the
  per-slot loop.

Grid = (N/bm, F_out/bf, F_in/bf); the F_in axis is the matmul reduction —
o_ref accumulates across the innermost grid dimension (standard Pallas
matmul pattern), with the ``[n_cols, bf]`` XC slab slice and the
``[bf, bf]`` weight block swapped per step. Block sizes come from
``autotune.get_config`` (persisted tuning table + closed-form heuristic);
``autotune.vmem_bytes`` is the resident-footprint model the configs are
validated against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 names this TPUCompilerParams; newer releases renamed it
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _fused_kernel(idx_ref, val_ref, xc_ref, rs_ref, w_ref, o_ref, *,
                  n_k: int, kc: int):
    """One (bm, bf) output tile for one F_in chunk: chunked gather of the
    row block's neighbor slots, tile-local weighted accumulate, row scale,
    then the weight-block matmul accumulated into the output tile."""
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]
    val = val_ref[...].astype(jnp.float32)
    xc = xc_ref[...].astype(jnp.float32)
    bm = idx.shape[0]
    acc = jnp.zeros((bm, xc.shape[1]), jnp.float32)
    for c in range(0, n_k, kc):                     # static: n_k % kc == 0
        rows = jnp.take(xc, idx[:, c:c + kc].reshape(-1), axis=0)
        rows = rows.reshape(bm, kc, xc.shape[1])
        acc = acc + (rows * val[:, c:c + kc][:, :, None]).sum(axis=1)
    acc = acc * rs_ref[...][:, None]
    o_ref[...] += jnp.dot(acc, w_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "kc", "interpret"))
def gnn_fused_aggregate_pallas(nbr_idx: jnp.ndarray, nbr_val: jnp.ndarray,
                               xc: jnp.ndarray, row_scale: jnp.ndarray,
                               w: jnp.ndarray, bm: int = 256, bf: int = 128,
                               kc: int = 8,
                               interpret: bool = False) -> jnp.ndarray:
    """Y = (rs · Σ_k val·XC[idx]) @ W over padded neighbor rows, fused.

    ``xc`` is X with the column scale folded in (ops.py does the fold +
    padding); ``w`` is the layer weight [F_in, F_out]. Row count must be a
    multiple of ``bm``, the slot count of ``kc``, both feature widths of
    ``bf`` (ops.py pads). The [n_cols, bf] slab slice stays VMEM-resident
    per tile — configs are budget-checked via ``autotune.vmem_bytes``."""
    n, k = nbr_idx.shape
    n_cols, f_in = xc.shape
    f_out = w.shape[1]
    assert n % bm == 0 and k % kc == 0, (n, k, bm, kc)
    assert f_in % bf == 0 and f_out % bf == 0, (f_in, f_out, bf)
    assert w.shape[0] == f_in, (w.shape, f_in)
    grid = (n // bm, f_out // bf, f_in // bf)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, n_k=k, kc=kc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j, l: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j, l: (i, 0)),
            pl.BlockSpec((n_cols, bf), lambda i, j, l: (0, l)),
            pl.BlockSpec((bm,), lambda i, j, l: (i,)),
            pl.BlockSpec((bf, bf), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f_out), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(nbr_idx.astype(jnp.int32), nbr_val.astype(jnp.float32), xc,
      jnp.broadcast_to(row_scale, (n,)).astype(jnp.float32),
      w.astype(jnp.float32))
    return out
