"""Public op: flash_attention — XLA / Pallas / interpret dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softcap: float | None = None, impl: str = "xla",
                    block: int = 128):
    """q [B,H,S,dh], k/v [B,KV,S,dh] → [B,H,S,dh]."""
    if impl == "xla":
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    s = q.shape[2]
    bq = bk = min(block, s)
    while s % bq:
        bq //= 2
    while s % bk:
        bk //= 2
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  softcap=softcap, bq=bq, bk=bk,
                                  interpret=(impl == "interpret"))
