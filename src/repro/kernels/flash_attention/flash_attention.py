"""Pallas TPU flash attention (causal, sliding-window, logit softcap, GQA).

Online-softmax over KV blocks: grid = (B, H, S/bq, S/bk) with the KV-block
axis innermost and ``arbitrary`` semantics; VMEM scratch carries the running
(max m, denominator l, accumulator acc) per query block across KV steps.
Block shapes default to (128, 128) — MXU-aligned, and the (bq·dh + bk·dh +
bq·bk) working set stays far under the ~16 MB v5e VMEM budget for dh ≤ 256.

Sliding-window and causal predicates are applied per-element inside the
block; fully-masked KV blocks are skipped with ``pl.when`` (no FLOPs, no
VMEM traffic beyond the prefetch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
# jax<0.5 names this TPUCompilerParams; newer releases renamed it to CompilerParams
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, nk: int, scale: float, causal: bool,
                  window: int | None, softcap: float | None):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    # skip KV blocks entirely above the causal diagonal / outside the window
    relevant = True
    if causal:
        relevant = k_start <= q_start + bq - 1
    if window is not None:
        relevant = jnp.logical_and(
            relevant, q_start - (k_start + bk - 1) < window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, dh]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           softcap: float | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q [B,H,S,dh], k/v [B,KV,S,dh] → [B,H,S,dh]. S divisible by bq/bk."""
    b, h, s, dh = q.shape
    kvh = k.shape[1]
    g = h // kvh
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, scale=dh ** -0.5, causal=causal,
        window=window, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
