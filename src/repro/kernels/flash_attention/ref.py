"""Pure-jnp oracle for flash attention (causal / sliding-window / softcap)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int | None = None,
                  softcap: float | None = None) -> jnp.ndarray:
    """q [B,H,S,dh], k/v [B,KV,S,dh] (GQA) → [B,H,S,dh]."""
    b, h, s, dh = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, s, dh).astype(jnp.float32)
    sc = jnp.einsum("bkgqd,bksd->bkgqs", qg,
                    k.astype(jnp.float32)) * dh ** -0.5
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = jnp.ones((s, s), bool)
    if causal:
        m &= i >= j
    if window is not None:
        m &= i - j < window
    sc = jnp.where(m[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, s, dh).astype(q.dtype)
