"""Public op: ssd_chunk_scan — XLA (jnp chunked) / Pallas / interpret."""
from __future__ import annotations

from repro.kernels.chunk_scan.chunk_scan import ssd_chunk_scan_pallas
from repro.kernels.chunk_scan.ref import ssd_scan_ref


def ssd_chunk_scan(x, bmat, cmat, loga, *, impl: str = "xla",
                   chunk: int = 128):
    """x [B,S,H,P] (Δ-scaled), b/c [B,S,N], loga [B,S,H] ≤ 0 → [B,S,H,P]."""
    if impl == "xla":
        return ssd_scan_ref(x, bmat, cmat, loga)
    if impl not in ("pallas", "interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    return ssd_chunk_scan_pallas(x, bmat, cmat, loga, chunk=chunk,
                                 interpret=(impl == "interpret"))
