"""Pure-jnp oracle for the SSD chunk-scan kernel: the exact sequential
state-space recurrence  h_t = a_t·h_{t−1} + x_t ⊗ B_t,  y_t = h_t·C_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x: jnp.ndarray, bmat: jnp.ndarray, cmat: jnp.ndarray,
                 loga: jnp.ndarray) -> jnp.ndarray:
    """x [B,S,H,P] (already Δ-scaled), b/c [B,S,N], loga [B,S,H] ≤ 0
    → y [B,S,H,P]."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]

    def step(state, ins):
        xt, bt, ct, lat = ins                      # [B,H,P], [B,N], ...
        state = state * jnp.exp(lat)[..., None, None] + \
            jnp.einsum("bhp,bn->bhpn", xt, bt)
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    init = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, init,
                         (x.swapaxes(0, 1).astype(jnp.float32),
                          bmat.swapaxes(0, 1).astype(jnp.float32),
                          cmat.swapaxes(0, 1).astype(jnp.float32),
                          loga.swapaxes(0, 1).astype(jnp.float32)))
    return ys.swapaxes(0, 1).astype(x.dtype)
