"""Pallas TPU kernel: fused Mamba2 SSD chunk scan.

One grid step processes one (batch, head, chunk) tile: the intra-chunk
quadratic part runs as dense [L,L] matmuls on the MXU, and the inter-chunk
[P,N] state lives in VMEM scratch and is carried across the (innermost,
``arbitrary``) chunk axis — the HBM round-trip for the state that a
chunk-by-chunk XLA scan would pay is eliminated, which is the point of
fusing (state is P·N floats per (b,h), re-read every chunk otherwise).

Inputs are pre-scaled x (Δ·x), shared B/C (single SSD group), and per-step
log-decay (≤ 0, so every exp here is ≤ 1 — numerically safe).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
# jax<0.5 names this TPUCompilerParams; newer releases renamed it to CompilerParams
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _ssd_kernel(x_ref, b_ref, c_ref, la_ref, y_ref, state_ref, *, l: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [L, P]
    bm = b_ref[0].astype(jnp.float32)                # [L, N]
    cm = c_ref[0].astype(jnp.float32)                # [L, N]
    la = la_ref[0, :, 0].astype(jnp.float32)         # [L]
    ca = jnp.cumsum(la)                              # [L]

    # intra-chunk: y_i = Σ_{j≤i} exp(ca_i − ca_j)·(C_i·B_j)·x_j
    g = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, L]
    dec = jnp.exp(ca[:, None] - ca[None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    w = jnp.where(ii >= jj, g * dec, 0.0)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, P]

    # inter-chunk: y_i += exp(ca_i) · C_i · Sᵀ  (S = state at chunk start)
    state = state_ref[...]                           # [P, N]
    y = y + jnp.exp(ca)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: S ← exp(ca_L)·S + Σ_j exp(ca_L − ca_j)·x_j ⊗ B_j
    dec_end = jnp.exp(ca[-1] - ca)                   # [L]
    inc = jax.lax.dot_general(x, bm * dec_end[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [P, N]
    state_ref[...] = state * jnp.exp(ca[-1]) + inc
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan_pallas(x, bmat, cmat, loga, *, chunk: int = 128,
                          interpret: bool = False):
    """x [B,S,H,P], b/c [B,S,N], loga [B,S,H] ≤ 0 → y [B,S,H,P]."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    l = min(chunk, s)
    while s % l:
        l //= 2
    nc = s // l
    grid = (b, h, nc)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, l=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, l, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, l, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, l, 1), lambda b_, h_, c_: (b_, c_, h_)),
        ],
        out_specs=pl.BlockSpec((1, l, 1, p),
                               lambda b_, h_, c_: (b_, c_, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, bmat, cmat, loga)
