"""Paper Fig. 10: system cost across GNN models (GCN, GAT, GraphSAGE, SGC)
on each dataset, plus the pre-trained models' node-classification accuracy
(the paper requires the 60–80% band).

The GNN model enters the cost model through the per-layer feature sizes
S_κ (Eqs. 10–11): SGC collapses to a single linear map, the others carry a
64-d hidden layer.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import costs
from repro.core.offload.baselines import run_greedy
from repro.core.offload.drlgo import DRLGOTrainer, DRLGOTrainerConfig
from repro.data.graphs import DATASETS, make_graph, sample_subgraph
from repro.gnn.models import pretrain

# per-model GNN layer feature sizes (kb per vertex; cap 1500 per paper)
MODEL_LAYERS = {
    "gcn": (1500.0, 64.0, 8.0),
    "gat": (1500.0, 64.0, 8.0),
    "graphsage": (1500.0, 64.0, 8.0),
    "sgc": (1500.0, 8.0),
}


def run(quick: bool = True) -> None:
    n_users = 32 if quick else 300
    n_assoc = 3 * n_users if quick else 4800
    episodes = 20 if quick else 300
    datasets = ["synth-cora"] if quick else list(DATASETS)
    models = list(MODEL_LAYERS)

    tcfg = DRLGOTrainerConfig(capacity=n_users, n_users=n_users,
                              n_assoc=n_assoc, episodes=episodes,
                              warmup_steps=256, cost_scale=1.0)
    tr = DRLGOTrainer(tcfg)
    tr.train()

    for ds in datasets:
        spec = DATASETS[ds]
        g = make_graph(spec, seed=0)
        sub = sample_subgraph(g, min(400, g.num_vertices), 4 * n_users,
                              seed=0)
        for model in models:
            served, stats = pretrain(model, sub,
                                     steps=40 if quick else 120)
            gnn_params = costs.GNNCostParams(
                layer_sizes_kb=MODEL_LAYERS[model])
            env = tr.make_env(tr.scenario)
            env.gnn = gnn_params
            env.__post_init__()
            drlgo = tr.run_episode(env, explore=False, learn=False)
            env2 = tr.make_env(tr.scenario)
            env2.gnn = gnn_params
            env2.__post_init__()
            gm = run_greedy(env2)
            emit(f"fig10_{ds}_{model}", 0.0,
                 f"drlgo_cost={drlgo['system_cost']:.2f};"
                 f"gm_cost={gm['system_cost']:.2f};"
                 f"acc={stats['acc_test']:.2f}")


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
