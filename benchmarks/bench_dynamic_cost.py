"""Paper Figs. 7–9: system cost of DRLGO / PTOM / GM / RM under dynamic
user states (user count ramp, association ramp, mobility) on the three
synthetic citation datasets, + cross-server communication cost (the (d)
panels).

All methods run through :class:`repro.core.api.GraphEdgeController` —
one controller per offload-policy registry name, sharing the trainer's
edge network. DRLGO and PTOM are trained briefly (quick mode) on the
dynamic-scenario protocol of §6.4 before evaluation. The mobility panel
moves users without touching the topology, so the controllers' partition
cache skips every re-cut (reported at the end).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.api import GraphEdgeController
from repro.core.dynamic_graph import random_scenario
from repro.core.offload.drlgo import DRLGOTrainer, DRLGOTrainerConfig
from repro.core.offload.env import OBS_DIM
from repro.core.offload.ppo import PPOConfig, PTOMAgent
from repro.data.graphs import DATASETS, make_graph, sample_subgraph

M = 4


def _scenario_from_dataset(name: str, n_users: int, n_assoc: int,
                           capacity: int, seed: int):
    spec = DATASETS[name]
    g = make_graph(spec, seed=seed % 7)          # cache-friendly small pool
    sub = sample_subgraph(g, min(n_users, g.num_vertices),
                          n_assoc, seed=seed)
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, 2000, size=(sub.num_vertices, 2))
    from repro.core.dynamic_graph import make_graph_state
    return make_graph_state(capacity, pos, sub.edges, sub.task_sizes_kb())


def run(quick: bool = True, partitioner: str = "hicut_ref",
        policy: str | None = None) -> None:
    caps = 64 if quick else 320
    user_axis = [24, 48] if quick else [50, 100, 150, 200, 250, 300]
    assoc_axis = [60, 120] if quick else [300, 600, 900, 1200, 1500, 1800]
    episodes = 60 if quick else 400
    datasets = ["synth-citeseer"] if quick else list(DATASETS)

    # --policy on the CLI restricts the comparison; resolve the selection
    # BEFORE training so filtered-out learners never pay their train time
    alias = {"drlgo": "drlgo", "ppo": "ptom", "greedy": "gm", "random": "rm"}
    if policy is None:
        selected = list(alias.values())
    elif policy in alias or policy in alias.values():
        selected = [alias.get(policy, policy)]
    else:
        from repro.core.api import available_offload_policies
        if policy not in available_offload_policies():
            raise ValueError(f"unknown offload policy {policy!r}; available: "
                             f"{available_offload_policies()}")
        selected = [policy]                 # e.g. "local": no training needed

    # train DRLGO + PTOM (when selected) on the dynamic protocol, seeded
    # from the dataset-derived scenario distribution (paper: sampled PubMed)
    init_sc = _scenario_from_dataset(datasets[0], user_axis[-1],
                                     assoc_axis[-1], caps, seed=0)
    tcfg = DRLGOTrainerConfig(capacity=caps, n_users=user_axis[-1],
                              n_assoc=assoc_axis[-1], episodes=episodes,
                              n_servers=M, warmup_steps=256, cost_scale=1.0,
                              partitioner=partitioner,
                              initial_scenario=init_sc)
    tr = DRLGOTrainer(tcfg)
    if "drlgo" in selected:
        t_train = timeit(lambda: tr.train(), repeats=1)
        emit("fig7_drlgo_train", t_train, f"episodes={episodes}")
    ptom = PTOMAgent(PPOConfig(state_dim=M * OBS_DIM, n_actions=M))
    if "ptom" in selected:
        for _ in range(episodes):
            env = tr.make_env(tr.scenario)
            ptom.run_episode(env)

    def make_controller(pol, **kw):
        return GraphEdgeController(net=tr.net, policy=pol, policy_kwargs=kw,
                                   partitioner=partitioner,
                                   cost_scale=tcfg.cost_scale,
                                   zeta_sp=tcfg.zeta_sp)

    factories = {
        "drlgo": lambda: make_controller("drlgo", trainer=tr),
        "ptom": lambda: make_controller("ppo", agent=ptom),
        "gm": lambda: make_controller("greedy"),
        "rm": lambda: [make_controller("random", seed=s) for s in range(3)],
    }
    controllers = {name: factories.get(name, lambda n=name:
                                       make_controller(n))()
                   for name in selected}

    def eval_methods(tag, scenario):
        decisions = {}
        for name, ctrl in controllers.items():
            if isinstance(ctrl, list):        # RM: average over seeds
                ds = [c.step(scenario) for c in ctrl]
                cost = float(np.mean([float(d.cost.c) for d in ds]))
                decisions[name] = ds[0]
            else:
                decisions[name] = d = ctrl.step(scenario)
                cost = float(d.cost.c)
            emit(f"{tag}_{name}", 0.0, f"system_cost={cost:.3f}")
        if "drlgo" in decisions and "gm" in decisions:
            cb = {k: float(decisions[k].cost.cross_bits.sum())
                  for k in ("drlgo", "gm")}
            emit(f"{tag}_crossbits", 0.0,
                 f"drlgo={cb['drlgo']:.0f};gm={cb['gm']:.0f};"
                 f"reduction={1 - cb['drlgo'] / max(cb['gm'], 1):.2%}")

    for ds in datasets:
        for n in user_axis:                          # Fig 7/8/9 (a)
            sc = _scenario_from_dataset(ds, n, 3 * n, caps, seed=n)
            eval_methods(f"fig789_{ds}_users{n}", sc)
        for e in assoc_axis:                         # Fig 7/8/9 (b)
            sc = _scenario_from_dataset(ds, user_axis[-1], e, caps, seed=e)
            eval_methods(f"fig789_{ds}_assoc{e}", sc)
        # (c): mobility — same users, positions shuffled per step; the
        # topology is unchanged so every controller reuses its cached cut
        rng = np.random.default_rng(0)
        sc = _scenario_from_dataset(ds, user_axis[-1], assoc_axis[-1],
                                    caps, seed=1)
        from repro.core.dynamic_graph import move_users
        import jax.numpy as jnp
        for t in range(2 if quick else 10):
            newp = rng.uniform(0, 2000, (caps, 2)).astype(np.float32)
            sc = move_users(sc, jnp.asarray(newp))
            eval_methods(f"fig789_{ds}_move_t{t}", sc)
    gm = controllers.get("gm")
    if gm is not None:
        emit("fig789_partition_cache", 0.0,
             f"hits={gm.cache_hits};misses={gm.cache_misses}")


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
