"""Paper Figs. 7–9: system cost of DRLGO / PTOM / GM / RM under dynamic
user states (user count ramp, association ramp, mobility) on the three
synthetic citation datasets, + cross-server communication cost (the (d)
panels).

DRLGO and PTOM are trained briefly (quick mode) on the dynamic-scenario
protocol of §6.4 before evaluation; each method is evaluated ``repeats``
times and averaged, as in the paper.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import costs
from repro.core.dynamic_graph import random_scenario
from repro.core.offload.baselines import run_greedy, run_random
from repro.core.offload.drlgo import DRLGOTrainer, DRLGOTrainerConfig
from repro.core.offload.env import OBS_DIM
from repro.core.offload.ppo import PPOConfig, PTOMAgent
from repro.data.graphs import DATASETS, make_graph, sample_subgraph

M = 4


def _scenario_from_dataset(name: str, n_users: int, n_assoc: int,
                           capacity: int, seed: int):
    spec = DATASETS[name]
    g = make_graph(spec, seed=seed % 7)          # cache-friendly small pool
    sub = sample_subgraph(g, min(n_users, g.num_vertices),
                          n_assoc, seed=seed)
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, 2000, size=(sub.num_vertices, 2))
    from repro.core.dynamic_graph import make_graph_state
    return make_graph_state(capacity, pos, sub.edges, sub.task_sizes_kb())


def run(quick: bool = True) -> None:
    caps = 64 if quick else 320
    user_axis = [24, 48] if quick else [50, 100, 150, 200, 250, 300]
    assoc_axis = [60, 120] if quick else [300, 600, 900, 1200, 1500, 1800]
    episodes = 60 if quick else 400
    datasets = ["synth-citeseer"] if quick else list(DATASETS)

    # train DRLGO + PTOM once on the dynamic protocol, seeded from the
    # dataset-derived scenario distribution (paper: sampled PubMed docs)
    init_sc = _scenario_from_dataset(datasets[0], user_axis[-1],
                                     assoc_axis[-1], caps, seed=0)
    tcfg = DRLGOTrainerConfig(capacity=caps, n_users=user_axis[-1],
                              n_assoc=assoc_axis[-1], episodes=episodes,
                              n_servers=M, warmup_steps=256, cost_scale=1.0,
                              initial_scenario=init_sc)
    tr = DRLGOTrainer(tcfg)
    t_train = timeit(lambda: tr.train(), repeats=1)
    emit("fig7_drlgo_train", t_train, f"episodes={episodes}")
    ptom = PTOMAgent(PPOConfig(state_dim=M * OBS_DIM, n_actions=M))
    for _ in range(episodes):
        env = tr.make_env(tr.scenario)
        ptom.run_episode(env)

    def eval_methods(tag, scenario, repeats=3):
        drlgo = np.mean([tr.evaluate(scenario)["system_cost"]
                         for _ in range(1)])
        env_costs = {
            "drlgo": drlgo,
            "ptom": np.mean([ptom.run_episode(tr.make_env(scenario),
                                              learn=False, explore=False)
                             ["system_cost"] for _ in range(1)]),
            "gm": run_greedy(tr.make_env(scenario))["system_cost"],
            "rm": np.mean([run_random(tr.make_env(scenario), seed=s)
                           ["system_cost"] for s in range(repeats)]),
        }
        cross = {
            "drlgo": tr.evaluate(scenario)["cross_bits"],
            "gm": run_greedy(tr.make_env(scenario))["cross_bits"],
        }
        for k, v in env_costs.items():
            emit(f"{tag}_{k}", 0.0, f"system_cost={v:.3f}")
        emit(f"{tag}_crossbits", 0.0,
             f"drlgo={cross['drlgo']:.0f};gm={cross['gm']:.0f};"
             f"reduction={1 - cross['drlgo'] / max(cross['gm'], 1):.2%}")

    for ds in datasets:
        for n in user_axis:                          # Fig 7/8/9 (a)
            sc = _scenario_from_dataset(ds, n, 3 * n, caps, seed=n)
            eval_methods(f"fig789_{ds}_users{n}", sc)
        for e in assoc_axis:                         # Fig 7/8/9 (b)
            sc = _scenario_from_dataset(ds, user_axis[-1], e, caps, seed=e)
            eval_methods(f"fig789_{ds}_assoc{e}", sc)
        # (c): mobility — same users, positions shuffled per step
        rng = np.random.default_rng(0)
        sc = _scenario_from_dataset(ds, user_axis[-1], assoc_axis[-1],
                                    caps, seed=1)
        from repro.core.dynamic_graph import move_users
        import jax.numpy as jnp
        for t in range(2 if quick else 10):
            newp = rng.uniform(0, 2000, (caps, 2)).astype(np.float32)
            sc = move_users(sc, jnp.asarray(newp))
            eval_methods(f"fig789_{ds}_move_t{t}", sc, repeats=2)


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
