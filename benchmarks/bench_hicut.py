"""Paper Fig. 6: HiCut vs iterated max-flow min-cut ([36]) — wall time and
cut quality on sparse / non-sparse random graphs.

Paper sizes: 500–20 000 vertices (sparse E ≈ 10V, non-sparse E ≈ 1000V+),
25 servers for the baseline. Quick mode trims sizes so the whole bench
suite stays fast; --full reproduces the paper's axis.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit_with_result
from repro.core.hicut import cut_metrics, hicut_ref
from repro.core.mincut_baseline import pairwise_mincut_partition
from repro.data.graphs import random_graph


def run(quick: bool = True) -> None:
    if quick:
        sparse = [(500, 5_010), (2_000, 20_040), (4_000, 40_080)]
        dense = [(500, 50_100), (1_000, 200_100), (2_000, 400_100)]
        servers = 9
    else:  # paper axis
        sparse = [(500, 5_010), (5_000, 200_010), (10_000, 400_020),
                  (20_000, 800_040)]
        dense = [(500, 500_100), (5_000, 2_000_100), (10_000, 4_000_200),
                 (20_000, 8_000_400)]
        servers = 25
    rng = np.random.default_rng(0)
    for label, cases in (("sparse", sparse), ("nonsparse", dense)):
        for n, e in cases:
            g = random_graph(n, e, seed=int(rng.integers(1 << 30)))
            weights = rng.integers(1, 101, g.num_edges)
            t_hicut, a_hicut = timeit_with_result(
                lambda: hicut_ref(n, g.edges), repeats=1)
            m_hicut = cut_metrics(n, g.edges, a_hicut)
            t_mincut, a_mincut = timeit_with_result(
                lambda: pairwise_mincut_partition(n, g.edges, weights,
                                                  servers), repeats=1)
            m_mincut = cut_metrics(n, g.edges, a_mincut)
            emit(f"fig6_hicut_{label}_v{n}_e{e}", t_hicut,
                 f"cut_frac={m_hicut['cut_fraction']:.3f};"
                 f"subgraphs={m_hicut['num_subgraphs']}")
            emit(f"fig6_mincut36_{label}_v{n}_e{e}", t_mincut,
                 f"cut_frac={m_mincut['cut_fraction']:.3f};"
                 f"speedup_hicut={t_mincut / max(t_hicut, 1):.1f}x")


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
