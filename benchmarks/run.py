"""Benchmark entry: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV. ``--full`` reproduces paper-scale axes.

Control-plane strategies are selected by registry name
(``repro.core.api``), e.g.::

    PYTHONPATH=src:. python benchmarks/run.py \
        --partitioner hicut_jax --policy drlgo

Modules whose ``run()`` takes ``partitioner`` / ``policy`` kwargs receive
the selection; the rest ignore it.
"""
from __future__ import annotations

import argparse
import inspect
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale axes (slow)")
    ap.add_argument("--partitioner", default=None,
                    help="partitioner registry name (repro.core.api); "
                         "default: each bench's own (hicut_ref)")
    ap.add_argument("--policy", default=None,
                    help="restrict control-plane benches to one offload "
                         "policy registry name (default: compare all)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    from benchmarks.common import warn_stale_benches
    warn_stale_benches()   # flag BENCH_*.json stamped at an older commit
    t0 = time.time()
    from benchmarks import (bench_ablation, bench_backends,
                            bench_convergence, bench_distributed_gnn,
                            bench_dynamic_cost, bench_gnn_models,
                            bench_hicut, bench_kernels,
                            bench_partition_plan, bench_serving)
    for mod in (bench_hicut, bench_partition_plan, bench_kernels,
                bench_distributed_gnn, bench_serving, bench_backends,
                bench_dynamic_cost, bench_gnn_models, bench_convergence,
                bench_ablation):
        name = mod.__name__.split(".")[-1]
        t = time.time()
        kwargs = {"quick": not args.full}
        accepted = inspect.signature(mod.run).parameters
        if "partitioner" in accepted and args.partitioner is not None:
            kwargs["partitioner"] = args.partitioner
        if "policy" in accepted and args.policy is not None:
            kwargs["policy"] = args.policy
        try:
            mod.run(**kwargs)
            print(f"# {name} done in {time.time() - t:.1f}s")
        except Exception as exc:      # keep the suite going, but loudly
            print(f"# {name} FAILED: {exc!r}")
            raise
    print(f"# total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
