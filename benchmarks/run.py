"""Benchmark entry: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV. ``--full`` reproduces paper-scale axes."""
from __future__ import annotations

import sys
import time


def main() -> None:
    quick = "--full" not in sys.argv
    print("name,us_per_call,derived")
    t0 = time.time()
    from benchmarks import (bench_ablation, bench_convergence,
                            bench_distributed_gnn, bench_dynamic_cost,
                            bench_gnn_models, bench_hicut, bench_kernels)
    for mod in (bench_hicut, bench_kernels, bench_distributed_gnn,
                bench_dynamic_cost, bench_gnn_models, bench_convergence,
                bench_ablation):
        name = mod.__name__.split(".")[-1]
        t = time.time()
        try:
            mod.run(quick=quick)
            print(f"# {name} done in {time.time() - t:.1f}s")
        except Exception as exc:      # keep the suite going, but loudly
            print(f"# {name} FAILED: {exc!r}")
            raise
    print(f"# total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
