"""TPU adaptation of the paper's Eq. (15) objective: halo-exchange bytes of
distributed GNN inference under HiCut vs random vertex partitioning.

Runs the shard_map inference in a subprocess with virtual devices and
reports the per-layer all-gather volume (the ICI realization of the
paper's cross-server communication cost)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.hicut import hicut_ref
from repro.data.graphs import CORA, make_graph, sample_subgraph
from repro.gnn.distributed import make_partition_plan


def run(quick: bool = True) -> None:
    n = 160 if quick else 1000
    devices = 4 if quick else 8
    g = make_graph(CORA, seed=0)
    sub = sample_subgraph(g, n, 6 * n, seed=0)
    adj = sub.adjacency()
    rng = np.random.default_rng(0)

    hic = hicut_ref(n, sub.edges)
    assign_h = hic % devices
    assign_r = rng.integers(0, devices, n)
    feat_dim = 64
    for name, assign in (("hicut", assign_h), ("random", assign_r)):
        plan = make_partition_plan(adj, assign, devices)
        emit(f"dist_gnn_halo_{name}", 0.0,
             f"halo_rows={plan.halo};"
             f"bytes_per_layer={plan.bytes_per_aggregate(feat_dim)}")
    ph = make_partition_plan(adj, assign_h, devices)
    pr = make_partition_plan(adj, assign_r, devices)
    red = 1 - ph.bytes_per_aggregate(feat_dim) / max(
        pr.bytes_per_aggregate(feat_dim), 1)
    emit("dist_gnn_halo_reduction", 0.0, f"hicut_vs_random={red:.2%}")


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
