"""Serving throughput: pipelined-jit engine vs the sequential controller
loop (ROADMAP "Async serving loop" / "Controller-in-jit").

Both arms serve the *same* pre-built request stream — a dynamic rollout
(``change_rate`` perturbations) with a few inference requests per topology
interval, ≥128 users:

* **sequential** — the pre-engine ``serve_gnn`` loop verbatim: numpy
  ``greedy`` policy walking the env user by user, a fresh
  ``Decision.to_partition_plan`` + blocking ``distributed_gcn_forward``
  per request.
* **pipelined-jit** — :class:`repro.serve.ServingEngine` with the
  ``greedy_jit`` policy: one jitted scan per decision, bounded plan cache,
  async-dispatch overlap of decision t with forward t−1.

Both warm up on a copy of the first request (compile/trace time excluded
from both arms), outputs are cross-checked against the single-device
``gcn_apply`` oracle, and the results land in machine-readable
**``BENCH_serving.json``** (steps/sec per arm, speedup, parity errors,
cache counters) so the perf trajectory — and the ≥2× acceptance bar — is
tracked across PRs. The CI serving smoke lane fails if the engine is
slower than the sequential loop or diverges from the oracle.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_bench_json

OUT_JSON = "BENCH_serving.json"
FEATURES, HIDDEN, CLASSES = 32, 16, 5


def _build_requests(rng, capacity, users, steps, repeats, change_rate):
    from repro.core.dynamic_graph import perturb_scenario, random_scenario
    from repro.serve import ServeRequest

    state = random_scenario(rng, capacity, users, 3 * users)
    reqs = []
    for t in range(steps):
        if t:
            state = perturb_scenario(rng, state, change_rate)
        for _ in range(repeats):
            x = rng.normal(size=(capacity, FEATURES)).astype(np.float32)
            reqs.append(ServeRequest(state, x))
    return reqs


def _oracle_err(params, res_out, req) -> float:
    import jax.numpy as jnp

    from repro.gnn.layers import gcn_apply
    st = req.state
    oracle = np.asarray(gcn_apply(params, jnp.asarray(req.x), st.adj,
                                  st.mask))
    served = np.nonzero(np.asarray(st.mask) > 0)[0]
    return float(np.abs(res_out[served] - oracle[served]).max())


def _sequential_pass(net, requests, mesh, params, devices):
    """The pre-engine one-decision→one-forward loop, timed verbatim."""
    from repro.core.api import GraphEdgeController
    from repro.gnn.distributed import distributed_gcn_forward

    ctrl = GraphEdgeController(net=net, policy="greedy")
    outs = []
    for req in requests:
        decision = ctrl.step(req.state)
        plan = decision.to_partition_plan(devices)
        outs.append(distributed_gcn_forward(mesh, "servers", plan, params,
                                            req.x))
    return outs


def run(quick: bool = True) -> None:
    import jax
    from jax.sharding import Mesh

    from repro.core import costs
    from repro.core.api import GraphEdgeController
    from repro.gnn.layers import gcn_init
    from repro.serve import ServingEngine

    cases = ([(128, 5, 2)] if quick else
             [(128, 8, 4), (256, 8, 4)])   # (users, topo steps, reqs/topo)
    devices = min(4, len(jax.devices()))
    mesh = Mesh(np.array(jax.devices()[:devices]), ("servers",))
    records = []
    for users, steps, repeats in cases:
        capacity = users + 8
        rng = np.random.default_rng(0)
        net = costs.default_network(rng, capacity, 4)
        params = gcn_init(jax.random.PRNGKey(0),
                          [FEATURES, HIDDEN, CLASSES])
        requests = _build_requests(rng, capacity, users, steps, repeats,
                                   change_rate=0.2)
        n_req = len(requests)

        # -- warmup both arms on the first request (compile/trace excluded)
        warm = [requests[0]]
        _sequential_pass(net, warm, mesh, params, devices)
        engine = ServingEngine(
            controller=GraphEdgeController(net=net, policy="greedy_jit"),
            params=params, mesh=mesh, num_devices=devices)
        engine.serve_all(warm)

        # -- sequential loop (fresh controller so its caches start cold)
        t0 = time.perf_counter()
        seq_outs = _sequential_pass(net, requests, mesh, params, devices)
        t_seq = time.perf_counter() - t0

        # -- pipelined-jit engine (fresh caches, jit compiles stay warm)
        engine = ServingEngine(
            controller=GraphEdgeController(net=net, policy="greedy_jit"),
            params=params, mesh=mesh, num_devices=devices)
        t0 = time.perf_counter()
        results = engine.serve_all(requests)
        t_eng = time.perf_counter() - t0

        eng_err = max(_oracle_err(params, r.output, r.request)
                      for r in results)
        seq_err = max(_oracle_err(params, o, r)
                      for o, r in zip(seq_outs, requests))
        pc, cc = engine.plan_cache_info(), engine.controller.cache_info()
        rec = {
            "users": users, "capacity": capacity, "devices": devices,
            "requests": n_req, "topology_steps": steps,
            "requests_per_topology": repeats,
            "seq_steps_per_sec": n_req / t_seq,
            "engine_steps_per_sec": n_req / t_eng,
            "speedup": t_seq / t_eng,
            "seq_oracle_max_err": seq_err,
            "engine_oracle_max_err": eng_err,
            "plan_cache": {"hits": pc.hits, "misses": pc.misses},
            "partition_cache": {"hits": cc.hits, "misses": cc.misses},
        }
        records.append(rec)
        emit(f"serving_sequential_u{users}", t_seq / n_req * 1e6,
             f"steps_per_sec={rec['seq_steps_per_sec']:.2f}")
        emit(f"serving_pipelined_jit_u{users}", t_eng / n_req * 1e6,
             f"steps_per_sec={rec['engine_steps_per_sec']:.2f};"
             f"speedup={rec['speedup']:.1f}x;"
             f"max_err={eng_err:.1e}")

    write_bench_json(OUT_JSON, "serving", quick, records)


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
