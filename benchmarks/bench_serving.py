"""Serving throughput: pipelined-jit engine vs the sequential controller
loop (ROADMAP "Async serving loop" / "Controller-in-jit").

Both arms serve the *same* pre-built request stream — a dynamic rollout
(``change_rate`` perturbations) with a few inference requests per topology
interval, ≥128 users:

* **sequential** — the pre-engine ``serve_gnn`` loop verbatim: numpy
  ``greedy`` policy walking the env user by user, a fresh
  ``Decision.to_partition_plan`` + blocking ``distributed_gcn_forward``
  per request.
* **pipelined-jit** — :class:`repro.serve.ServingEngine` with the
  ``greedy_jit`` policy: one jitted scan per decision, bounded plan cache,
  async-dispatch overlap of decision t with forward t−1.

Both warm up on a copy of the first request (compile/trace time excluded
from both arms), outputs are cross-checked against the single-device
``gcn_apply`` oracle, and the results land in machine-readable
**``BENCH_serving.json``** (steps/sec per arm, speedup, parity errors,
cache counters) so the perf trajectory — and the ≥2× acceptance bar — is
tracked across PRs. The CI serving smoke lane fails if the engine is
slower than the sequential loop or diverges from the oracle.

The file also carries the **streaming front-end records** (``"mode":
"streaming"`` — DESIGN.md §7, BENCHMARKS.md):

* ``burst_batchable`` — a burst of concurrent requests on one topology,
  served once with continuous batching (``max_batch`` ≥ 4) and once
  per-request (``max_batch=1``) through the same warm engine; the batched
  arm must clear the **≥2× throughput** acceptance bar, and every
  streamed output is checked against the no-frontend ``engine.serve``
  sequential oracle.
* ``overload_lyapunov`` / ``overload_admit_all`` — an open-loop Poisson
  stream far above service capacity with per-request deadlines; the
  Lyapunov arm must keep the *admitted* p99 bounded (CI gates
  ``p99 ≤ 2 × deadline``) with every shed request accounted
  (conservation), while the admit-all contrast arm shows the unbounded
  tail admission control removes.
* ``decide_batch`` — the batched control plane (ISSUE 8): B distinct
  perturbed topologies decided per-request (``decide_entry`` loop) vs as
  one vmapped ``decide_entries`` call on the same warm engine. CI gates
  **speedup ≥2×** and assignment-exact parity between the two roads.
* ``cross_topology`` — continuous batching *across* topologies: an
  all-at-once queue of requests spread over several perturbed layouts
  (same shape bucket), served with ``cross_topology=True`` so one padded
  multi-plan dispatch covers plan-heterogeneous batches. Records the
  sustained req/s, the speedup over the PR 6 ``burst_batchable`` record
  (``pr6_burst_rps_ref``), and the **exact** (bitwise, ``== 0``) parity
  vs the sequential no-frontend engine oracle, which CI gates.

And the **fault-injection records** (``"mode": "failure"`` — DESIGN.md §9,
the chaos harness of ``repro.serve.faults``):

* ``server_down_migration`` — a mid-stream server failure + recovery on a
  deterministic (ManualClock) streaming run: every queued request migrates
  to a warm-recut plan on the repriced network. CI gates
  ``lost_requests == 0``, conservation, ``requests_migrated > 0``,
  recovery within 3 pump cycles, a bitwise-identical fault trace across
  two identical runs (``trace_deterministic``), and output parity against
  the single-device oracle (the GCN output depends only on the topology,
  so migration must never change it).
* ``warm_recut`` — the migration re-cut itself: warm-started multilevel
  refinement (previous cut as the initial assignment, coarsening and GGGP
  skipped) vs a from-scratch re-partition on the post-fault server count,
  comparing wall time (``recut_speedup``), edge cut, and the system cost
  of the resulting offload decision (``cost_delta_vs_scratch``).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, write_bench_json

OUT_JSON = "BENCH_serving.json"
FEATURES, HIDDEN, CLASSES = 32, 16, 5


def _build_requests(rng, capacity, users, steps, repeats, change_rate):
    from repro.core.dynamic_graph import perturb_scenario, random_scenario
    from repro.serve import ServeRequest

    state = random_scenario(rng, capacity, users, 3 * users)
    reqs = []
    for t in range(steps):
        if t:
            state = perturb_scenario(rng, state, change_rate)
        for _ in range(repeats):
            x = rng.normal(size=(capacity, FEATURES)).astype(np.float32)
            reqs.append(ServeRequest(state, x))
    return reqs


def _oracle_err(params, res_out, req) -> float:
    import jax.numpy as jnp

    from repro.gnn.layers import gcn_apply
    st = req.state
    oracle = np.asarray(gcn_apply(params, jnp.asarray(req.x), st.adj,
                                  st.mask))
    served = np.nonzero(np.asarray(st.mask) > 0)[0]
    return float(np.abs(res_out[served] - oracle[served]).max())


def _sequential_pass(net, requests, mesh, params, devices):
    """The pre-engine one-decision→one-forward loop, timed verbatim."""
    from repro.core.api import GraphEdgeController
    from repro.gnn.distributed import distributed_gcn_forward

    ctrl = GraphEdgeController(net=net, policy="greedy")
    outs = []
    for req in requests:
        decision = ctrl.step(req.state)
        plan = decision.to_partition_plan(devices)
        outs.append(distributed_gcn_forward(mesh, "servers", plan, params,
                                            req.x))
    return outs


def _streaming_records(quick, mesh, devices) -> list:
    """The streaming front-end arms (``"mode": "streaming"`` records)."""
    import time as _time

    import jax

    from repro.core import costs
    from repro.core.api import GraphEdgeController
    from repro.core.dynamic_graph import perturb_scenario, random_scenario
    from repro.gnn.layers import gcn_init
    from repro.serve import (AdmitAll, LyapunovAdmission, ServeRequest,
                             ServingEngine, StreamRequest, StreamingFrontend,
                             poisson_workload)

    users = 64 if quick else 128
    capacity = users + 8
    n_burst = 16 if quick else 32
    max_batch = 8
    rng = np.random.default_rng(1)
    net = costs.default_network(rng, capacity, 4)
    params = gcn_init(jax.random.PRNGKey(1), [FEATURES, HIDDEN, CLASSES])
    state = random_scenario(rng, capacity, users, 3 * users)
    xs = [rng.normal(size=(capacity, FEATURES)).astype(np.float32)
          for _ in range(n_burst)]

    def make_engine():
        return ServingEngine(
            controller=GraphEdgeController(net=net, policy="greedy_jit"),
            params=params, mesh=mesh, num_devices=devices)

    def burst():
        return [(0.0, StreamRequest(state, x, tenant=i % 2))
                for i, x in enumerate(xs)]

    # -- no-frontend sequential oracle (the parity reference) ----------------
    oracle_engine = make_engine()
    seq_outs = [r.output for r in oracle_engine.serve_all(
        [ServeRequest(state, x) for x in xs])]
    mask_rows = np.nonzero(np.asarray(state.mask) > 0)[0]

    def run_arm(mb):
        """One timed burst pass at batch cap ``mb`` on a pre-warmed engine
        (compile/trace excluded, plan cache warm — steady-state serving)."""
        eng = make_engine()
        StreamingFrontend(engine=eng, queue_depth=n_burst + 8,
                          max_batch=mb).run(burst())          # warmup
        fe = StreamingFrontend(engine=eng, queue_depth=n_burst + 8,
                               max_batch=mb)
        t0 = _time.perf_counter()
        results = fe.run(burst())
        dt = _time.perf_counter() - t0
        err = max(float(np.abs(r.output[mask_rows]
                               - seq_outs[r.rid][mask_rows]).max())
                  for r in results)
        return fe, len(results) / dt, err

    fe1, base_rps, err1 = run_arm(1)
    feb, batch_rps, errb = run_arm(max_batch)
    records = [{
        "mode": "streaming", "workload": "burst_batchable",
        "users": users, "capacity": capacity, "devices": devices,
        "requests": n_burst, "max_batch": max_batch,
        "baseline_rps": base_rps, "batched_rps": batch_rps,
        "batch_speedup": batch_rps / base_rps,
        "batches": feb.stats.batches,
        "batched_requests": feb.stats.batched_requests,
        "parity_vs_engine_max_err": max(err1, errb),
        "conservation_ok": bool(fe1.stats.conservation_ok
                                and feb.stats.conservation_ok),
    }]
    emit(f"streaming_burst_u{users}", 1e6 / batch_rps,
         f"batched_rps={batch_rps:.2f};baseline_rps={base_rps:.2f};"
         f"batch_speedup={batch_rps / base_rps:.1f}x;"
         f"max_err={max(err1, errb):.1e}")

    # -- overload: open-loop Poisson far above capacity, with deadlines ------
    # Timed on a ManualClock (every clock read = 20 logical ms) so "service
    # capacity" is simulated and the overload regime — and therefore the CI
    # gate on the admitted p99 — is deterministic across machines. The
    # forwards still run for real; only the tick arithmetic is logical.
    from repro.serve import ManualClock

    deadline = 0.5                    # logical SLO budget (lyapunov arm)
    count = 60 if quick else 120
    rate = 100.0                      # logical arrivals/sec >> service rate
    tenants = 3
    queue_depth = 16                  # shallow: overflow → queue_full

    def overload_arm(admission, name, slo_budget):
        eng = make_engine()
        StreamingFrontend(engine=eng, queue_depth=count,
                          max_batch=max_batch).run(burst())   # warm compiles
        fe = StreamingFrontend(engine=eng, queue_depth=queue_depth,
                               max_batch=max_batch, admission=admission,
                               clock=ManualClock(tick_per_now=0.02))
        wl_rng = np.random.default_rng(2)
        fe.run(poisson_workload(
            wl_rng, rate, count,
            lambda i: StreamRequest(state, xs[i % n_burst],
                                    tenant=i % tenants,
                                    deadline=slo_budget)))
        stats, slo = fe.stats.as_dict(), fe.slo_summary()
        rec = {
            "mode": "streaming", "workload": name, "clock": "manual",
            "users": users, "capacity": capacity, "devices": devices,
            "requests": count, "arrival_rate": rate, "tenants": tenants,
            "deadline": slo_budget, "queue_depth": queue_depth,
            "max_batch": max_batch,
            "admitted": stats["admitted"],
            "rejected": stats["rejected"],
            "rejected_total": stats["rejected_total"],
            "deferred": stats["deferred"],
            "conservation_ok": stats["conservation_ok"],
            "sustained_rps": slo.get("sustained_rps", 0.0),
            "admitted_p50_s": slo.get("total", {}).get("p50"),
            "admitted_p99_s": slo.get("total", {}).get("p99"),
        }
        if name == "overload_lyapunov":
            rec["tenant_queue_max"] = admission.queue_max
        emit(f"streaming_{name}_u{users}",
             (rec["admitted_p99_s"] or 0.0) * 1e6,
             f"admitted={rec['admitted']}/{count};"
             f"rejected={rec['rejected_total']};"
             f"p99_s={rec['admitted_p99_s']:.3f};"
             f"conservation={'ok' if rec['conservation_ok'] else 'BAD'}")
        return rec

    # lyapunov enforces the SLO budget; the admit-all contrast arm runs the
    # same stream best-effort (no deadlines, no control) and shows the
    # unbounded latency tail admission control removes
    records.append(overload_arm(
        LyapunovAdmission(num_tenants=tenants), "overload_lyapunov",
        deadline))
    records.append(overload_arm(AdmitAll(), "overload_admit_all", None))

    # -- decide_batch: per-request decide loop vs one vmapped decide ---------
    # B distinct perturbed topologies, caches sized to hold them all (the
    # comparison is decide dispatch, not partition-recompute thrash).
    n_topo_decide = 32 if quick else 64
    topo_rng = np.random.default_rng(3)
    decide_states = [state]
    for _ in range(n_topo_decide - 1):
        decide_states.append(perturb_scenario(topo_rng, decide_states[-1],
                                              0.1))
    dec_eng = ServingEngine(
        controller=GraphEdgeController(net=net, policy="greedy_jit",
                                       cache_size=2 * n_topo_decide),
        params=params, mesh=mesh, num_devices=devices,
        plan_cache_size=2 * n_topo_decide)
    dec_eng.decide_entries(decide_states)            # warm the batched road
    seq_entries = [dec_eng.decide_entry(s) for s in decide_states]
    reps = 5
    t0 = _time.perf_counter()
    for _ in range(reps):
        for s in decide_states:
            dec_eng.decide_entry(s)
    t_seq_dec = (_time.perf_counter() - t0) / reps
    t0 = _time.perf_counter()
    for _ in range(reps):
        bat_entries = dec_eng.decide_entries(decide_states)
    t_bat_dec = (_time.perf_counter() - t0) / reps
    assign_exact = all(
        np.array_equal(eb[0].servers, es[0].servers)
        for eb, es in zip(bat_entries, seq_entries))
    rec = {
        "mode": "streaming", "workload": "decide_batch",
        "users": users, "capacity": capacity, "devices": devices,
        "batch": n_topo_decide,
        "seq_decides_per_sec": n_topo_decide / t_seq_dec,
        "batch_decides_per_sec": n_topo_decide / t_bat_dec,
        "decide_batch_speedup": t_seq_dec / t_bat_dec,
        "assign_exact": bool(assign_exact),
    }
    records.append(rec)
    emit(f"streaming_decide_batch_b{n_topo_decide}",
         t_bat_dec / n_topo_decide * 1e6,
         f"batch_decides_per_sec={rec['batch_decides_per_sec']:.1f};"
         f"speedup={rec['decide_batch_speedup']:.2f}x;"
         f"assign_exact={assign_exact}")

    # -- cross_topology: one dispatch serves plan-heterogeneous batches ------
    # All requests queued up front (closed-loop drain — pure service rate),
    # spread over several perturbed layouts sharing one shape bucket, with
    # cross_topology batching and the vmapped decide_entries control plane.
    n_cross = 256 if quick else 512
    n_topo_cross = 4
    mb_cross = 128
    cross_states = [state]
    for _ in range(n_topo_cross - 1):
        cross_states.append(perturb_scenario(topo_rng, cross_states[-1],
                                             0.1))
    cross_xs = [rng.normal(size=(capacity, FEATURES)).astype(np.float32)
                for _ in range(n_cross)]
    cross_eng = make_engine()
    cross_outs = [r.output for r in cross_eng.serve_all(
        [ServeRequest(cross_states[i % n_topo_cross], x)
         for i, x in enumerate(cross_xs)])]   # sequential oracle (+ warmup)

    def cross_load():
        return [(0.0, StreamRequest(cross_states[i % n_topo_cross], x))
                for i, x in enumerate(cross_xs)]

    StreamingFrontend(engine=cross_eng, queue_depth=n_cross,
                      max_batch=mb_cross, cross_topology=True
                      ).run(cross_load())              # warm padded plans
    fe_x = StreamingFrontend(engine=cross_eng, queue_depth=n_cross,
                             max_batch=mb_cross, cross_topology=True)
    t0 = _time.perf_counter()
    cross_results = fe_x.run(cross_load())
    t_cross = _time.perf_counter() - t0
    cross_rows = [np.nonzero(np.asarray(s.mask) > 0)[0]
                  for s in cross_states]
    cross_err = max(
        float(np.abs(r.output[cross_rows[r.rid % n_topo_cross]]
                     - cross_outs[r.rid][cross_rows[r.rid % n_topo_cross]]
                     ).max())
        for r in cross_results)
    pr6_burst_rps_ref = 2792.697862932865   # PR 6 burst_batchable record
    cyc = fe_x.cycles.as_dict()
    rec = {
        "mode": "streaming", "workload": "cross_topology",
        "users": users, "capacity": capacity, "devices": devices,
        "requests": n_cross, "topologies": n_topo_cross,
        "max_batch": mb_cross,
        "sustained_rps": len(cross_results) / t_cross,
        "pr6_burst_rps_ref": pr6_burst_rps_ref,
        "speedup_vs_pr6_burst": (len(cross_results) / t_cross
                                 / pr6_burst_rps_ref),
        "cross_batches": fe_x.stats.cross_batches,
        "cross_batched_requests": fe_x.stats.cross_batched_requests,
        "batch_hist": cyc["batch_hist"],
        "decide_p50_s": cyc["decide"]["p50"],
        "parity_vs_engine_max_err": cross_err,
        "conservation_ok": bool(fe_x.stats.conservation_ok),
    }
    records.append(rec)
    emit(f"streaming_cross_topology_u{users}",
         t_cross / n_cross * 1e6,
         f"sustained_rps={rec['sustained_rps']:.1f};"
         f"speedup_vs_pr6_burst={rec['speedup_vs_pr6_burst']:.2f}x;"
         f"max_err={cross_err:.1e};"
         f"conservation={'ok' if rec['conservation_ok'] else 'BAD'}")
    return records


def _failure_records(quick, mesh, devices) -> list:
    """The fault-injection arms (``"mode": "failure"`` records).

    ``server_down_migration`` runs the exact fault drill CI gates: a
    mid-stream ``server_down`` + ``server_up`` on a ManualClock streaming
    run, executed **twice** with identical seeds so the fault trace, the
    stats ledger and every served output can be checked for bitwise
    determinism. ``warm_recut`` isolates the migration re-cut cost."""
    import time as _time
    import types

    import jax

    from repro.core import costs
    from repro.core.api import GraphEdgeController, state_edges
    from repro.core.dynamic_graph import random_scenario
    from repro.core.multilevel import multilevel_partition
    from repro.gnn.layers import gcn_init
    from repro.serve import (AdmitAll, FaultInjector, FaultSchedule,
                             ManualClock, ServingEngine, StreamRequest,
                             StreamingFrontend, poisson_workload)

    users = 64 if quick else 128
    capacity = users + 8
    count = 24 if quick else 48
    spec = "2:server_down:1,5:server_up:1"
    rng = np.random.default_rng(5)
    net = costs.default_network(rng, capacity, 4)
    params = gcn_init(jax.random.PRNGKey(5), [FEATURES, HIDDEN, CLASSES])
    state = random_scenario(rng, capacity, users, 3 * users)
    xs = [rng.normal(size=(capacity, FEATURES)).astype(np.float32)
          for _ in range(count)]

    # -- server_down_migration: the gated fault drill, twice -----------------
    def fault_pass():
        eng = ServingEngine(
            controller=GraphEdgeController(net=net, policy="greedy_jit"),
            params=params, mesh=mesh, num_devices=devices)
        inj = FaultInjector(FaultSchedule.parse(spec), net, seed=0)
        fe = StreamingFrontend(engine=eng, queue_depth=count, max_batch=4,
                               admission=AdmitAll(), faults=inj,
                               clock=ManualClock(tick_per_now=0.02))
        wl = poisson_workload(
            np.random.default_rng(4), rate=5.0, count=count,
            make_request=lambda i: StreamRequest(state=state, x=xs[i]))
        t0 = _time.perf_counter()
        results = fe.run(wl)
        return fe, results, _time.perf_counter() - t0

    fe_a, res_a, _ = fault_pass()          # also warms the compiles
    fe_b, res_b, t_run = fault_pass()
    out_a = {r.rid: r.output for r in res_a}
    out_b = {r.rid: r.output for r in res_b}
    trace_det = bool(
        fe_a.fault_trace == fe_b.fault_trace
        and fe_a.stats.as_dict() == fe_b.stats.as_dict()
        and out_a.keys() == out_b.keys()
        and all(np.array_equal(out_a[rid], out_b[rid]) for rid in out_a))
    parity = max(
        _oracle_err(params, r.output,
                    types.SimpleNamespace(state=state, x=xs[r.rid]))
        for r in res_b)
    stats = fe_b.stats.as_dict()
    lost = stats["submitted"] - stats["served"] - stats["rejected_total"]
    recovery = max((t["recovery_cycles"] for t in fe_b.fault_trace
                    if "recovery_cycles" in t), default=0)
    rec = {
        "mode": "failure", "workload": "server_down_migration",
        "users": users, "capacity": capacity, "devices": devices,
        "requests": count, "faults": spec, "clock": "manual",
        "max_batch": 4,
        "submitted": stats["submitted"], "served": stats["served"],
        "lost_requests": int(lost),
        "requests_migrated": stats["requests_migrated"],
        "migrated_served": stats["migrated_served"],
        "recovery_cycles": int(recovery),
        "net_swaps": fe_b.engine.net_swaps,
        "fault_events": sum(len(t["events"]) for t in fe_b.fault_trace),
        "conservation_ok": bool(stats["conservation_ok"]),
        "trace_deterministic": trace_det,
        "parity_vs_oracle_max_err": parity,
    }
    records = [rec]
    emit(f"failure_server_down_migration_u{users}", t_run / count * 1e6,
         f"migrated={rec['requests_migrated']};lost={rec['lost_requests']};"
         f"recovery_cycles={rec['recovery_cycles']};"
         f"deterministic={trace_det};max_err={parity:.1e}")

    # -- warm_recut: warm-started migration re-cut vs from-scratch -----------
    edges = state_edges(state)
    active = np.asarray(state.mask) > 0
    n = state.capacity
    cold = multilevel_partition(n, edges, 4, active=active)
    reps = 3 if quick else 5
    warm = scratch = None
    multilevel_partition(n, edges, 3, active=active, initial=cold)
    t0 = _time.perf_counter()
    for _ in range(reps):
        warm = multilevel_partition(n, edges, 3, active=active, initial=cold)
    t_warm = (_time.perf_counter() - t0) / reps
    multilevel_partition(n, edges, 3, active=active)
    t0 = _time.perf_counter()
    for _ in range(reps):
        scratch = multilevel_partition(n, edges, 3, active=active)
    t_scratch = (_time.perf_counter() - t0) / reps

    def cut(assign):
        a, b = assign[edges[:, 0]], assign[edges[:, 1]]
        return int(np.sum((a >= 0) & (b >= 0) & (a != b)))

    # system cost of the offload decision each cut leads to on the
    # post-fault (server 1 down) pricing
    m = int(net.f_k.shape[0])
    prof = costs.ServerProfile.healthy(m)
    deg = costs.degrade_network(net, prof._replace(up=prof.up.at[1].set(0.0)))
    ctrl_warm = GraphEdgeController(net=deg, policy="greedy_jit")
    ctrl_warm.recut_warm(state, cold, num_parts=3)
    c_warm = float(ctrl_warm.step(state).cost.c)
    ctrl_scratch = GraphEdgeController(net=deg, policy="greedy_jit",
                                       partitioner="multilevel",
                                       partitioner_kwargs={"num_parts": 3})
    c_scratch = float(ctrl_scratch.step(state).cost.c)
    rec = {
        "mode": "failure", "workload": "warm_recut",
        "users": users, "capacity": capacity,
        "parts_before": 4, "parts_after": 3,
        "t_warm_ms": t_warm * 1e3, "t_scratch_ms": t_scratch * 1e3,
        "recut_speedup": t_scratch / t_warm,
        "cut_warm": cut(warm), "cut_scratch": cut(scratch),
        "cost_warm": c_warm, "cost_scratch": c_scratch,
        "cost_delta_vs_scratch": (c_warm - c_scratch) / c_scratch,
    }
    records.append(rec)
    emit(f"failure_warm_recut_u{users}", t_warm * 1e6,
         f"recut_speedup={rec['recut_speedup']:.2f}x;"
         f"cut_warm={rec['cut_warm']};cut_scratch={rec['cut_scratch']};"
         f"cost_delta={rec['cost_delta_vs_scratch']:+.4f}")
    return records


def _multihost_records(quick) -> list:
    """The multi-host SPMD arms (``"mode": "multihost"`` records).

    Drives ``repro.launch.serve_multihost`` in subprocesses (each host
    count needs its own ``jax.distributed`` world, so none of them can
    share the bench's jax runtime): a replicate-everything single-process
    **engine** baseline, then the **resident** sharded path at 1, 2 (and
    ``--full`` 4) simulated hosts on the same ``community_graph`` —
    10⁶ vertices under ``--full``, smoke-size under quick. hosts=1 writes
    the reference output; every other arm must match it **bitwise**
    (``parity_max_err == 0``, CI-gated), and halo bytes must stay
    strictly under the replicate baseline's transfer."""
    import json as _json
    import os
    import pathlib
    import subprocess
    import sys
    import tempfile

    n, e, steps = (20_000, 60_000, 3) if quick else (1_000_000, 3_000_000, 5)
    devices = 4
    host_counts = [1, 2] if quick else [1, 2, 4]
    root = pathlib.Path(__file__).resolve().parent.parent

    def launch(extra, hosts):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(root / "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devices // hosts}"
        with tempfile.TemporaryDirectory() as td:
            out = os.path.join(td, "rec.json")
            cmd = [sys.executable, "-m", "repro.launch.serve_multihost",
                   "--processes", str(hosts), "--devices", str(devices),
                   "--vertices", str(n), "--edges", str(e),
                   "--steps", str(steps), "--json-out", out] + extra
            proc = subprocess.run(cmd, env=env, cwd=root,
                                  capture_output=True, text=True,
                                  timeout=3600)
            assert proc.returncode == 0, proc.stdout + proc.stderr
            with open(out) as f:
                return _json.loads(f.read())

    ref = tempfile.NamedTemporaryFile(suffix=".npy", delete=False)
    ref.close()
    try:
        eng = launch(["--arm", "engine", "--exchange", "gather"], 1)
        records = []
        for hosts in host_counts:
            parity = ["--ref-out", ref.name] if hosts == 1 else \
                ["--ref-in", ref.name]
            rec = launch(["--arm", "resident"] + parity, hosts)
            rec["engine_steps_per_s"] = eng["steps_per_s"]
            rec["speedup_vs_engine"] = (rec["steps_per_s"]
                                        / eng["steps_per_s"])
            records.append(rec)
            emit(f"multihost_resident_h{hosts}_n{n}",
                 1e6 / rec["steps_per_s"],
                 f"steps_per_s={rec['steps_per_s']:.2f};"
                 f"speedup_vs_engine={rec['speedup_vs_engine']:.2f}x;"
                 f"halo_frac={rec['halo_frac']:.4f};"
                 f"parity_max_err={rec.get('parity_max_err', 0.0):.1e}")
        eng["engine_steps_per_s"] = eng["steps_per_s"]
        eng["speedup_vs_engine"] = 1.0
        records.append(eng)
        emit(f"multihost_engine_h1_n{n}", 1e6 / eng["steps_per_s"],
             f"steps_per_s={eng['steps_per_s']:.2f};"
             f"halo_frac={eng['halo_frac']:.4f}")
    finally:
        os.unlink(ref.name)
    return records


def run(quick: bool = True, profile_dir: str | None = None) -> None:
    import jax

    if profile_dir is not None:
        jax.profiler.start_trace(profile_dir)
    try:
        _run(quick)
    finally:
        if profile_dir is not None:
            jax.profiler.stop_trace()
            print(f"# profile trace written to {profile_dir}")


def _run(quick: bool) -> None:
    import jax
    from jax.sharding import Mesh

    from repro.core import costs
    from repro.core.api import GraphEdgeController
    from repro.gnn.layers import gcn_init
    from repro.serve import ServingEngine

    cases = ([(128, 5, 2)] if quick else
             [(128, 8, 4), (256, 8, 4)])   # (users, topo steps, reqs/topo)
    devices = min(4, len(jax.devices()))
    mesh = Mesh(np.array(jax.devices()[:devices]), ("servers",))
    records = []
    for users, steps, repeats in cases:
        capacity = users + 8
        rng = np.random.default_rng(0)
        net = costs.default_network(rng, capacity, 4)
        params = gcn_init(jax.random.PRNGKey(0),
                          [FEATURES, HIDDEN, CLASSES])
        requests = _build_requests(rng, capacity, users, steps, repeats,
                                   change_rate=0.2)
        n_req = len(requests)

        # -- warmup both arms on the first request (compile/trace excluded)
        warm = [requests[0]]
        _sequential_pass(net, warm, mesh, params, devices)
        engine = ServingEngine(
            controller=GraphEdgeController(net=net, policy="greedy_jit"),
            params=params, mesh=mesh, num_devices=devices)
        engine.serve_all(warm)

        # -- sequential loop (fresh controller so its caches start cold)
        t0 = time.perf_counter()
        seq_outs = _sequential_pass(net, requests, mesh, params, devices)
        t_seq = time.perf_counter() - t0

        # -- pipelined-jit engine (fresh caches, jit compiles stay warm)
        engine = ServingEngine(
            controller=GraphEdgeController(net=net, policy="greedy_jit"),
            params=params, mesh=mesh, num_devices=devices)
        t0 = time.perf_counter()
        results = engine.serve_all(requests)
        t_eng = time.perf_counter() - t0

        eng_err = max(_oracle_err(params, r.output, r.request)
                      for r in results)
        seq_err = max(_oracle_err(params, o, r)
                      for o, r in zip(seq_outs, requests))
        pc, cc = engine.plan_cache_info(), engine.controller.cache_info()
        rec = {
            "users": users, "capacity": capacity, "devices": devices,
            "requests": n_req, "topology_steps": steps,
            "requests_per_topology": repeats,
            "seq_steps_per_sec": n_req / t_seq,
            "engine_steps_per_sec": n_req / t_eng,
            "speedup": t_seq / t_eng,
            "seq_oracle_max_err": seq_err,
            "engine_oracle_max_err": eng_err,
            "plan_cache": {"hits": pc.hits, "misses": pc.misses},
            "partition_cache": {"hits": cc.hits, "misses": cc.misses},
        }
        records.append(rec)
        emit(f"serving_sequential_u{users}", t_seq / n_req * 1e6,
             f"steps_per_sec={rec['seq_steps_per_sec']:.2f}")
        emit(f"serving_pipelined_jit_u{users}", t_eng / n_req * 1e6,
             f"steps_per_sec={rec['engine_steps_per_sec']:.2f};"
             f"speedup={rec['speedup']:.1f}x;"
             f"max_err={eng_err:.1e}")

    records.extend(_streaming_records(quick, mesh, devices))
    records.extend(_failure_records(quick, mesh, devices))
    records.extend(_multihost_records(quick))
    write_bench_json(OUT_JSON, "serving", quick, records)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--full", action="store_true",
                    help="paper-scale axes (slow)")
    ap.add_argument("--quick", action="store_true",
                    help="small axes (the default; --full overrides)")
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="write a jax.profiler trace of the run to DIR")
    args = ap.parse_args()
    run(quick=not args.full, profile_dir=args.profile)
