"""Backend ablation grid: partitioner × policy × {users, change_rate}
(ROADMAP "Multi-backend partitioners/policies").

Every cell drives one :class:`repro.core.api.GraphEdgeController` through
a short dynamic rollout (``perturb_scenario`` at the cell's change rate)
and records the three axes the backends trade against each other:

* **cut quality** — mean cross-subgraph edges / cut fraction of the
  partitions actually used (``Partition.cut_metrics``);
* **SystemCost** — mean exact Eqs. (12)–(14) objective of the offload
  decisions;
* **throughput** — control steps/sec (jit compile warmed up out of band).

Each record also carries validity flags (partition covers exactly the
active vertices; every active user got a server), so the CI backends
lane can fail on an invalid backend rather than a silently wrong one.
A final oracle record pins the ``lyapunov`` jit scan to its numpy
reference (``run_lyapunov``) on a seeded scenario — assignment exact,
reward to f32 tolerance.

Results land in machine-readable **``BENCH_backends.json``** (common
schema header + one record per grid cell; see BENCHMARKS.md).

    PYTHONPATH=src:. python benchmarks/bench_backends.py --quick
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit, write_bench_json

OUT_JSON = "BENCH_backends.json"
# the default grid; --partitioner/--policy (or run.py's flags) extend it
PARTITIONERS = ("hicut_jax", "multilevel", "multilevel_jax", "mincut")
POLICIES = ("greedy_jit", "local_jit", "lyapunov", "greedy")


def _partition_valid(decision) -> bool:
    active = np.asarray(decision.state.mask) > 0
    sub = np.asarray(decision.partition.subgraph)
    return bool((sub[active] >= 0).all() and (sub[~active] == -1).all())


def _assignment_valid(decision, m: int) -> bool:
    active = np.asarray(decision.state.mask) > 0
    srv = np.asarray(decision.servers)
    return bool(((srv[active] >= 0) & (srv[active] < m)).all()
                and (srv[~active] == -1).all())


def _lyapunov_oracle_record(seed: int = 0) -> dict:
    """Jit scan vs numpy reference on one seeded scenario (the CI gate)."""
    import jax

    from repro.core import costs
    from repro.core.api import GraphEdgeController
    from repro.core.dynamic_graph import random_scenario
    from repro.core.offload.batched_env import make_scene
    from repro.core.offload.env import OffloadEnv
    from repro.core.offload.lyapunov import (lyapunov_rollout_jit,
                                             run_lyapunov)

    rng = np.random.default_rng(seed)
    state = random_scenario(rng, 48, 40, 120)
    net = costs.default_network(rng, 48, 4)
    ctrl = GraphEdgeController(net=net, policy="lyapunov")
    part = ctrl.partition(state)
    env = OffloadEnv(net, state, part, zeta_sp=ctrl.zeta_sp,
                     cost_scale=ctrl.cost_scale)
    stats = run_lyapunov(env)
    scene = make_scene(net, state, part.subgraph, zeta_sp=ctrl.zeta_sp,
                       cost_scale=ctrl.cost_scale)
    assign, reward = jax.jit(lyapunov_rollout_jit)(scene)
    mism = int((np.asarray(assign, np.int64) != env.assign).sum())
    rerr = abs(float(reward) - stats["reward"]) / max(abs(stats["reward"]),
                                                      1e-9)
    return {"seed": seed, "assign_mismatches": mism,
            "reward_rel_err": rerr, "queue_max": stats["queue_max"]}


def run(quick: bool = True, partitioner: str | None = None,
        policy: str | None = None, steps: int | None = None) -> None:
    from repro.core import costs
    from repro.core.api import GraphEdgeController
    from repro.core.dynamic_graph import random_scenario

    parts = list(PARTITIONERS)
    pols = list(POLICIES)
    if partitioner and partitioner not in parts:
        parts.append(partitioner)
    if policy and policy not in pols:
        pols.append(policy)
    if quick:
        users_axis, rates = (32,), (0.3,)
        steps = 4 if steps is None else steps
    else:
        users_axis, rates = (64, 128), (0.1, 0.3)
        steps = 6 if steps is None else steps

    records = []
    for users in users_axis:
        capacity = users + 8
        rng = np.random.default_rng(0)
        state0 = random_scenario(rng, capacity, users, 3 * users)
        net = costs.default_network(rng, capacity, 4)
        for change_rate in rates:
            for part in parts:
                for pol in pols:
                    # warm every compile/dispatch in the cell's exact
                    # path (incl. the perturbation event ops) with a
                    # throwaway controller over the identical rollout —
                    # a bare step(state0) leaves the first cell
                    # compile-dominated and its steps/sec wrong by >10×
                    warm = GraphEdgeController(net=net, policy=pol,
                                               partitioner=part)
                    warm.rollout(state0, steps, np.random.default_rng(1),
                                 change_rate=change_rate)
                    # timed arm: fresh controller (cold partition LRU),
                    # so the real per-topology cut work is still measured
                    ctrl = GraphEdgeController(net=net, policy=pol,
                                               partitioner=part)
                    t0 = time.perf_counter()
                    decisions = ctrl.rollout(state0, steps,
                                             np.random.default_rng(1),
                                             change_rate=change_rate)
                    dt = time.perf_counter() - t0
                    m = int(net.server_pos.shape[0])
                    cms = [d.partition.cut_metrics for d in decisions]
                    rec = {
                        "users": users, "capacity": capacity,
                        "change_rate": change_rate,
                        "partitioner": part, "policy": pol,
                        "steps": steps,
                        "steps_per_sec": steps / dt,
                        "system_cost_mean": float(np.mean(
                            [float(d.cost.c) for d in decisions])),
                        "cross_edges_mean": float(np.mean(
                            [c["cross_edges"] for c in cms])),
                        "cut_fraction_mean": float(np.mean(
                            [c["cut_fraction"] for c in cms])),
                        "num_subgraphs_mean": float(np.mean(
                            [c["num_subgraphs"] for c in cms])),
                        "partition_valid": all(_partition_valid(d)
                                               for d in decisions),
                        "assignment_valid": all(_assignment_valid(d, m)
                                                for d in decisions),
                    }
                    records.append(rec)
                    emit(f"backends_u{users}_r{change_rate}_{part}_{pol}",
                         dt / steps * 1e6,
                         f"cost={rec['system_cost_mean']:.2f};"
                         f"cut={rec['cross_edges_mean']:.1f};"
                         f"steps_per_sec={rec['steps_per_sec']:.2f}")

    oracle = _lyapunov_oracle_record()
    emit("backends_lyapunov_oracle", 0.0,
         f"assign_mismatches={oracle['assign_mismatches']};"
         f"reward_rel_err={oracle['reward_rel_err']:.2e}")
    write_bench_json(OUT_JSON, "backends", quick, records,
                     grid={"partitioners": parts, "policies": pols,
                           "users": list(users_axis),
                           "change_rates": list(rates)},
                     lyapunov_oracle=oracle)


def main() -> None:
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="small grid (the default)")
    mode.add_argument("--full", action="store_true",
                      help="paper-scale grid axes")
    ap.add_argument("--partitioner", default=None,
                    help="extra partitioner registry name to include")
    ap.add_argument("--policy", default=None,
                    help="extra offload-policy registry name to include")
    ap.add_argument("--steps", type=int, default=None,
                    help="rollout steps per cell")
    args = ap.parse_args()
    run(quick=not args.full, partitioner=args.partitioner,
        policy=args.policy, steps=args.steps)


if __name__ == "__main__":
    main()
