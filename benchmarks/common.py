"""Shared benchmark plumbing: CSV emission, quick/full mode, and the
common ``BENCH_*.json`` schema header (see BENCHMARKS.md)."""
from __future__ import annotations

import json
import pathlib
import subprocess
import time

# Bump when the *header* layout changes (record layouts are per-bench and
# documented in BENCHMARKS.md).
BENCH_SCHEMA_VERSION = 1


def git_describe() -> str:
    """``git describe --always --dirty`` of the tree the bench ran in
    ("unknown" outside a checkout), so BENCH_*.json files are
    self-describing across PRs."""
    try:
        return subprocess.run(
            ["git", "describe", "--always", "--dirty"],
            cwd=pathlib.Path(__file__).resolve().parent,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def warn_stale_benches(root: pathlib.Path | None = None) -> list[str]:
    """Warn (loudly, on stdout with the ``#`` CSV-comment prefix) for every
    checked-in ``BENCH_*.json`` whose stamped ``git`` describe no longer
    matches the current tree — i.e. numbers generated at an older commit.
    The ``-dirty`` suffix is ignored: only the base hash must match.
    Returns the stale file names so callers/tests can assert on them."""
    here = git_describe().removesuffix("-dirty")
    if here == "unknown":
        return []
    root = root or pathlib.Path(__file__).resolve().parent.parent
    stale = []
    for path in sorted(root.glob("BENCH_*.json")):
        try:
            stamped = json.loads(path.read_text()).get("git", "unknown")
        except (OSError, json.JSONDecodeError):
            stamped = "unreadable"
        if stamped.removesuffix("-dirty") != here:
            stale.append(path.name)
            print(f"# WARNING: {path.name} stamped {stamped!r} but the "
                  f"tree is {here!r} — stale numbers, regenerate")
    return stale


def write_bench_json(path: str, bench: str, quick: bool, records: list,
                     **extra) -> None:
    """Write a ``BENCH_*.json`` with the common schema header: every file
    carries ``schema`` / ``bench`` / ``quick`` / ``git`` / ``records``
    (plus bench-specific top-level extras), so readers never need to guess
    which bench or tree produced it."""
    payload = {"schema": BENCH_SCHEMA_VERSION, "bench": bench,
               "quick": bool(quick), "git": git_describe(), **extra,
               "records": records}
    out = pathlib.Path(path)
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {out}")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn, repeats: int = 3) -> float:
    """Median wall time of fn() in microseconds."""
    return timeit_with_result(fn, repeats)[0]


def timeit_with_result(fn, repeats: int = 3):
    """(median wall time of fn() in µs, result of the last timed call) —
    so benchmarks that also inspect the output never run fn() twice."""
    times, result = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2], result
