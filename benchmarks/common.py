"""Shared benchmark plumbing: CSV emission, quick/full mode, and the
common ``BENCH_*.json`` schema header (see BENCHMARKS.md)."""
from __future__ import annotations

import json
import pathlib
import subprocess
import time

# Bump when the *header* layout changes (record layouts are per-bench and
# documented in BENCHMARKS.md).
BENCH_SCHEMA_VERSION = 1


def _git(root: pathlib.Path, *args: str) -> str:
    try:
        return subprocess.run(["git", *args], cwd=root, capture_output=True,
                              text=True, timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return ""


def git_describe() -> str:
    """``git describe --always`` of the tree the bench ran in, suffixed
    ``-dirty`` when any *tracked, non-BENCH* file differs from HEAD
    ("unknown" outside a checkout). ``BENCH_*.json`` files are the
    benches' own outputs — regenerating them must not dirty their own
    stamp, or a clean-HEAD regeneration could never produce a clean
    stamp."""
    root = pathlib.Path(__file__).resolve().parent.parent
    head = _git(root, "describe", "--always")
    if not head:
        return "unknown"
    dirt = _git(root, "status", "--porcelain", "--untracked-files=no",
                "--", ".", ":(exclude)BENCH_*.json")
    return head + ("-dirty" if dirt else "")


def warn_stale_benches(root: pathlib.Path | None = None) -> list[str]:
    """Warn (loudly, on stdout with the ``#`` CSV-comment prefix) for every
    checked-in ``BENCH_*.json`` whose stamped ``git`` describe no longer
    matches the current tree — i.e. numbers generated at an older commit —
    **or** whose stamp carries a ``-dirty`` suffix, meaning the numbers came
    from an uncommitted tree and no commit can reproduce them, **or** whose
    ``schema`` field predates :data:`BENCH_SCHEMA_VERSION` — old-schema
    records would otherwise silently pass the smoke gates with fields the
    current readers misinterpret. (The current tree being dirty is fine —
    only the *stamp* must be clean and match.)
    "Current tree" means the last commit touching anything *but*
    ``BENCH_*.json``: committing freshly regenerated BENCH files moves
    HEAD, so the stamp (taken before that commit) is compared against the
    code it actually measured, not against the commit that merely
    archived the numbers. Returns the flagged file names so
    callers/tests can assert on them."""
    root = root or pathlib.Path(__file__).resolve().parent.parent
    here = _git(root, "log", "-1", "--format=%h", "--", ".",
                ":(exclude)BENCH_*.json") \
        or git_describe().removesuffix("-dirty")
    if here == "unknown":
        return []
    stale = []
    for path in sorted(root.glob("BENCH_*.json")):
        try:
            payload = json.loads(path.read_text())
            stamped = payload.get("git", "unknown")
            schema = payload.get("schema")
        except (OSError, json.JSONDecodeError):
            stamped, schema = "unreadable", BENCH_SCHEMA_VERSION
        if schema != BENCH_SCHEMA_VERSION:
            stale.append(path.name)
            print(f"# WARNING: {path.name} carries schema {schema!r} but "
                  f"the writer is at {BENCH_SCHEMA_VERSION!r} — regenerate "
                  f"before trusting its records")
        elif stamped.endswith("-dirty"):
            stale.append(path.name)
            print(f"# WARNING: {path.name} stamped {stamped!r} — numbers "
                  f"from an uncommitted tree, regenerate at a clean HEAD")
        elif stamped != here:
            stale.append(path.name)
            print(f"# WARNING: {path.name} stamped {stamped!r} but the "
                  f"tree is {here!r} — stale numbers, regenerate")
    return stale


def write_bench_json(path: str, bench: str, quick: bool, records: list,
                     **extra) -> None:
    """Write a ``BENCH_*.json`` with the common schema header: every file
    carries ``schema`` / ``bench`` / ``quick`` / ``git`` / ``records``
    (plus bench-specific top-level extras), so readers never need to guess
    which bench or tree produced it."""
    payload = {"schema": BENCH_SCHEMA_VERSION, "bench": bench,
               "quick": bool(quick), "git": git_describe(), **extra,
               "records": records}
    out = pathlib.Path(path)
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {out}")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn, repeats: int = 3) -> float:
    """Median wall time of fn() in microseconds."""
    return timeit_with_result(fn, repeats)[0]


def timeit_with_result(fn, repeats: int = 3):
    """(median wall time of fn() in µs, result of the last timed call) —
    so benchmarks that also inspect the output never run fn() twice."""
    times, result = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2], result
