"""Shared benchmark plumbing: CSV emission, quick/full mode, and the
common ``BENCH_*.json`` schema header (see BENCHMARKS.md)."""
from __future__ import annotations

import json
import pathlib
import subprocess
import time

# Bump when the *header* layout changes (record layouts are per-bench and
# documented in BENCHMARKS.md).
BENCH_SCHEMA_VERSION = 1


def git_describe() -> str:
    """``git describe --always --dirty`` of the tree the bench ran in
    ("unknown" outside a checkout), so BENCH_*.json files are
    self-describing across PRs."""
    try:
        return subprocess.run(
            ["git", "describe", "--always", "--dirty"],
            cwd=pathlib.Path(__file__).resolve().parent,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def write_bench_json(path: str, bench: str, quick: bool, records: list,
                     **extra) -> None:
    """Write a ``BENCH_*.json`` with the common schema header: every file
    carries ``schema`` / ``bench`` / ``quick`` / ``git`` / ``records``
    (plus bench-specific top-level extras), so readers never need to guess
    which bench or tree produced it."""
    payload = {"schema": BENCH_SCHEMA_VERSION, "bench": bench,
               "quick": bool(quick), "git": git_describe(), **extra,
               "records": records}
    out = pathlib.Path(path)
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {out}")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn, repeats: int = 3) -> float:
    """Median wall time of fn() in microseconds."""
    return timeit_with_result(fn, repeats)[0]


def timeit_with_result(fn, repeats: int = 3):
    """(median wall time of fn() in µs, result of the last timed call) —
    so benchmarks that also inspect the output never run fn() twice."""
    times, result = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2], result
