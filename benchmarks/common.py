"""Shared benchmark plumbing: CSV emission + quick/full mode."""
from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn, repeats: int = 3) -> float:
    """Median wall time of fn() in microseconds."""
    return timeit_with_result(fn, repeats)[0]


def timeit_with_result(fn, repeats: int = 3):
    """(median wall time of fn() in µs, result of the last timed call) —
    so benchmarks that also inspect the output never run fn() twice."""
    times, result = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2], result
