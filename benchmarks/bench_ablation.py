"""Paper Fig. 12 (ablation): DRLGO vs DRL-only (no HiCut, no subgraph
reward) — system cost and cross-server bytes across time steps."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.dynamic_graph import perturb_scenario
from repro.core.offload.drlgo import DRLGOTrainer, DRLGOTrainerConfig


def run(quick: bool = True) -> None:
    episodes = 30 if quick else 300
    n_users = 24 if quick else 300
    base = dict(capacity=n_users + 8, n_users=n_users, n_assoc=3 * n_users,
                episodes=episodes, warmup_steps=256, cost_scale=1.0)
    full = DRLGOTrainer(DRLGOTrainerConfig(**base, use_hicut=True))
    ablated = DRLGOTrainer(DRLGOTrainerConfig(**base, use_hicut=False))
    full.train()
    ablated.train()

    rng = np.random.default_rng(3)
    sc = full.scenario
    costs_full, costs_abl, bits_full, bits_abl = [], [], [], []
    for t in range(3 if quick else 10):
        sc = perturb_scenario(rng, sc, 0.2)
        f = full.evaluate(sc)
        a = ablated.evaluate(sc)
        costs_full.append(f["system_cost"])
        costs_abl.append(a["system_cost"])
        bits_full.append(f["cross_bits"])
        bits_abl.append(a["cross_bits"])
        emit(f"fig12_t{t}", 0.0,
             f"drlgo={f['system_cost']:.2f};drl_only={a['system_cost']:.2f}")
    emit("fig12_summary", 0.0,
         f"drlgo_mean={np.mean(costs_full):.2f};"
         f"drl_only_mean={np.mean(costs_abl):.2f};"
         f"crossbits_drlgo={np.mean(bits_full):.0f};"
         f"crossbits_drl_only={np.mean(bits_abl):.0f}")


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
