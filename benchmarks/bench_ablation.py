"""Paper Fig. 12 (ablation): DRLGO vs DRL-only (no HiCut, no subgraph
reward) — system cost and cross-server bytes across time steps.

Both arms are :class:`repro.core.api.GraphEdgeController` instances that
differ only in the partitioner registry name: the full system uses
``partitioner`` (HiCut by default), the ablation uses ``"none"`` (every
vertex its own subgraph, subgraph reward off)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.api import GraphEdgeController
from repro.core.dynamic_graph import perturb_scenario
from repro.core.offload.drlgo import DRLGOTrainer, DRLGOTrainerConfig


def run(quick: bool = True, partitioner: str = "hicut_ref") -> None:
    episodes = 30 if quick else 300
    n_users = 24 if quick else 300
    base = dict(capacity=n_users + 8, n_users=n_users, n_assoc=3 * n_users,
                episodes=episodes, warmup_steps=256, cost_scale=1.0)
    full = DRLGOTrainer(DRLGOTrainerConfig(**base, partitioner=partitioner))
    ablated = DRLGOTrainer(DRLGOTrainerConfig(**base, partitioner="none"))
    full.train()
    ablated.train()

    arms = {}
    for tag, tr in (("drlgo", full), ("drl_only", ablated)):
        arms[tag] = GraphEdgeController(
            net=tr.net, policy=tr.as_policy(),
            partitioner=tr.cfg.partitioner_name,
            cost_scale=tr.cfg.cost_scale, zeta_sp=tr.cfg.zeta_sp)

    rng = np.random.default_rng(3)
    sc = full.scenario
    costs_full, costs_abl, bits_full, bits_abl = [], [], [], []
    for t in range(3 if quick else 10):
        sc = perturb_scenario(rng, sc, 0.2)
        f = arms["drlgo"].step(sc)
        a = arms["drl_only"].step(sc)
        costs_full.append(float(f.cost.c))
        costs_abl.append(float(a.cost.c))
        bits_full.append(float(f.cost.cross_bits.sum()))
        bits_abl.append(float(a.cost.cross_bits.sum()))
        emit(f"fig12_t{t}", 0.0,
             f"drlgo={costs_full[-1]:.2f};drl_only={costs_abl[-1]:.2f}")
    emit("fig12_summary", 0.0,
         f"drlgo_mean={np.mean(costs_full):.2f};"
         f"drl_only_mean={np.mean(costs_abl):.2f};"
         f"crossbits_drlgo={np.mean(bits_full):.0f};"
         f"crossbits_drl_only={np.mean(bits_abl):.0f}")


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
