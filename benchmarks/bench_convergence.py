"""Paper Fig. 11: training-reward convergence, DRLGO vs PTOM — plus the
``--batch`` throughput axis for the vmapped environment.

Both learners train on the §6.4 dynamic protocol (20% change rate); the
negated system cost is the reward. Emits the reward trace (down-sampled)
and the final-window mean/std — DRLGO should converge higher and flatter.

With ``--batch B > 1`` both learners collect B vmapped episodes per update
round through :class:`~repro.core.offload.batched_env.BatchedOffloadEnv`;
the ``*_eps_per_sec`` rows report steady-state training throughput — the
timer starts after jit compilation is warm and the replay warmup threshold
is reached, so both batch settings measure the same collect + update
regime. ``--batch 8`` should report ≥ 4× the episodes/sec of ``--batch 1``;
because absolute eps/sec numbers jitter with ambient CPU load, a
``--batch B > 1`` run *also* times the B=1 path in the same process and
emits the noise-immune ``fig11_drlgo_batch_speedup`` row.

    PYTHONPATH=src python benchmarks/bench_convergence.py --batch 8
"""
from __future__ import annotations

import time

import numpy as np


def _train_ptom(tr, ptom, episodes: int, batch: int, change_rate: float):
    """PTOM episodes on the trainer's perturbation protocol → rewards."""
    from repro.core.dynamic_graph import perturb_scenario
    rng = np.random.default_rng(1)
    rewards = []
    if batch > 1:
        scenarios = [tr.scenario] * batch
        while len(rewards) < episodes:
            scenarios = [perturb_scenario(rng, s, change_rate)
                         for s in scenarios]
            benv = tr.make_batched_env(scenarios)
            rewards.extend(o["reward"] for o in ptom.run_batch(benv))
    else:
        sc = tr.scenario
        for _ in range(episodes):
            sc = perturb_scenario(rng, sc, change_rate)
            rewards.append(ptom.run_episode(tr.make_env(sc))["reward"])
    return np.array(rewards)     # all trained episodes (may exceed request)


def _warmed_trainer(cfg):
    """Trainer past every cold-start cliff: jit round, update compile, and
    the replay-warmup threshold — so subsequent ``train()`` calls measure
    the same steady collect + update regime at every batch size."""
    from repro.core.offload.drlgo import DRLGOTrainer

    tr = DRLGOTrainer(cfg)
    round_eps = max(cfg.batch_envs, 1)
    tr.train(episodes=round_eps)
    tr.warm_update_jit()
    while len(tr.buffer) < max(tr.mcfg.batch_size, cfg.warmup_steps):
        tr.train(episodes=round_eps)
    return tr


def run(quick: bool = True, batch: int = 1) -> None:
    from dataclasses import replace

    from benchmarks.common import emit
    from repro.core.offload.drlgo import DRLGOTrainerConfig
    from repro.core.offload.env import OBS_DIM
    from repro.core.offload.ppo import PPOConfig, PTOMAgent

    episodes = 40 if quick else 500
    n_users = 24 if quick else 300
    cfg = DRLGOTrainerConfig(capacity=n_users + 8, n_users=n_users,
                             n_assoc=3 * n_users, episodes=episodes,
                             warmup_steps=256, cost_scale=1.0,
                             batch_envs=batch)
    tr = _warmed_trainer(cfg)
    # With batch > 1 a B=1 reference is timed in the SAME process with the
    # timing slices interleaved, so ambient CPU-load swings hit both legs
    # equally and the speedup row stays meaningful on a noisy machine.
    ref = _warmed_trainer(replace(cfg, batch_envs=1)) if batch > 1 else None
    dt_main = dt_ref = 0.0
    h_main = len(tr.history)
    h_ref = len(ref.history) if ref is not None else 0
    while len(tr.history) - h_main < episodes:
        # batched rounds may overshoot a chunk; count actual episodes below
        n = min(max(batch, 4), episodes - (len(tr.history) - h_main))
        t0 = time.perf_counter()
        tr.train(episodes=n)
        dt_main += time.perf_counter() - t0
        if ref is not None:
            t0 = time.perf_counter()
            ref.train(episodes=n)
            dt_ref += time.perf_counter() - t0
    n_main = len(tr.history) - h_main
    eps_per_sec = n_main / dt_main
    emit("fig11_drlgo_eps_per_sec", eps_per_sec,
         f"us_per_episode={1e6 / eps_per_sec:.1f};batch={batch};"
         f"episodes={n_main}")
    if ref is not None:
        n_ref = len(ref.history) - h_ref
        ref_eps = n_ref / dt_ref
        emit("fig11_drlgo_eps_per_sec_b1ref", ref_eps,
             f"us_per_episode={1e6 / ref_eps:.1f};batch=1")
        emit("fig11_drlgo_batch_speedup", eps_per_sec / ref_eps,
             f"batch={batch};vs=1;same_process=1;interleaved=1")
    # Fig. 11 reward trace covers the full from-scratch history (the warm
    # region is excluded from the timer above, not from training)
    rewards = np.array([h["reward"] for h in tr.history])

    ptom = PTOMAgent(PPOConfig(state_dim=cfg.n_servers * OBS_DIM,
                               n_actions=cfg.n_servers))
    _train_ptom(tr, ptom, max(batch, 1), batch, cfg.change_rate)  # jit warm
    t0 = time.perf_counter()
    ptom_rewards = _train_ptom(tr, ptom, episodes, batch, cfg.change_rate)
    dt = time.perf_counter() - t0
    emit("fig11_ptom_eps_per_sec", len(ptom_rewards) / dt,
         f"us_per_episode={dt / len(ptom_rewards) * 1e6:.1f};batch={batch};"
         f"episodes={len(ptom_rewards)}")

    w = max(4, episodes // 8)
    for name, r in (("drlgo", rewards), ("ptom", ptom_rewards)):
        emit(f"fig11_{name}_final", 0.0,
             f"mean={r[-w:].mean():.2f};std={r[-w:].std():.2f};"
             f"first={r[:w].mean():.2f}")
        stride = max(1, len(r) // 10)
        trace = ";".join(f"{v:.1f}" for v in r[::stride])
        emit(f"fig11_{name}_trace", 0.0, trace)


if __name__ == "__main__":
    import argparse
    import os
    import sys

    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_root, os.path.join(_root, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale axes (300 users, 500 episodes)")
    ap.add_argument("--batch", type=int, default=1,
                    help="vmapped episodes per update round (B)")
    args = ap.parse_args()
    run(quick=not args.full, batch=args.batch)
