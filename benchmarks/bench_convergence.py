"""Paper Fig. 11: training-reward convergence, DRLGO vs PTOM.

Both learners train on the §6.4 dynamic protocol (20% change rate); the
negated system cost is the reward. Emits the reward trace (down-sampled)
and the final-window mean/std — DRLGO should converge higher and flatter.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.offload.drlgo import DRLGOTrainer, DRLGOTrainerConfig
from repro.core.offload.env import OBS_DIM
from repro.core.offload.ppo import PPOConfig, PTOMAgent


def run(quick: bool = True) -> None:
    episodes = 40 if quick else 500
    n_users = 24 if quick else 300
    cfg = DRLGOTrainerConfig(capacity=n_users + 8, n_users=n_users,
                             n_assoc=3 * n_users, episodes=episodes,
                             warmup_steps=256, cost_scale=1.0)
    tr = DRLGOTrainer(cfg)
    hist = tr.train()
    rewards = np.array([h["reward"] for h in hist])

    ptom = PTOMAgent(PPOConfig(state_dim=cfg.n_servers * OBS_DIM,
                               n_actions=cfg.n_servers))
    ptom_rewards = []
    from repro.core.dynamic_graph import perturb_scenario
    rng = np.random.default_rng(1)
    sc = tr.scenario
    for _ in range(episodes):
        sc = perturb_scenario(rng, sc, cfg.change_rate)
        env = tr.make_env(sc)
        ptom_rewards.append(ptom.run_episode(env)["reward"])
    ptom_rewards = np.array(ptom_rewards)

    w = max(4, episodes // 8)
    for name, r in (("drlgo", rewards), ("ptom", ptom_rewards)):
        emit(f"fig11_{name}_final", 0.0,
             f"mean={r[-w:].mean():.2f};std={r[-w:].std():.2f};"
             f"first={r[:w].mean():.2f}")
        stride = max(1, episodes // 10)
        trace = ";".join(f"{v:.1f}" for v in r[::stride])
        emit(f"fig11_{name}_trace", 0.0, trace)


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
