"""Kernel microbenchmarks — the fused aggregation kernel plus the other
Pallas kernels' XLA reference paths.

The GNN-aggregate section runs the BENCH_partition graph shapes through
every layer formulation and writes **``BENCH_kernels.json``** (schema in
BENCHMARKS.md):

* **kernel vs kernel** (interpret mode, jitted): the fused
  gather–normalize–matmul kernel against the unfused pair — the existing
  ``gnn_gather_aggregate_pallas`` followed by the layer matmul. Interpret
  mode is the only Pallas execution venue on this CPU-only box and both
  arms pay the same interpreter, so the ratio isolates the structural
  change (chunked slot gathers on a native-width slab vs the
  slot-at-a-time ``fori_loop`` on a lane-padded slab).
* **XLA layer paths** (compiled wall-clock): fused/unfused gather layer
  vs the dense masked-SpMM layer.
* **auto-selection**: ``resolve_aggregate`` on the real partition plan;
  ``agg_speedup`` compares the dense layer against the selected path
  (exactly 1.0 by construction when "dense" is selected — the selected
  arm *is* the dense timing then).

``--profile`` wraps the timed section in a ``jax.profiler`` trace (one
TensorBoard-loadable directory per run; see tools/profile_trace.py for
the standalone lane). ``--quick`` / ``--full`` pick the axis sizes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit, write_bench_json
from repro.core.hicut import hicut_ref
from repro.data.graphs import random_graph
from repro.gnn.distributed import (make_partition_plan_sparse,
                                   resolve_aggregate)
from repro.gnn.layers import gcn_norm_sparse
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.gnn_aggregate.autotune import get_config
from repro.kernels.gnn_aggregate.ops import (fused_gather_aggregate,
                                             gather_aggregate,
                                             normalized_aggregate,
                                             sort_neighbor_slots)
from repro.kernels.chunk_scan.ops import ssd_chunk_scan

OUT_JSON = "BENCH_kernels.json"
FEATURE_DIM = 64
DEVICES = 4
GRAPH_SEED = 1


def _best_of(fn, repeats: int = 9) -> float:
    """Min wall time of fn() in µs — kernel-vs-kernel ratios need the
    noise floor, not the median, on a busy single-core box."""
    fn()   # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _aggregate_record(n: int, e: int, rng: np.random.Generator) -> dict:
    g = random_graph(n, e, seed=GRAPH_SEED)
    idx, val, dinv = gcn_norm_sparse(g.edges, n)
    idx, val = sort_neighbor_slots(idx, val)
    k = idx.shape[1]
    x = jnp.asarray(rng.normal(size=(n, FEATURE_DIM)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(FEATURE_DIM, FEATURE_DIM)).astype(
        np.float32) * 0.1)
    ij, vj, dj = jnp.asarray(idx), jnp.asarray(val), jnp.asarray(dinv)
    cfg = get_config(n, n, FEATURE_DIM, FEATURE_DIM, k)

    # kernel vs kernel (interpret mode, jitted — see module docstring)
    fused_k = jax.jit(lambda xx: fused_gather_aggregate(
        ij, vj, xx, dj, dj, w, impl="interpret"))
    unfused_k = jax.jit(lambda xx: gather_aggregate(
        ij, vj, xx, dj, dj, impl="interpret") @ w)
    t_fused_k = _best_of(lambda: fused_k(x).block_until_ready())
    t_unfused_k = _best_of(lambda: unfused_k(x).block_until_ready())

    # XLA layer paths (compiled wall clock; the xla lane has no fusion
    # distinction — fused impl="xla" is exactly gather + matmul)
    fused_x = jax.jit(lambda xx: fused_gather_aggregate(
        ij, vj, xx, dj, dj, w, impl="xla"))
    unfused_x = jax.jit(lambda xx: gather_aggregate(
        ij, vj, xx, dj, dj, impl="xla") @ w)
    a_hat = jnp.asarray(g.adjacency() + np.eye(n, dtype=np.float32))
    dense_x = jax.jit(lambda xx: normalized_aggregate(
        a_hat, xx, dj, dj, impl="xla") @ w)
    t_fused_x = _best_of(lambda: fused_x(x).block_until_ready())
    t_unfused_x = _best_of(lambda: unfused_x(x).block_until_ready())
    t_dense_x = _best_of(lambda: dense_x(x).block_until_ready())

    parity = float(jnp.abs(fused_k(x) - fused_x(x)).max())

    # auto-selection on the real partition plan for this graph
    assign = hicut_ref(n, g.edges) % DEVICES
    plan = make_partition_plan_sparse(g.edges, assign, DEVICES, n=n)
    selected = resolve_aggregate(plan)
    # when "dense" is selected the selected arm IS the dense timing, so
    # agg_speedup is exactly 1.0 by construction (never < 1 from noise)
    t_selected = t_dense_x if selected == "dense" else t_fused_x

    rec = {"n": n, "e": g.num_edges, "f": FEATURE_DIM, "k": k,
           "devices": DEVICES, "config": list(cfg),
           "t_fused_kernel_us": t_fused_k,
           "t_unfused_kernel_us": t_unfused_k,
           "fused_kernel_speedup": t_unfused_k / max(t_fused_k, 1e-9),
           "t_agg_fused_xla_us": t_fused_x,
           "t_agg_unfused_xla_us": t_unfused_x,
           "t_agg_dense_us": t_dense_x,
           "selected": selected,
           "agg_speedup": t_dense_x / max(t_selected, 1e-9),
           "fused_parity_err": parity}
    emit(f"kernel_fused_aggregate_n{n}_k{k}", t_fused_k,
         f"cfg={tuple(cfg)};unfused={t_unfused_k:.0f}us;"
         f"speedup={rec['fused_kernel_speedup']:.2f}x;"
         f"parity={parity:.1e}")
    emit(f"agg_layer_n{n}_selected_{selected}", t_selected,
         f"dense={t_dense_x:.0f}us;agg_speedup={rec['agg_speedup']:.2f}x")
    return rec


def run(quick: bool = True, profile_dir: str | None = None) -> None:
    if profile_dir is not None:
        jax.profiler.start_trace(profile_dir)
    try:
        _run(quick)
    finally:
        if profile_dir is not None:
            jax.profiler.stop_trace()
            print(f"# profile trace written to {profile_dir}")


def _run(quick: bool) -> None:
    rng = np.random.default_rng(0)

    cases = [(1_000, 10_000), (5_000, 50_000)] if quick else \
        [(1_000, 10_000), (2_000, 20_000), (5_000, 50_000)]
    records = [_aggregate_record(n, e, rng) for n, e in cases]
    write_bench_json(OUT_JSON, "kernels", quick, records)

    # flash attention
    b, h, kv, s, dh = (1, 4, 2, 1024, 64) if quick else (2, 8, 2, 4096, 128)
    q = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, kv, s, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, kv, s, dh)).astype(np.float32))
    fa = jax.jit(lambda q_, k_, v_: flash_attention(q_, k_, v_))
    fa(q, k, v).block_until_ready()
    t = timeit(lambda: fa(q, k, v).block_until_ready())
    emit(f"kernel_flash_attention_s{s}_dh{dh}", t,
         f"blocks=128x128;vmem_scratch={4 * (128 + 128 + 128 * dh)}B")

    # ssd chunk scan
    b2, s2, h2, p2, n2 = (2, 512, 4, 64, 64) if quick else (4, 2048, 8, 64, 64)
    xx = jnp.asarray(rng.normal(size=(b2, s2, h2, p2)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b2, s2, n2)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b2, s2, n2)).astype(np.float32))
    la = -jnp.asarray(rng.random((b2, s2, h2)).astype(np.float32))
    sc = jax.jit(lambda *a: ssd_chunk_scan(*a))
    sc(xx, bm, cm, la).block_until_ready()
    t = timeit(lambda: sc(xx, bm, cm, la).block_until_ready())
    emit(f"kernel_ssd_scan_s{s2}_h{h2}", t,
         f"chunk=128;state_vmem={p2 * n2 * 4}B")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--full", action="store_true",
                    help="paper-scale axes (slow)")
    ap.add_argument("--quick", action="store_true",
                    help="small axes (the default; --full overrides)")
    ap.add_argument("--profile", metavar="DIR", default=None,
                    help="write a jax.profiler trace of the run to DIR")
    args = ap.parse_args()
    run(quick=not args.full, profile_dir=args.profile)
