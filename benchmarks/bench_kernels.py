"""Kernel microbenchmarks (XLA path wall-clock on CPU; the Pallas kernels
target TPU and are validated in interpret mode by the test suite — CPU
wall time of interpret mode is not meaningful, so we time the jnp/XLA
reference path and report the kernels' VMEM working sets as derived)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.gnn_aggregate.ops import normalized_aggregate
from repro.kernels.chunk_scan.ops import ssd_chunk_scan


def run(quick: bool = True) -> None:
    rng = np.random.default_rng(0)

    # gnn_aggregate
    n, f = (512, 128) if quick else (4096, 512)
    adj = jnp.asarray((rng.random((n, n)) < 0.05).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    scale = jnp.ones((n,), jnp.float32)
    fn = jax.jit(lambda a, x_: normalized_aggregate(a, x_, scale, scale))
    fn(adj, x).block_until_ready()
    t = timeit(lambda: fn(adj, x).block_until_ready())
    emit(f"kernel_gnn_aggregate_n{n}_f{f}", t,
         f"vmem_tile=128x128x128;flops={2 * n * n * f:.0f}")

    # flash attention
    b, h, kv, s, dh = (1, 4, 2, 1024, 64) if quick else (2, 8, 2, 4096, 128)
    q = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, kv, s, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, kv, s, dh)).astype(np.float32))
    fa = jax.jit(lambda q_, k_, v_: flash_attention(q_, k_, v_))
    fa(q, k, v).block_until_ready()
    t = timeit(lambda: fa(q, k, v).block_until_ready())
    emit(f"kernel_flash_attention_s{s}_dh{dh}", t,
         f"blocks=128x128;vmem_scratch={4 * (128 + 128 + 128 * dh)}B")

    # ssd chunk scan
    b2, s2, h2, p2, n2 = (2, 512, 4, 64, 64) if quick else (4, 2048, 8, 64, 64)
    xx = jnp.asarray(rng.normal(size=(b2, s2, h2, p2)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b2, s2, n2)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b2, s2, n2)).astype(np.float32))
    la = -jnp.asarray(rng.random((b2, s2, h2)).astype(np.float32))
    sc = jax.jit(lambda *a: ssd_chunk_scan(*a))
    sc(xx, bm, cm, la).block_until_ready()
    t = timeit(lambda: sc(xx, bm, cm, la).block_until_ready())
    emit(f"kernel_ssd_scan_s{s2}_h{h2}", t,
         f"chunk=128;state_vmem={p2 * n2 * 4}B")


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
