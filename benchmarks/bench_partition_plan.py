"""Sparse vs dense partition-plan construction + aggregation forward
(ROADMAP "sharded/large-graph serving"; the serving half of Fig. 6's axis).

Times ``make_partition_plan_sparse`` (vectorized O(E) edge-list path)
against ``make_partition_plan_dense_reference`` (the original O(N²)
triple-loop builder) on random graphs, plus the matching aggregation
forward: the sparse gather op vs the dense masked-SpMM op on the same
normalized Â. The dense builder/aggregate are skipped above
``DENSE_MAX_VERTICES`` — at the 20k/800k ``--full`` tip only the sparse
path runs (that is the point: no N×N anywhere).

Besides the usual CSV rows, writes **machine-readable
``BENCH_partition.json``** (one record per case: timings, speedups, plan
stats, parity error) so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit_with_result, write_bench_json
from repro.core.hicut import hicut_ref
from repro.data.graphs import random_graph
from repro.gnn.distributed import (make_partition_plan_dense_reference,
                                   make_partition_plan_sparse)
from repro.gnn.layers import gcn_norm_sparse
from repro.kernels.gnn_aggregate.ops import (gather_aggregate,
                                             normalized_aggregate)

DENSE_MAX_VERTICES = 5_000
FEATURE_DIM = 64
OUT_JSON = "BENCH_partition.json"


def run(quick: bool = True) -> None:
    import jax
    import jax.numpy as jnp

    if quick:
        cases = [(1_000, 10_000), (2_000, 20_000), (5_000, 50_000)]
        devices = 4
    else:  # paper Fig. 6 sparse axis up to 20k vertices
        cases = [(1_000, 10_000), (5_000, 200_000), (10_000, 400_000),
                 (20_000, 800_000)]
        devices = 8
    rng = np.random.default_rng(0)
    records = []
    for n, e in cases:
        g = random_graph(n, e, seed=int(rng.integers(1 << 30)))
        assign = hicut_ref(n, g.edges) % devices
        t_sparse, plan_s = timeit_with_result(
            lambda: make_partition_plan_sparse(g.edges, assign, devices,
                                               n=n), repeats=1)
        rec = {"n": n, "e": g.num_edges, "devices": devices,
               "t_plan_sparse_us": t_sparse, "halo": plan_s.halo,
               "block": plan_s.block, "max_degree": plan_s.max_degree,
               "bytes_per_aggregate": plan_s.bytes_per_aggregate(
                   FEATURE_DIM)}
        emit(f"partition_plan_sparse_v{n}_e{g.num_edges}", t_sparse,
             f"halo={plan_s.halo};max_deg={plan_s.max_degree}")

        if n <= DENSE_MAX_VERTICES:
            adj = g.adjacency()
            t_dense, plan_d = timeit_with_result(
                lambda: make_partition_plan_dense_reference(adj, assign,
                                                            devices),
                repeats=1)
            parity = float(np.abs(plan_s.dense_adj_ext()
                                  - plan_d.adj_ext).max())
            rec.update(t_plan_dense_us=t_dense,
                       plan_speedup=t_dense / max(t_sparse, 1e-9),
                       plan_parity_err=parity)
            emit(f"partition_plan_dense_v{n}_e{g.num_edges}", t_dense,
                 f"sparse_speedup={rec['plan_speedup']:.1f}x;"
                 f"parity_err={parity:.1e}")

        # aggregation forward on the same normalized operator (jitted +
        # warmed so both paths are timed compiled, not eager dispatch)
        idx, val, dinv = gcn_norm_sparse(g.edges, n)
        x = jnp.asarray(rng.normal(size=(n, FEATURE_DIM)).astype(
            np.float32))
        agg_s = jax.jit(lambda xx: gather_aggregate(jnp.asarray(idx),
                                                    jnp.asarray(val), xx,
                                                    dinv, dinv))
        y_s = np.asarray(agg_s(x))          # warmup/compile
        t_agg_s, _ = timeit_with_result(
            lambda: agg_s(x).block_until_ready(), repeats=3)
        rec["t_agg_sparse_us"] = t_agg_s
        emit(f"sparse_aggregate_v{n}_e{g.num_edges}", t_agg_s,
             f"k={idx.shape[1]}")
        if n <= DENSE_MAX_VERTICES:
            a_hat = jnp.asarray(g.adjacency() + np.eye(n, dtype=np.float32))
            agg_d = jax.jit(lambda xx: normalized_aggregate(a_hat, xx,
                                                            dinv, dinv))
            y_d = np.asarray(agg_d(x))      # warmup/compile
            t_agg_d, _ = timeit_with_result(
                lambda: agg_d(x).block_until_ready(), repeats=3)
            agg_err = float(np.abs(y_s - y_d).max())
            rec.update(t_agg_dense_us=t_agg_d,
                       agg_speedup=t_agg_d / max(t_agg_s, 1e-9),
                       agg_max_err=agg_err)
            emit(f"dense_aggregate_v{n}_e{g.num_edges}", t_agg_d,
                 f"sparse_speedup={rec['agg_speedup']:.1f}x;"
                 f"max_err={agg_err:.1e}")
        records.append(rec)

    write_bench_json(OUT_JSON, "partition_plan", quick, records)


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
