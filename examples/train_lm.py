"""Train a reduced assigned-architecture LM on synthetic tokens.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b --steps 50
    PYTHONPATH=src python examples/train_lm.py --arch mixtral-8x7b \
        --steps 20 --d-model 128            # any of the 10 archs works

Exercises the same model stack the multi-pod dry-run lowers (reduced dims
on CPU) — data pipeline → train_step (AdamW) → checkpoint. Loss should
drop visibly within a few dozen steps on the structured synthetic stream.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.registry import get_config, list_archs
from repro.data.tokens import TokenDataConfig, token_batches
from repro.models import transformer as T
from repro.models.config import reduced
from repro.optim.adamw import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), d_model=args.d_model,
                  layers=args.layers)
    print(f"{cfg.name}: ~{cfg.param_count() / 1e6:.1f}M-param family "
          f"config reduced to d_model={cfg.d_model}")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt = T.init_opt(params)
    step = jax.jit(T.make_train_step(cfg, AdamWConfig(lr=args.lr)))

    data = token_batches(TokenDataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=args.seq,
                                         batch_size=args.batch))
    extras = {}
    if cfg.num_prefix_tokens and cfg.prefix_dim:
        extras["prefix_emb"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.num_prefix_tokens, cfg.prefix_dim))
    if cfg.encoder_stages:
        extras["frames"] = 0.02 * jax.random.normal(
            key, (args.batch, cfg.encoder_seq_len, cfg.prefix_dim))

    t0 = time.time()
    first = last = None
    for i, batch in zip(range(args.steps), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()} | extras
        params, opt, m = step(params, opt, batch)
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if (i + 1) % max(args.steps // 10, 1) == 0:
            print(f"step {i + 1:4d}  loss {loss:.4f}")
    print(f"\nloss {first:.3f} → {last:.3f} in {args.steps} steps "
          f"({time.time() - t0:.1f}s)")
    if args.ckpt:
        ckpt.save(args.ckpt, params)
        print(f"params saved to {args.ckpt}")


if __name__ == "__main__":
    main()
