"""GraphEdge quickstart: perceive → partition → offload → cost report.

    PYTHONPATH=src python examples/quickstart.py \
        [--episodes 40] [--partitioner hicut_jax] [--policy drlgo]

Builds a small dynamic EC scenario (users on a 2000 m plane, 4 edge
servers), trains DRLGO briefly, then runs GraphEdge control steps through
the pluggable :class:`repro.core.api.GraphEdgeController` and compares
against baseline policies — all selected by registry name.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.api import (GraphEdgeController, available_offload_policies,
                            available_partitioners)
from repro.core.offload.drlgo import DRLGOTrainer, DRLGOTrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=40)
    ap.add_argument("--users", type=int, default=32)
    ap.add_argument("--partitioner", default="hicut_jax",
                    choices=available_partitioners())
    ap.add_argument("--policy", default="drlgo",
                    choices=available_offload_policies())
    ap.add_argument("--steps", type=int, default=3,
                    help="dynamic control steps to roll out")
    args = ap.parse_args()

    cfg = DRLGOTrainerConfig(capacity=args.users + 8, n_users=args.users,
                             n_assoc=3 * args.users,
                             episodes=args.episodes, warmup_steps=256,
                             cost_scale=1.0, partitioner=args.partitioner)
    trainer = DRLGOTrainer(cfg)
    kw = {}
    if args.policy == "drlgo":
        print(f"training DRLGO for {args.episodes} episodes "
              f"({args.users} users, 4 edge servers)...")
        trainer.train(log_every=max(args.episodes // 4, 1))
        kw = {"trainer": trainer}
    elif args.policy == "ppo":
        from repro.core.dynamic_graph import perturb_scenario
        from repro.core.offload.env import OBS_DIM
        from repro.core.offload.ppo import PPOConfig, PTOMAgent
        print(f"training PTOM (PPO) for {args.episodes} episodes "
              f"({args.users} users, 4 edge servers)...")
        ptom = PTOMAgent(PPOConfig(state_dim=4 * OBS_DIM, n_actions=4))
        for _ in range(args.episodes):
            trainer.scenario = perturb_scenario(trainer.rng, trainer.scenario,
                                                cfg.change_rate)
            ptom.run_episode(trainer.make_env(trainer.scenario))
        kw = {"agent": ptom}

    def controller(policy, **kw):
        return GraphEdgeController(net=trainer.net, policy=policy,
                                   policy_kwargs=kw,
                                   partitioner=args.partitioner,
                                   zeta_sp=cfg.zeta_sp,
                                   cost_scale=cfg.cost_scale)

    system = controller(args.policy, **kw)
    decision = system.step(trainer.scenario)
    print(f"\n=== GraphEdge control step "
          f"({args.partitioner} + {args.policy}) ===")
    print(f"subgraphs:             {decision.partition.num_subgraphs}  "
          f"(cut fraction {decision.partition.cut_metrics['cut_fraction']:.2f})")
    print(f"system cost C:         {float(decision.cost.c):.3f}  "
          f"(T_all={float(decision.cost.t_all):.3f}s, "
          f"I_all={float(decision.cost.i_all):.3f}J)")
    print(f"cross-server traffic:  "
          f"{float(decision.cost.cross_bits.sum()) / 8e6:.2f} MB")

    # multi-step control under the dynamic-graph event model (§3.2)
    decisions = system.rollout(trainer.scenario, args.steps,
                               np.random.default_rng(0))
    costs_t = ", ".join(f"{float(d.cost.c):.3f}" for d in decisions)
    print(f"rollout over {args.steps} dynamic steps: C(t) = [{costs_t}]  "
          f"(partition cache: {system.cache_hits} hits, "
          f"{system.cache_misses} misses)")

    # serving bridge: the decision directly yields a halo-exchange plan
    plan = decision.to_partition_plan()
    print(f"serving plan:          {plan.num_devices} devices, "
          f"halo {plan.halo} rows/device, "
          f"{plan.bytes_per_aggregate(64)} B/aggregation @64 features")

    print("\n=== baselines ===")
    results = {}
    for name in ("greedy", "random"):
        results[name] = float(controller(name).step(trainer.scenario).cost.c)
        print(f"{name:6s} cost:           {results[name]:.3f}")
    print(f"{args.policy} cost saving vs greedy: "
          f"{1 - float(decision.cost.c) / results['greedy']:+.1%}")


if __name__ == "__main__":
    main()
