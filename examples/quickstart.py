"""GraphEdge quickstart: perceive → HiCut → DRLGO offload → cost report.

    PYTHONPATH=src python examples/quickstart.py [--episodes 40]

Builds a small dynamic EC scenario (users on a 2000 m plane, 4 edge
servers), trains DRLGO briefly, then runs one GraphEdge control step and
compares against the greedy / random baselines.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.offload.baselines import run_greedy, run_random
from repro.core.offload.drlgo import DRLGOTrainer, DRLGOTrainerConfig
from repro.core.system import GraphEdge


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=40)
    ap.add_argument("--users", type=int, default=32)
    args = ap.parse_args()

    cfg = DRLGOTrainerConfig(capacity=args.users + 8, n_users=args.users,
                             n_assoc=3 * args.users,
                             episodes=args.episodes, warmup_steps=256,
                             cost_scale=1.0)
    trainer = DRLGOTrainer(cfg)
    print(f"training DRLGO for {args.episodes} episodes "
          f"({args.users} users, 4 edge servers)...")
    trainer.train(log_every=max(args.episodes // 4, 1))

    system = GraphEdge(trainer)
    result = system.offload(trainer.scenario)
    print("\n=== GraphEdge control step ===")
    print(f"subgraphs (HiCut):     {result['num_subgraphs']}")
    print(f"system cost C:         {result['system_cost']:.3f}  "
          f"(T_all={result['t_all']:.3f}s, I_all={result['i_all']:.3f}J)")
    print(f"cross-server traffic:  {result['cross_bits'] / 8e6:.2f} MB")

    gm = run_greedy(trainer.make_env(trainer.scenario))
    rm = np.mean([run_random(trainer.make_env(trainer.scenario), seed=s)
                  ["system_cost"] for s in range(5)])
    print("\n=== baselines ===")
    print(f"greedy (GM) cost:      {gm['system_cost']:.3f}")
    print(f"random (RM) cost:      {rm:.3f}")
    print(f"DRLGO cost saving vs GM: "
          f"{1 - result['system_cost'] / gm['system_cost']:+.1%}")


if __name__ == "__main__":
    main()
