"""Distributed GNN serving over a virtual device mesh.

    PYTHONPATH=src python examples/distributed_gnn_serving.py \
        [--devices 4] [--partitioner hicut_ref]

The serving-side realization of GraphEdge on a TPU-style mesh: edge
servers → mesh devices, registry-selected partition → vertex placement,
message passing → halo-exchange all-gathers. Pre-trains a GCN on a synthetic
citation graph, then serves batched node-classification requests with the
shard_map inference path and reports accuracy + ICI bytes (HiCut vs
random placement).

NOTE: sets XLA_FLAGS before importing jax — run as a script, not import.
"""
from __future__ import annotations

import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=4)
ap.add_argument("--vertices", type=int, default=260)
ap.add_argument("--requests", type=int, default=3)
ap.add_argument("--partitioner", default="hicut_ref",
                help="partitioner registry name (repro.core.api)")
args = ap.parse_args()
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")

import jax                                    # noqa: E402
import numpy as np                            # noqa: E402
from jax.sharding import Mesh                 # noqa: E402

from repro.core.api import get_partitioner    # noqa: E402
from repro.core.dynamic_graph import make_graph_state  # noqa: E402
from repro.data.graphs import CORA, make_graph, sample_subgraph  # noqa
from repro.gnn.distributed import (make_partition_plan,          # noqa
                                   distributed_gcn_forward)
from repro.gnn.models import pretrain         # noqa: E402


def main() -> None:
    p = args.devices
    g = make_graph(CORA, seed=0)
    sub = sample_subgraph(g, args.vertices, 6 * args.vertices, seed=0)
    print(f"graph: {sub.num_vertices} vertices, {sub.num_edges} edges")

    model, stats = pretrain("gcn", sub, steps=80)
    print(f"pre-trained GCN: train acc {stats['acc_train']:.2f}, "
          f"test acc {stats['acc_test']:.2f} (paper band: 0.60-0.80)")

    adj = sub.adjacency()
    mesh = Mesh(np.array(jax.devices()[:p]), ("servers",))
    rng = np.random.default_rng(0)

    # partition via the registry: vertices → subgraphs → devices
    state = make_graph_state(sub.num_vertices,
                             rng.uniform(0, 2000, (sub.num_vertices, 2)),
                             sub.edges, sub.task_sizes_kb())
    partition = get_partitioner(args.partitioner)(state)
    print(f"{args.partitioner}: {partition.num_subgraphs} subgraphs, "
          f"cut fraction {partition.cut_metrics['cut_fraction']:.2f}")

    for name, assign in (
            (args.partitioner, partition.to_device_assignment(p)),
            ("random", rng.integers(0, p, sub.num_vertices))):
        plan = make_partition_plan(adj, assign, p)
        out = None
        for req in range(args.requests):      # batched request loop
            out = distributed_gcn_forward(mesh, "servers", plan,
                                          model.params, sub.features)
        acc = (out.argmax(-1) == sub.labels).mean()
        print(f"[{name:6s}] halo rows/device: {plan.halo:4d}   "
              f"bytes/aggregation: {plan.bytes_per_aggregate(model.hidden):8d}"
              f"   serve acc: {acc:.2f}")


if __name__ == "__main__":
    main()
