"""End-to-end DRLGO training driver (paper Algorithm 2).

    PYTHONPATH=src python examples/train_drlgo.py --episodes 300 \
        --users 60 --batch 8 --ckpt /tmp/drlgo.npz

Every episode perturbs the dynamic scenario (20% change rate), re-runs
HiCut, rolls the MAMDP, and updates every agent; prints convergence and
saves actor/critic checkpoints restorable with repro.checkpoint.

``--batch B`` trains on B independently-perturbed scenarios per update
round via the vmapped batched environment (≈ B× the episodes/sec; the
paper-scale Fig. 7–9 sweeps use this path).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.checkpoint import ckpt
from repro.core.offload.drlgo import DRLGOTrainer, DRLGOTrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=300)
    ap.add_argument("--users", type=int, default=60)
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--change-rate", type=float, default=0.2)
    ap.add_argument("--zeta", type=float, default=0.1)
    ap.add_argument("--partitioner", default="hicut_ref",
                    help="partitioner registry name (repro.core.api)")
    ap.add_argument("--batch", type=int, default=1,
                    help="vmapped episodes per update round (B)")
    ap.add_argument("--ckpt", default="/tmp/drlgo_ckpt.npz")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = DRLGOTrainerConfig(
        capacity=args.users + 16, n_users=args.users,
        n_assoc=3 * args.users, n_servers=args.servers,
        episodes=args.episodes, change_rate=args.change_rate,
        zeta_sp=args.zeta, warmup_steps=512, cost_scale=1.0,
        partitioner=args.partitioner, batch_envs=args.batch, seed=args.seed)
    trainer = DRLGOTrainer(cfg)
    t0 = time.perf_counter()
    hist = trainer.train(log_every=max(args.episodes // 20, 1))
    dt = time.perf_counter() - t0
    print(f"trained {len(hist)} episodes in {dt:.1f}s "
          f"({len(hist) / dt:.2f} eps/s, batch={args.batch})")

    rewards = np.array([h["reward"] for h in hist])
    w = max(args.episodes // 10, 1)
    print(f"\nreward first-{w}: {rewards[:w].mean():.2f}  "
          f"last-{w}: {rewards[-w:].mean():.2f}  "
          f"improvement: {rewards[-w:].mean() - rewards[:w].mean():+.2f}")
    ckpt.save(args.ckpt, {"actor": trainer.state.actor,
                          "critic": trainer.state.critic})
    print(f"checkpoint saved to {args.ckpt}")
    restored = ckpt.restore(args.ckpt, {"actor": trainer.state.actor,
                                        "critic": trainer.state.critic})
    print("checkpoint restore round-trip: OK"
          if len(restored["actor"]) == args.servers else "MISMATCH")


if __name__ == "__main__":
    main()
